"""The paper's technique inside LM training: manual data-parallel
gradient sync with hierarchical *tree* cross-pod reduction vs flat psum —
numerically identical, different collective schedule (HLO shown).

Run with 8 host devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/tree_gradient_sync.py
"""
import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm.hierarchical import hierarchical_allreduce
from repro.compat import shard_map
from repro.core.trees import TreeKind
from repro.launch.dryrun import collective_bytes


def main():
    devs = jax.devices()
    if len(devs) < 8:
        print("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
        return
    mesh = Mesh(np.array(devs[:8]).reshape(2, 4), ("pod", "data"))
    w = jnp.ones((4096,)) * 0.1
    x = jnp.arange(2.0 * 4 * 4096).reshape(2, 4, 4096) / 1e5

    def loss(w, xb):
        return jnp.sum(jnp.tanh(xb @ w))

    def grads_tree(xb):
        g = jax.grad(loss)(w, xb.reshape(1, -1))
        # paper technique: reduce-scatter intra-pod, shifted-tree
        # all-reduce across pods, all-gather intra-pod
        g = hierarchical_allreduce(g, "pod", "data", npods=2, inner_size=4,
                                   kind=TreeKind.SHIFTED, tag=0)
        return g.reshape(1, 1, -1)

    def grads_psum(xb):
        g = jax.grad(loss)(w, xb.reshape(1, -1))
        return jax.lax.psum(g, ("pod", "data")).reshape(1, 1, -1)

    outs = {}
    for name, f in (("tree", grads_tree), ("psum", grads_psum)):
        jf = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod", "data"),
                                   out_specs=P("pod", "data")))
        compiled = jf.lower(x).compile()
        outs[name] = np.asarray(jf(x))
        cb = collective_bytes(compiled.as_text())
        print(f"{name:5s} collectives:",
              {k: f"{v/1e3:.1f}KB" for k, v in cb.items()})
    assert np.allclose(outs["tree"], outs["psum"], rtol=1e-6)
    print("gradients identical: True")


if __name__ == "__main__":
    main()
