"""Analyze-once / solve-many with the PSelInvEngine session API.

One symbolic analysis (trees, rounds, tables, jitted sweep) serves an
entire stream of matrices that share a sparsity structure — the serving
pattern the engine exists for. Values move; structure doesn't.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/pselinv_engine.py
"""
import time

import numpy as np
import scipy.sparse as sp

from repro.core import sparse
from repro.core.engine import Grid, PlanOptions, PSelInvEngine
from repro.core.pselinv_dist import gather_blocks
from repro.core.selinv import dense_selinv_oracle


def main():
    A = sparse.laplacian_2d(16, 8)

    # 1. analyze ONCE: symbolic factorization -> CommPlan IR ->
    #    overlapped round schedule -> per-device tables -> jitted sweep.
    #    The session is cached on (structure, b, grid, options).
    t0 = time.perf_counter()
    engine = PSelInvEngine.analyze(
        A, b=8, grid=Grid(4, 2),
        options=PlanOptions(overlap=True, coalesce_max=8))
    stats = engine.stats()
    print(f"analyze: {time.perf_counter() - t0:.2f}s  "
          f"rounds={stats['ppermute_rounds']} "
          f"peak_arena_blocks={stats['peak_arena_blocks']}")

    # a second analyze of the same structure is a cache hit — same
    # engine object, nothing recompiled
    again = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                  options=PlanOptions(overlap=True,
                                                      coalesce_max=8))
    print(f"re-analyze is cached: {again is engine} "
          f"(hits={PSelInvEngine.cache_hits})")

    # 2. solve MANY: same structure, different values — one batched
    #    vmapped sweep call, no per-matrix retrace or recompile.
    mats = [A + sp.identity(A.shape[0]) * c for c in (0.0, 0.5, 1.0, 2.0)]
    t0 = time.perf_counter()
    outs = np.asarray(engine.solve_many(mats))        # (B, P, ...)
    print(f"solve_many(B={len(mats)}): {time.perf_counter() - t0:.2f}s  "
          f"out shape {outs.shape}  traces={engine.trace_count}")

    # 3. each batch member is a real selected inverse
    for i, M in enumerate(mats):
        ref = dense_selinv_oracle(M)
        blocks = gather_blocks(outs[i], engine)
        K = 0
        err = abs(blocks[K, K] - ref[:8, :8]).max()
        print(f"  matrix {i}: |A^-1(0,0) - oracle| = {err:.2e}")

    # 4. the cached plan also answers timing questions without
    #    re-lowering anything
    sim = engine.simulate()
    print(f"simulated sweep time: {sim.total_time * 1e6:.1f} us "
          f"(comm/comp = {sim.comm_to_comp_ratio():.2f})")

    # 5. the uniform round-stream executor: the SAME overlapped schedule,
    #    replayed from round-indexed tables by one lax.fori_loop body —
    #    identical output, but the program no longer grows with the
    #    round count (compare the compile metrics via stats(compile=True))
    streng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                   options=PlanOptions(stream=True))
    out_stream = np.asarray(streng.solve(A))
    out_base = np.asarray(engine.solve(A))
    cs, cu = streng.stats(compile=True), engine.stats(compile=True)
    print(f"stream executor: |out - overlapped| = "
          f"{abs(out_stream - out_base).max():.1e}  "
          f"hlo {cs['hlo_bytes'] / 1e3:.0f}kB vs {cu['hlo_bytes'] / 1e3:.0f}kB  "
          f"trace+compile {cs['trace_lower_ms'] + cs['compile_ms']:.0f}ms "
          f"vs {cu['trace_lower_ms'] + cu['compile_ms']:.0f}ms")

    # 6. the axis-factored stream (default): communication is a static
    #    dictionary of per-(grid-offset, lane-width) comm slots over the
    #    (pr, pc) torus, and each fori_loop round lax.cond-gates only the
    #    slots it actually uses — so the stream's executed wire bytes sit
    #    near the unrolled executor's instead of shipping every device's
    #    lane stack on every ring shift of every round.
    #    stats() reports both wire metrics; axis_factored=False recovers
    #    the old flat-ring encoding for an A/B, and shift_budget=k
    #    coarsens the slot dictionary (fewer gated permutes, more wire).
    ss = streng.stats()
    flat = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                 options=PlanOptions(stream=True,
                                                     axis_factored=False))
    fs = flat.stats()
    out_flat = np.asarray(flat.solve(A))
    print(f"axis-factored stream: wire {ss['stream_wire_bytes'] / 1e6:.1f}MB "
          f"vs flat-ring {fs['stream_wire_bytes'] / 1e6:.1f}MB  "
          f"active shifts/round {ss['stream_shifts_per_round']:.2f} "
          f"vs {fs['stream_shifts_per_round']:.2f}  "
          f"|out - flat| = {abs(out_stream - out_flat).max():.1e}")

    # 7. PlanLint: every program above already passed the static
    #    verifier at build time (PlanOptions(verify="error") is the
    #    default — analyze raises PlanVerificationError on any
    #    ERROR-severity diagnostic). Corrupt a copy of the lowered
    #    stream tables the way a buggy scheduler would — flip one
    #    slot_active gate bit off while the receive table still routes a
    #    device onto the slot — and the linter names the defect:
    import copy

    from repro.core import verify

    st = copy.deepcopy(streng.program.stream_tables)
    t, si = np.argwhere(st.slot_active)[0]
    st.slot_active[t, si] = False
    diags = verify.check_stream(st, streng.program.plan)
    print("PlanLint on a corrupted copy:")
    print(verify.lint_report(diags))

    # 8. HloLint: PlanLint proves the *tables* sound; HloLint closes the
    #    last gap by parsing the jaxpr / StableHLO / optimized HLO that
    #    XLA actually compiles and cross-checking it against those same
    #    tables — permute pair sets, fori_loop trip counts, wire-byte
    #    conservation, hot-path hygiene. lint_compiled() runs all three
    #    layers on the live session (PlanOptions(verify_compiled=...)
    #    wires the same pass into build_program; tools/hlo_lint.py is
    #    the CLI over the whole corpus, devices not required).
    from repro.core import hlo_verify

    hdiags = streng.lint_compiled()
    nerr = sum(1 for d in hdiags if d.severity == "error")
    print(f"HloLint over the compiled stream sweep: {nerr} error(s) "
          f"across jaxpr + stablehlo + optimized-hlo layers")

    #    inject the defect HloLint exists for — a stray all-gather on a
    #    hot path whose whole design is point-to-point permute rounds —
    #    into a copy of the lowered StableHLO and it names the line:
    _, sh_text = hlo_verify.abstract_lower(streng.program)
    bad = sh_text.replace(
        "func.func public @main",
        '"stablehlo.all_gather"(%bad) : (tensor<8x8xf32>) -> '
        "tensor<8x8xf32>\nfunc.func public @main", 1)
    hdiags = hlo_verify.check_hygiene(bad, layer="stablehlo")
    print("HloLint on a corrupted copy:")
    print(verify.lint_report(hdiags))

    # 9. SweepScope: the lints above verify the schedule; the
    #    observability tier *measures* it. Enable the global span
    #    tracer, re-run analyze + solve, and every host-side stage
    #    (symbolic -> plan -> lower -> verify, value prep, solve
    #    dispatch) lands in a ring buffer; profile_rounds() then
    #    re-executes the sweep as per-round jitted segments with
    #    block_until_ready fencing — the measured per-round timeline,
    #    joined against the plan wire tables and the alpha-beta
    #    simulator. Everything exports to one Chrome-trace JSON
    #    (chrome://tracing, ui.perfetto.dev); tools/obs_report.py is
    #    the CLI over the same pipeline.
    from repro.obs.export import write_trace
    from repro.obs.trace import TRACER

    TRACER.enable()
    obs_eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                    options=PlanOptions(coalesce_max=6))
    vals = obs_eng.prepare_values(A)
    obs_eng.solve(vals)
    spans = TRACER.spans()
    print(f"traced {len(spans)} host spans: "
          + " ".join(sorted({s.name for s in spans})))

    profile = obs_eng.profile_rounds(vals, reps=2)
    TRACER.disable()
    print(profile.report())          # per-round walls + imbalance table
    path = write_trace("pselinv_engine.trace.json", spans=spans,
                       profile=profile)
    print(f"wrote {path} — load it in chrome://tracing or "
          f"ui.perfetto.dev")


if __name__ == "__main__":
    main()
