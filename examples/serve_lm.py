"""Batched serving example: continuous batching over a request queue
with per-slot KV caches (greedy decoding of a small random-weight LM).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax

from repro.config import get_config, reduced_config
from repro.models import get_model
from repro.runtime.serve_loop import Request, ServeEngine


def main():
    cfg = reduced_config(get_config("qwen3-32b"), vocab=2048, d_model=128,
                         n_layers=4)
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    eng = ServeEngine(api, params, batch_slots=4, max_seq=64)

    prompts = [[1, 5, 9], [2, 4], [3, 3, 3, 3], [7], [11, 13], [17, 19, 23]]
    reqs = [Request(rid=i, prompt=p, max_new=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.out}")
        assert r.done and len(r.out) == 8


if __name__ == "__main__":
    main()
