"""Batched serving with SelInvServer: structure-keyed coalescing over
a mixed request stream.

The engine makes B same-structure solves cost one compile and ~10×
less per matrix; the server turns *traffic* into those batches: each
submitted matrix is fingerprinted by sparsity pattern, coalesced with
same-structure neighbors under a dynamic batch window (flush on full
bucket / max wait / queue pressure), padded to a power-of-2 bucket so
odd batch sizes reuse compiled programs, and answered through a
per-request future.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    JAX_ENABLE_X64=1 PYTHONPATH=src python examples/pselinv_serve.py
"""
import time

import numpy as np
import scipy.sparse as sp

import jax.numpy as jnp

from repro.core import sparse
from repro.core.engine import Grid, PSelInvEngine
from repro.serve import BatchWindow, SelInvServer, ServeConfig


def main():
    PSelInvEngine.clear_cache()
    grid = Grid(4, 2)

    # 1. a server: engine parameters + the dynamic batch window.
    #    max_batch=16 full buckets flush immediately; a lone request
    #    waits at most 2 ms for company; a backlog past 64 flushes the
    #    fullest queues early (bounded absorbed work — the paper's
    #    load-balancing lesson applied to the request queue).
    cfg = ServeConfig(b=8, grid=grid, dtype=jnp.float64,
                      window=BatchWindow(max_batch=16, max_wait_ms=2.0,
                                         pressure=64))

    # 2. mixed traffic: two sparsity structures, shifted values — the
    #    server coalesces per structure, never across.
    A = sparse.laplacian_2d(16, 8)
    B = sparse.laplacian_2d(24, 8)
    I_A = sp.identity(A.shape[0])
    I_B = sp.identity(B.shape[0])
    stream = []
    for i in range(40):
        stream.append(A + 0.1 * (i + 1) * I_A if i % 3 else
                      B + 0.1 * (i + 1) * I_B)

    # 3. serve it: the context manager runs the background worker;
    #    submit() returns a future immediately.
    with SelInvServer(cfg) as srv:
        t0 = time.perf_counter()
        reqs = [srv.submit(M) for M in stream]
        outs = [np.asarray(r.result(timeout=120)) for r in reqs]
        wall = time.perf_counter() - t0
        stats = srv.stats()

    print(f"served {len(stream)} requests in {wall:.2f}s "
          f"({wall / len(stream) * 1e3:.2f} ms/matrix, cold compiles "
          f"included) in {stats['batches']} batches")
    print(f"  latency p50/p95/p99: {stats['latency_p50_us'] / 1e3:.1f} / "
          f"{stats['latency_p95_us'] / 1e3:.1f} / "
          f"{stats['latency_p99_us'] / 1e3:.1f} ms")
    print(f"  batch sizes {stats['batch_size_hist']} rode buckets "
          f"{stats['batch_bucket_hist']} "
          f"(occupancy {stats['batch_occupancy_mean']:.2f})")
    for skey, s in stats["structures"].items():
        print(f"  structure {skey}: buckets {s['buckets_used']} -> "
              f"{s['trace_count']} compiles for {s['solve_calls']} "
              f"batched solves")
    print(f"  engine cache: {stats['engine_cache']['engines']} sessions, "
          f"{stats['engine_cache']['bytes'] / 1e6:.1f} MB tables, "
          f"{stats['engine_cache']['hits']} hits")

    # 4. every served result is the matrix's own selected inverse —
    #    identical to an unbatched engine.solve of the same matrix.
    eng = srv.engine_for(stream[0])
    ref = np.asarray(eng.solve(stream[0], dtype=jnp.float64))
    print(f"  |served - unbatched| = {abs(outs[0] - ref).max():.2e}")


if __name__ == "__main__":
    main()
