"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps on CPU with the full substrate (data pipeline, AdamW, checkpoints,
fault-tolerant loop).

    PYTHONPATH=src python examples/train_lm.py --steps 200

Any assigned arch works via --arch (reduced to ~100M with --width).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.config import get_config
from repro.data.pipeline import SyntheticTokens
from repro.models import get_model
from repro.optim import adamw_init, adamw_update, cosine_warmup
from repro.runtime.train_loop import TrainLoopConfig, run_train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--width", type=int, default=512)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config(args.arch)
    cfg = dataclasses.replace(
        base, n_layers=args.layers, d_model=args.width,
        n_heads=8, n_kv_heads=min(base.n_kv_heads, 4) or 4, head_dim=64,
        d_ff=4 * args.width if base.d_ff else 0, vocab=8192,
        n_experts=min(base.n_experts, 4), top_k=min(base.top_k, 2),
        enc_layers=2 if base.enc_layers else 0,
        layer_group=1 if not (base.attn_every or base.xlstm_pattern)
        else base.layer_group, param_dtype="float32",
        attn_every=min(base.attn_every, 2) if base.attn_every else 0)
    if cfg.attn_every:
        cfg = dataclasses.replace(cfg, n_layers=max(args.layers, 2),
                                  layer_group=2, attn_every=2)
    api = get_model(cfg)
    params = api.init(jax.random.key(0))
    nparams = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={nparams/1e6:.1f}M")
    opt = adamw_init(params)

    @jax.jit
    def raw_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, batch))(params)
        lr = cosine_warmup(step, 3e-4, warmup=20, total=args.steps)
        params, opt_state, mx = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, loss, mx

    def step_fn(params, opt_state, batch, step):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.frontend == "vision":
            b["frontend"] = jnp.ones(
                (args.batch, cfg.n_frontend_tokens, cfg.d_model))
        elif cfg.enc_layers:
            b["frontend"] = jnp.ones((args.batch, args.seq, cfg.d_model))
        return raw_step(params, opt_state, b, jnp.asarray(step))

    pipe = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)
    out = run_train_loop(
        step_fn, params, opt, pipe,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=50,
                        ckpt_dir=args.ckpt, log_every=20))
    first = sum(out["losses"][:10]) / 10
    last = sum(out["losses"][-10:]) / 10
    print(f"loss {first:.3f} -> {last:.3f} "
          f"(stragglers={out['stragglers']}, restarts={out['restarts']})")
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
