"""Quickstart: selected inversion end-to-end + the paper's three
communication trees on a real sparse structure.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import sparse
from repro.core.schedule import Grid2D
from repro.core.selinv import compare_with_oracle, selected_inverse
from repro.core.simulator import volume_stats, volumes_fast
from repro.core.symbolic import symbolic_factorize_elements
from repro.core.trees import TreeKind, binary_tree, shifted_binary_tree


def main():
    # 1. numeric selected inversion on a 2-D Laplacian
    A = sparse.laplacian_2d(12, 12)
    Ainv, bs = selected_inverse(A, max_supernode=8, backend="jax")
    err = compare_with_oracle(Ainv, bs, A)
    print(f"selected inversion: N={A.shape[0]} supernodes={bs.nsuper} "
          f"max|err| vs dense inverse = {err:.2e}")

    # 2. the paper's trees (Fig. 3): root 4, receivers 1,2,3,5,6
    t = binary_tree(4, [1, 2, 3, 5, 6])
    print("binary tree children:", t.children_map())
    t = shifted_binary_tree(4, [1, 2, 3, 5, 6], shift=4)
    print("shifted tree children:", t.children_map())

    # 3. communication-volume balance on a PSelInv schedule (Table 1)
    G, sizes = sparse.fem3d_like_structure(12, 12, 12, 3)
    bs = symbolic_factorize_elements(G, sizes, max_supernode=12)
    grid = Grid2D(16, 16)
    for kind in (TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED):
        s = volume_stats(volumes_fast(bs, grid, kind)["col-bcast"] / 1e6)
        print(f"{kind.value:8s} col-bcast MB/rank: "
              f"min={s['min']:.2f} max={s['max']:.2f} std={s['std']:.2f}")


if __name__ == "__main__":
    main()
