"""PlanLint — the static schedule verifier every lowered artifact passes.

The paper's contribution is a *schedule property*: tree-shaped
asynchronous rounds stay correct only if no processor's in-flight
payloads collide, and stay fast only if no processor's fan-in piles up
(arXiv:1504.04714 §4). The stack lowers three executors from one
CommPlan IR, and the worst bugs so far — (device, slot) dependence keys
silently wiring a stale arena tenant — were exactly the class a static
pass over the lowered tables catches at plan time instead of as f64
mismatches. This module is that pass: a pipeline of checkers over any
lowered artifact (:class:`~.plan.CommPlan`, level-serial
:class:`~.plan.ExecPlan`, overlapped :class:`~.plan.OverlappedExec`
round list, or :class:`~.stream.StreamTables`) emitting typed
:class:`PlanDiagnostic` records instead of scattered asserts.

Checker pipeline (each family owns a stable diagnostic ``code``):

* **race detector** — happens-before over (device, slot, generation)
  keys of the overlapped arena: every col-bcast forward reads a slot
  whose *latest* visible write is its own generation's fill
  (``race/stale-read``); every recycled Û slot's new fill is
  anti-dep-ordered after the previous tenant's last reader, i.e.
  ``scomp(T) boundary <= first fill round of the next tenant``
  (``race/war-overlap``); reduce/xfer-out lanes land inside their
  level's [producer boundary, consumer boundary) liveness window; and
  no two lanes of one round write the same (device, slot)
  (``race/waw-round``).
* **permutation legality** — every ppermute (unrolled rounds, flat-ring
  and gated comm slots) has unique sources and destinations
  (``perm/dup-endpoint``), no self-edges (``perm/self-edge``), edge
  metadata consistent with the perm (``perm/edges-mismatch``), and
  single-grid-offset slot perms under ``axis_factored``
  (``perm/offset-mix``); ``recv_slot``/trash routing is total and
  in-width (``gate/recv-route``, ``gate/lane-overflow``) and the
  ``slot_active`` gate table matches the receive table it guards
  (``gate/active-mismatch`` — the one check
  ``simulator.executed_wire_bytes`` shares through
  :func:`check_stream_gates`).
* **conservation** — per-(kind, rank) wire bytes summed from the
  executor's own tables must equal the CommPlan's tree volumes in wire
  orientation (``conserve/bytes-drift``) — the one-pass unification of
  the scattered executed-equals-simulated cross-checks.
* **overload lint** (paper §4 heuristic, WARN severity) — per-(round,
  device) inbound lane histograms against the coalescing fan-in cap
  (``load/fanin``) and whole-sweep inbound byte imbalance
  (``load/imbalance``).
* **soundness** — CommTree acyclicity/coverage (``dag/cycle``), arena
  addressing bounds (``arena/out-of-bounds``), and shared partial/S
  region generation ordering (``arena/region-order``).

Entry points: :func:`verify_artifact` (one artifact),
:func:`verify_program` (everything a compiled
``pselinv_dist.PSelInvProgram`` carries), and
:func:`enforce_verification`, which applies the
``PlanOptions(verify=...)`` mode — ``"error"`` raises
:class:`PlanVerificationError` on any ERROR diagnostic, ``"warn"``
issues one ``warnings.warn`` summary, ``"off"`` skips the pass
entirely. ``tools/plan_lint.py`` is the CLI over a structure corpus,
and ``tests/test_verify.py`` is the mutation self-test harness that
corrupts lowered tables and asserts each checker fires with the right
code.
"""
from __future__ import annotations

import warnings
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Tuple

import numpy as np

from .plan import CommPlan, ExecPlan, OverlappedExec
from .stream import StreamTables

__all__ = ["PlanDiagnostic", "PlanVerificationError", "VERIFY_MODES",
           "verify_artifact", "verify_program", "enforce_verification",
           "check_plan", "check_exec", "check_overlap", "check_stream",
           "check_stream_gates", "lint_report"]

#: the accepted ``PlanOptions(verify=...)`` / ``engine.analyze`` modes
VERIFY_MODES = ("error", "warn", "off")

#: default fan-in lint threshold: inbound lanes one device absorbs in a
#: single round before the overload heuristic warns (the coalescing cap
#: is the natural bound — one pair per receiver per ppermute round, at
#: most ``coalesce_max`` lanes per pair)
FANIN_MAX = 8

#: whole-sweep inbound byte imbalance (max/mean) before the load lint
#: warns — the paper's load-balancing signal, surfaced pre-execution
IMBALANCE_MAX = 4.0


@dataclass(frozen=True)
class PlanDiagnostic:
    """One typed finding of the verifier: a stable ``code`` (checker
    family / defect), ``severity`` ("error" = the lowered program is
    wrong or unsafe; "warn" = legal but suspect, e.g. load skew), a
    human message, the (device, round, slot) location where known
    (-1 = not applicable), and a fix hint."""
    code: str
    severity: str
    message: str
    device: int = -1
    round: int = -1
    slot: int = -1
    hint: str = ""

    def __str__(self) -> str:
        loc = ",".join(f"{k}={v}" for k, v in
                       (("dev", self.device), ("round", self.round),
                        ("slot", self.slot)) if v >= 0)
        s = f"[{self.severity.upper()}] {self.code}"
        if loc:
            s += f" ({loc})"
        s += f": {self.message}"
        if self.hint:
            s += f" — {self.hint}"
        return s


class PlanVerificationError(ValueError):
    """Raised by :func:`enforce_verification` in ``"error"`` mode when a
    lowered artifact carries ERROR-severity diagnostics. Carries the
    full diagnostic list on ``.diagnostics``."""

    def __init__(self, message: str, diagnostics: List[PlanDiagnostic]):
        super().__init__(message)
        self.diagnostics = diagnostics


def _err(code: str, msg: str, **loc) -> PlanDiagnostic:
    return PlanDiagnostic(code=code, severity="error", message=msg, **loc)


def _warn(code: str, msg: str, **loc) -> PlanDiagnostic:
    return PlanDiagnostic(code=code, severity="warn", message=msg, **loc)


# ---------------------------------------------------------------------------
# CommPlan: tree soundness
# ---------------------------------------------------------------------------

def check_plan(plan: CommPlan) -> List[PlanDiagnostic]:
    """Lint the IR itself: every collective's tree is acyclic, reaches
    exactly its participant set from its root, and prices non-negative
    bytes."""
    diags: List[PlanDiagnostic] = []
    for i, op in enumerate(plan.ops):
        try:
            op.tree.validate()
        except ValueError as e:
            diags.append(_err(
                "dag/cycle",
                f"op {i} ({op.kind}, supernode {op.supernode}): tree is "
                f"not a rooted spanning DAG — {e}",
                hint="rebuild the tree via plan.tree_for; a hand-edited "
                     "CommTree must reach every participant exactly once"))
            continue
        if op.tree.root != op.root:
            diags.append(_err(
                "dag/cycle",
                f"op {i} ({op.kind}, supernode {op.supernode}): tree "
                f"root {op.tree.root} != op root {op.root}",
                device=op.root))
        if set(op.tree.ranks) != set(op.participants):
            diags.append(_err(
                "dag/cycle",
                f"op {i} ({op.kind}, supernode {op.supernode}): tree "
                f"ranks {sorted(op.tree.ranks)} != participants "
                f"{sorted(op.participants)}"))
        if op.nbytes < 0:
            diags.append(_err(
                "conserve/bytes-drift",
                f"op {i} ({op.kind}, supernode {op.supernode}): negative "
                f"byte count {op.nbytes}"))
    return diags


# ---------------------------------------------------------------------------
# conservation: executor wire bytes == plan tree volumes
# ---------------------------------------------------------------------------

def _plan_wire_volumes(plan: CommPlan
                       ) -> Tuple[Dict[str, np.ndarray],
                                  Dict[str, np.ndarray]]:
    """Per-(kind, rank) wire bytes the IR's trees prescribe, in **wire
    orientation**: broadcast edges flow parent -> child, reduce edges
    child -> parent (``diag-bcast`` is host-absorbed and never moves)."""
    P = plan.grid.size
    out: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    inc: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    for op in plan.ops:
        if op.kind == "diag-bcast":
            continue
        mirrored = op.kind in ("row-reduce", "diag-reduce")
        for parent, kids in op.tree.children:
            for kid in kids:
                s, d = (kid, parent) if mirrored else (parent, kid)
                out[op.kind][s] += op.nbytes
                inc[op.kind][d] += op.nbytes
    return dict(out), dict(inc)


def _check_conservation(edges: Iterable[Tuple[int, int, str, int, float]],
                        plan: CommPlan) -> List[PlanDiagnostic]:
    """Wire bytes the executor tables carry must equal the plan's tree
    volumes per (kind, rank) — the one-pass form of the scattered
    executed-equals-simulated cross-checks."""
    P = plan.grid.size
    out_e: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    inc_e: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    for (s, d, kind, _lv, nb_) in edges:
        out_e[kind][s] += nb_
        inc_e[kind][d] += nb_
    out_p, inc_p = _plan_wire_volumes(plan)
    diags: List[PlanDiagnostic] = []
    z = np.zeros(P)
    for kind in sorted(set(out_e) | set(out_p)):
        for name, got, want in (("outgoing", out_e.get(kind, z),
                                 out_p.get(kind, z)),
                                ("incoming", inc_e.get(kind, z),
                                 inc_p.get(kind, z))):
            bad = np.flatnonzero(~np.isclose(got, want))
            if len(bad):
                r = int(bad[0])
                diags.append(_err(
                    "conserve/bytes-drift",
                    f"{kind}: {name} wire bytes drift from the plan "
                    f"volumes on {len(bad)} rank(s) — rank {r} carries "
                    f"{got[r]:.0f} B, the trees prescribe {want[r]:.0f} B",
                    device=r,
                    hint="an executor table was edited without "
                         "re-lowering, or a lowering dropped/duplicated "
                         "a tree edge"))
    return diags


# ---------------------------------------------------------------------------
# overlapped rounds: structure, races, liveness, load
# ---------------------------------------------------------------------------

def _round_lanes(ov: OverlappedExec):
    """Every lane of the compiled stream, reconstructed from the tables:
    (round, src, dst, gather_slot, scatter_slot, kind, level, nbytes,
    from_lh, local). Lane order inside ``GlobalRound.edges``/``lmoves``
    follows the scheduler's (pair, lane) nesting, so the running lane
    index recovers the table column (the ``_u_write_lanes`` idiom of the
    replay tests). Lanes whose metadata overruns the tables are skipped
    here — :func:`_check_round_structure` reports those."""
    for t, rnd in enumerate(ov.rounds):
        lane_j: Dict[Tuple[int, int], int] = {}
        for (s, d, kind, lv, nb_) in rnd.edges:
            j = lane_j.get((s, d), 0)
            lane_j[(s, d)] = j + 1
            if j >= rnd.gather.shape[1]:
                continue
            yield (t, s, d, int(rnd.gather[s, j]), int(rnd.scatter[d, j]),
                   kind, lv, nb_, bool(rnd.glh[s, j]), False)
        lane_i: Dict[int, int] = {}
        for (dev, kind, lv) in rnd.lmoves:
            j = lane_i.get(dev, 0)
            lane_i[dev] = j + 1
            if rnd.lgather is None or j >= rnd.lgather.shape[1]:
                continue
            yield (t, dev, dev, int(rnd.lgather[dev, j]),
                   int(rnd.lscatter[dev, j]), kind, lv, 0.0,
                   bool(rnd.lglh[dev, j]), True)


def _check_round_structure(ov: OverlappedExec) -> List[PlanDiagnostic]:
    """Permutation legality, in-round write uniqueness, and arena bounds
    of the unrolled round list."""
    diags: List[PlanDiagnostic] = []
    P = ov.pr * ov.pc
    trash = ov.trash
    for t, rnd in enumerate(ov.rounds):
        srcs = [s for s, _ in rnd.perm]
        dsts = [d for _, d in rnd.perm]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            dup = sorted({x for x in srcs if srcs.count(x) > 1}
                         | {x for x in dsts if dsts.count(x) > 1})
            diags.append(_err(
                "perm/dup-endpoint",
                f"round {t}: perm {sorted(rnd.perm)} books device(s) "
                f"{dup} as source or destination more than once — "
                "ppermute would drop a payload",
                round=t, device=dup[0],
                hint="a device may source and sink at most one transfer "
                     "per ppermute round"))
        for (s, d) in rnd.perm:
            if s == d:
                diags.append(_err(
                    "perm/self-edge",
                    f"round {t}: self-edge {s}->{d} in the perm — "
                    "owner-local copies belong in the local lane tables",
                    round=t, device=s))
        cnt: Dict[Tuple[int, int], int] = defaultdict(int)
        for (s, d, _kind, _lv, _nb) in rnd.edges:
            cnt[(s, d)] += 1
        if set(cnt) != set(rnd.perm):
            diags.append(_err(
                "perm/edges-mismatch",
                f"round {t}: edge metadata pairs {sorted(cnt)} disagree "
                f"with the permute pairs {sorted(rnd.perm)}",
                round=t))
        else:
            over = [(p, n) for p, n in cnt.items() if n > rnd.width]
            if over:
                diags.append(_err(
                    "perm/edges-mismatch",
                    f"round {t}: pair {over[0][0]} carries {over[0][1]} "
                    f"edge records but the round is {rnd.width} lanes "
                    "wide", round=t))
        # one writer per (device, slot) per round — two lanes landing in
        # the same slot inside one round silently drop a payload
        for dev in range(P):
            w = [int(x) for x in rnd.scatter[dev] if x != trash]
            if rnd.lwidth and rnd.lscatter is not None:
                w += [int(x) for x in rnd.lscatter[dev] if x != trash]
            seen = set()
            for x in w:
                if x in seen:
                    diags.append(_err(
                        "race/waw-round",
                        f"round {t}: device {dev} scatters twice into "
                        f"arena slot {x} in one round",
                        round=t, device=dev, slot=x,
                        hint="the one-writer-per-(device, slot, round) "
                             "invariant is broken — a payload is lost"))
                seen.add(x)
            for x in w:
                if not (0 <= x < ov.arena_blocks):
                    diags.append(_err(
                        "arena/out-of-bounds",
                        f"round {t}: device {dev} scatters into slot "
                        f"{x} outside the arena "
                        f"[0, {ov.arena_blocks})",
                        round=t, device=dev, slot=x))
    for (t, s, d, gs, ds, kind, lv, nb_, from_lh, local) in _round_lanes(ov):
        hi = ov.n_ainv if from_lh else ov.arena_blocks
        where = "the L-hat shard" if from_lh else "the arena"
        if not (0 <= gs < hi):
            diags.append(_err(
                "arena/out-of-bounds",
                f"round {t}: device {s} gathers {kind} lane from slot "
                f"{gs} outside {where} [0, {hi})",
                round=t, device=s, slot=gs))
    return diags


def _check_overlap_races(ov: OverlappedExec) -> List[PlanDiagnostic]:
    """The happens-before core: (device, slot, generation) domination
    and anti-dependence over the compiled rounds + compute boundaries.

    Boundary semantics (matches the scheduler): compute pinned at
    boundary ``t`` runs before round ``t``'s comm, so a write in round
    ``r`` is visible to boundary ``t`` iff ``r < t``, and a boundary's
    output is visible to round ``t`` iff ``boundary <= t``."""
    diags: List[PlanDiagnostic] = []
    at: Dict[Tuple[str, int], int] = {}
    at_idx: Dict[Tuple[str, int], int] = {}
    for t, ops in enumerate(ov.compute_at):
        for i, op in enumerate(ops):
            at[(op.kind, op.level)] = t
            at_idx[(op.kind, op.level)] = i
    nlev = len(ov.levels)
    u_lo = ov.n_ainv
    base_p = ov.levels[0].base_p if nlev else ov.n_ainv
    base_s = ov.levels[0].base_s if nlev else ov.n_ainv

    def boundary(kind: str, L: int) -> int | None:
        t = at.get((kind, L))
        if t is None:
            diags.append(_err(
                "race/stale-read",
                f"compute op ({kind}, level {L}) never fires — readers "
                "of its output race an absent producer",
                hint="the compute_at boundary list was corrupted"))
        return t

    lanes = list(_round_lanes(ov))

    # Û-region fills per (device, slot), keyed by generation (= level)
    writes: Dict[Tuple[int, int], Dict[int, List[int]]] = {}
    for (t, s, d, gs, ds, kind, lv, nb_, from_lh, local) in lanes:
        if kind in ("xfer", "col-bcast", "xfer-local") \
                and u_lo <= ds < base_p:
            writes.setdefault((d, ds), {}).setdefault(lv, []).append(t)

    def latest_levels(dev: int, slot: int, before: int):
        """Generations of the latest write into (dev, slot) strictly
        before round ``before`` (empty when never written)."""
        gens = writes.get((dev, slot), {})
        prior = [(r, l) for l, rs in gens.items() for r in rs
                 if r < before]
        if not prior:
            return None, frozenset()
        rmax = max(r for r, _l in prior)
        return rmax, frozenset(l for r, l in prior if r == rmax)

    # (1) every arena read a comm lane performs is dominated by its own
    # generation's fill: col-bcast forwards read the Û region, reduce /
    # xfer-out lanes read regions produced at compute boundaries
    for (t, s, d, gs, ds, kind, lv, nb_, from_lh, local) in lanes:
        if kind == "col-bcast" and not from_lh:
            _r, lv_at = latest_levels(s, gs, t)
            if lv not in lv_at:
                have = (f"generation(s) {sorted(lv_at)}" if lv_at
                        else "no fill at all")
                diags.append(_err(
                    "race/stale-read",
                    f"round {t}: device {s} forwards Û slot {gs} for "
                    f"generation {lv} but the latest visible write is "
                    f"{have} — the broadcast ships a stale tenant",
                    round=t, device=s, slot=gs,
                    hint="dependence keys must be (device, slot, "
                         "generation); a weaker key wires the previous "
                         "tenant's fill"))
        elif kind in ("row-reduce", "diag-reduce"):
            prod = "gemm" if kind == "row-reduce" else "scomp"
            cons = "write" if kind == "row-reduce" else "diagw"
            tp, tc = boundary(prod, lv), boundary(cons, lv)
            if tp is not None and t < tp:
                diags.append(_err(
                    "race/stale-read",
                    f"round {t}: {kind} lane {s}->{d} (level {lv}) fires "
                    f"before its producer {prod}({lv}) at boundary {tp} "
                    "— it ships an unwritten partial",
                    round=t, device=s, slot=gs))
            if tc is not None and t >= tc:
                diags.append(_err(
                    "race/stale-read",
                    f"round {t}: {kind} lane {s}->{d} (level {lv}) "
                    f"arrives at/after its consumer {cons}({lv}) at "
                    f"boundary {tc} — the contribution is lost",
                    round=t, device=d, slot=ds))
        elif kind in ("xfer-out", "xfer-out-local"):
            tw, ts_ = boundary("write", lv), boundary("scomp", lv)
            if tw is not None and t < tw:
                diags.append(_err(
                    "race/stale-read",
                    f"round {t}: xfer-out lane {s}->{d} (level {lv}) "
                    f"fires before write({lv}) at boundary {tw} — it "
                    "ships a stale A⁻¹ block",
                    round=t, device=s, slot=gs))
            if ts_ is not None and t >= ts_:
                diags.append(_err(
                    "race/stale-read",
                    f"round {t}: xfer-out lane {s}->{d} (level {lv}) "
                    f"lands at/after scomp({lv}) at boundary {ts_} — "
                    "the S einsum reads the transpose too early",
                    round=t, device=d, slot=ds))

    # (2) gemm-boundary domination: wherever a generation filled a slot,
    # that generation must still be the latest write when its level's
    # GEMM reads the slot, and every fill must land before the boundary
    for L in range(nlev):
        tg = boundary("gemm", L)
        if tg is None:
            continue
        for (dev, slot), gens in writes.items():
            if L not in gens:
                continue
            late = [r for r in gens[L] if r >= tg]
            if late:
                diags.append(_err(
                    "race/stale-read",
                    f"Û fill of generation {L} into (device {dev}, slot "
                    f"{slot}) lands in round {late[0]}, at/after its "
                    f"gemm boundary {tg} — the GEMM reads an unfilled "
                    "slot", round=late[0], device=dev, slot=slot))
                continue
            _r, lv_at = latest_levels(dev, slot, tg)
            if L not in lv_at:
                diags.append(_err(
                    "race/stale-read",
                    f"at gemm({L}) boundary {tg}, (device {dev}, slot "
                    f"{slot}) holds generation(s) {sorted(lv_at)} "
                    f"instead of {L} — a recycled tenant is visible at "
                    "read time", device=dev, slot=slot))

    # (3) WAR anti-dependence on recycled Û slots: the earlier tenant's
    # last reader (its scomp boundary) must precede the later tenant's
    # first fill round
    for (dev, slot), gens in sorted(writes.items()):
        order = sorted(gens)
        for la, lb in zip(order, order[1:]):
            ts_ = at.get(("scomp", la))
            first = min(gens[lb])
            if ts_ is None or ts_ > first:
                have = "never fires" if ts_ is None else \
                    f"fires at boundary {ts_}"
                diags.append(_err(
                    "race/war-overlap",
                    f"(device {dev}, slot {slot}): generation {lb}'s "
                    f"first fill lands in round {first} but the previous "
                    f"tenant {la}'s last reader scomp({la}) {have} — the "
                    "fill clobbers a live slot",
                    round=first, device=dev, slot=slot,
                    hint="a recycled slot's fill must carry the previous "
                         "tenant's scomp as an anti-dependence"))

    # (4) shared partial/S regions: generation L's occupancy must end
    # before generation L+1's begins (ties legal only reader-first)
    def _ordered(reader: str, writer: str, L: int, region: str):
        tr, tw = at.get((reader, L)), at.get((writer, L + 1))
        if tr is None or tw is None:
            return                      # reported by boundary() already
        ok = tr < tw or (tr == tw
                         and at_idx[(reader, L)] < at_idx[(writer, L + 1)])
        if not ok:
            diags.append(_err(
                "arena/region-order",
                f"shared {region} region: generation {L}'s last reader "
                f"{reader}({L}) at boundary {tr} does not precede "
                f"generation {L + 1}'s writer {writer}({L + 1}) at "
                f"boundary {tw} — aliased occupancies overlap in time",
                hint="compute ops sharing a boundary execute in "
                     "compute_at list order; the reader must be listed "
                     "first"))

    for L in range(nlev - 1):
        _ordered("write", "gemm", L, "partial")
        _ordered("diagw", "scomp", L, "S")

    # region geometry sanity
    if nlev and not (u_lo <= base_p <= base_s < ov.arena_blocks):
        diags.append(_err(
            "arena/out-of-bounds",
            f"arena regions out of order: n_ainv={u_lo}, "
            f"base_p={base_p}, base_s={base_s}, "
            f"arena_blocks={ov.arena_blocks}"))
    return diags


def _check_overlap_load(ov: OverlappedExec, fanin_max: int
                        ) -> List[PlanDiagnostic]:
    """The paper's overload heuristic as a pre-execution lint: WARN when
    one device's per-round inbound fan-in exceeds the coalescing cap, or
    when the whole-sweep inbound bytes skew past
    :data:`IMBALANCE_MAX` x the mean."""
    diags: List[PlanDiagnostic] = []
    P = ov.pr * ov.pc
    inbound = np.zeros(P)
    for t, rnd in enumerate(ov.rounds):
        lanes_in: Dict[int, int] = defaultdict(int)
        for (s, d, _kind, _lv, nb_) in rnd.edges:
            lanes_in[d] += 1
            inbound[d] += nb_
        for d, n in sorted(lanes_in.items()):
            if n > fanin_max:
                diags.append(_warn(
                    "load/fanin",
                    f"round {t}: device {d} absorbs {n} inbound lanes "
                    f"(> fan-in threshold {fanin_max}) — the paper's "
                    "overload heuristic flags this receiver",
                    round=t, device=d,
                    hint="spread the collective's tree or lower "
                         "coalesce_max"))
    mean = float(inbound.mean())
    if mean > 0:
        worst = int(inbound.argmax())
        ratio = float(inbound[worst]) / mean
        if ratio > IMBALANCE_MAX:
            diags.append(_warn(
                "load/imbalance",
                f"device {worst} receives {ratio:.1f}x the mean inbound "
                f"bytes over the sweep ({inbound[worst]:.0f} B vs mean "
                f"{mean:.0f} B)",
                device=worst,
                hint="a different tree kind (HYBRID/SHIFTED) "
                     "decorrelates hot roots"))
    return diags


def check_overlap(ov: OverlappedExec, plan: CommPlan | None = None, *,
                  fanin_max: int = FANIN_MAX) -> List[PlanDiagnostic]:
    """Full checker pipeline over an overlapped round stream: structural
    permutation legality, the (device, slot, generation) race detector,
    shared-region liveness, the load lint, and — when the originating
    ``plan`` is given — byte conservation against the IR's trees."""
    diags = _check_round_structure(ov)
    diags += _check_overlap_races(ov)
    diags += _check_overlap_load(ov, fanin_max)
    if plan is not None:
        diags += _check_conservation(
            (e for rnd in ov.rounds for e in rnd.edges), plan)
    return diags


# ---------------------------------------------------------------------------
# level-serial executor tables
# ---------------------------------------------------------------------------

def check_exec(ex: ExecPlan) -> List[PlanDiagnostic]:
    """Permutation legality of the level-serial executor's packed
    rounds (its phase ordering is barriered, so the race surface is the
    per-round ppermute constraint)."""
    diags: List[PlanDiagnostic] = []
    for L, lv in enumerate(ex.levels):
        phases = (("xfer", lv.xfer_in), ("col-bcast", lv.bcast),
                  ("row-reduce", lv.reduce), ("xfer-out", lv.xfer_out),
                  ("diag-reduce", lv.diag_reduce))
        for kind, rounds in phases:
            for t, rnd in enumerate(rounds):
                srcs = [s for s, _ in rnd.perm]
                dsts = [d for _, d in rnd.perm]
                if len(set(srcs)) != len(srcs) \
                        or len(set(dsts)) != len(dsts):
                    diags.append(_err(
                        "perm/dup-endpoint",
                        f"level {L} {kind} round {t}: perm "
                        f"{sorted(rnd.perm)} reuses a source or "
                        "destination", round=t))
                for (s, d) in rnd.perm:
                    if s == d:
                        diags.append(_err(
                            "perm/self-edge",
                            f"level {L} {kind} round {t}: self-edge "
                            f"{s}->{d}", round=t, device=s))
    return diags


# ---------------------------------------------------------------------------
# stream tables: slot dictionary, gates, routing, bounds
# ---------------------------------------------------------------------------

def check_stream_gates(st: StreamTables) -> List[PlanDiagnostic]:
    """The gate/receive consistency check
    ``simulator.executed_wire_bytes`` prices wire through: the active
    slot set re-derived from ``recv_slot`` must match the
    ``slot_active`` gate table the device program branches on — equal
    under ``axis_factored`` (a slot is active iff it delivers), a
    subset under the always-active flat ring."""
    diags: List[PlanDiagnostic] = []
    nslots = st.nslots
    for t in range(st.steps):
        derived = set()
        for d in range(st.pr * st.pc):
            si = int(st.recv_slot[t, d])
            if si < 0:
                continue
            if si >= nslots:
                diags.append(_err(
                    "gate/recv-route",
                    f"round {t}: device {d} receives on slot {si} but "
                    f"only {nslots} comm slots exist",
                    round=t, device=d, slot=si))
                continue
            derived.add(si)
        gated = {si for si in range(nslots) if st.slot_active[t, si]}
        if st.axis_factored and derived != gated:
            diags.append(_err(
                "gate/active-mismatch",
                f"round {t}: slots with receivers {sorted(derived)} != "
                f"gated active slots {sorted(gated)} — the gate table "
                "drifted from the receive table",
                round=t,
                slot=min(derived ^ gated) if derived ^ gated else -1,
                hint="an inactive slot with a receiver delivers zeros; "
                     "an active slot without receivers ships dead wire"))
        elif not derived <= gated:
            diags.append(_err(
                "gate/active-mismatch",
                f"round {t}: device receives on inactive slot(s) "
                f"{sorted(derived - gated)} — the arrival would be "
                "zeros", round=t, slot=min(derived - gated)))
    return diags


def check_stream(st: StreamTables, plan: CommPlan | None = None
                 ) -> List[PlanDiagnostic]:
    """Full checker pipeline over the gated stream tables: comm-slot
    dictionary legality, gate/receive consistency, scatter routing
    totality, lane-width discipline, arena bounds, and the lane-metadata
    cross-check (plus byte conservation against the plan's trees when
    given)."""
    diags: List[PlanDiagnostic] = []
    P = st.pr * st.pc

    # ---- slot dictionary ------------------------------------------------
    for si, perm in enumerate(st.slot_perm):
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            dup = sorted({x for x in srcs if srcs.count(x) > 1}
                         | {x for x in dsts if dsts.count(x) > 1})
            diags.append(_err(
                "perm/dup-endpoint",
                f"comm slot {si}: perm {sorted(perm)} books device(s) "
                f"{dup} more than once — not a permutation",
                slot=si, device=dup[0],
                hint="a slot perm must have unique sources and unique "
                     "destinations to be a (partial) permutation"))
        for (s, d) in perm:
            if s == d:
                diags.append(_err(
                    "perm/self-edge",
                    f"comm slot {si}: self-edge {s}->{d}",
                    slot=si, device=s))
            if not (0 <= s < P and 0 <= d < P):
                diags.append(_err(
                    "perm/dup-endpoint",
                    f"comm slot {si}: pair ({s}, {d}) outside the "
                    f"device range [0, {P})", slot=si))
        if st.axis_factored and perm:
            offs = {((d // st.pc - s // st.pc) % st.pr,
                     (d % st.pc - s % st.pc) % st.pc) for (s, d) in perm}
            if len(offs) != 1 or offs != {tuple(st.slot_shift[si])}:
                diags.append(_err(
                    "perm/offset-mix",
                    f"comm slot {si}: pairs span grid offsets "
                    f"{sorted(offs)}, declared {tuple(st.slot_shift[si])}"
                    " — a mixed-offset union is not a permutation",
                    slot=si))
        w = st.slot_width[si]
        if not (1 <= w <= max(st.W, 1)):
            diags.append(_err(
                "gate/lane-overflow",
                f"comm slot {si}: width {w} outside [1, {st.W}]",
                slot=si))

    # ---- gates vs receive table ----------------------------------------
    diags += check_stream_gates(st)

    # ---- routing totality + lane-width discipline ----------------------
    src_of = [dict((d, s) for (s, d) in perm) for perm in st.slot_perm]
    for t in range(st.steps):
        for d in range(P):
            lanes = [j for j in range(st.W)
                     if int(st.scatter[t, d, j]) != st.trash]
            si = int(st.recv_slot[t, d])
            if not lanes:
                continue
            if si < 0 or si >= st.nslots:
                diags.append(_err(
                    "gate/recv-route",
                    f"round {t}: device {d} scatters {len(lanes)} "
                    "lane(s) but has no receive slot — the payload "
                    "would be the previous loop carry",
                    round=t, device=d))
                continue
            if d not in src_of[si]:
                diags.append(_err(
                    "gate/recv-route",
                    f"round {t}: device {d} receives on slot {si} but "
                    "is not a destination of its perm",
                    round=t, device=d, slot=si))
                continue
            over = [j for j in lanes if j >= st.slot_width[si]]
            if over:
                diags.append(_err(
                    "gate/lane-overflow",
                    f"round {t}: device {d} scatters lane {over[0]} but "
                    f"its receive slot {si} ships only "
                    f"{st.slot_width[si]} lanes",
                    round=t, device=d, slot=si))

    # ---- arena bounds ---------------------------------------------------
    def _bounds(tab, lh_mask, what):
        bad = (tab < 0) | (tab >= st.arena_blocks)
        bad |= lh_mask & (tab >= st.n_ainv)
        idx = np.argwhere(bad)
        if len(idx):
            t, d = int(idx[0][0]), int(idx[0][1])
            diags.append(_err(
                "arena/out-of-bounds",
                f"{what} table holds {len(idx)} out-of-range "
                f"address(es) — first at round {t}, device {d}",
                round=t, device=d))

    _bounds(st.scatter, np.zeros_like(st.scatter, bool), "scatter")
    _bounds(st.lscatter, np.zeros_like(st.lscatter, bool), "lscatter")
    _bounds(st.gather, st.glh, "gather")
    _bounds(st.lgather, st.lglh, "lgather")
    if st.nlev and ((st.comp_level < 0) | (st.comp_level >= st.nlev)).any():
        diags.append(_err(
            "arena/out-of-bounds",
            f"comp_level indexes outside [0, {st.nlev})"))

    # ---- lane metadata cross-check -------------------------------------
    if st.lane_edges:
        for t in range(min(st.nrounds, len(st.lane_edges))):
            meta: Dict[Tuple[int, int], int] = defaultdict(int)
            for (s, d, _kind, _lv, _nb) in st.lane_edges[t]:
                meta[(s, d)] += 1
            got: Dict[Tuple[int, int], int] = defaultdict(int)
            for d in range(P):
                si = int(st.recv_slot[t, d])
                if si < 0 or si >= st.nslots or d not in src_of[si]:
                    continue
                n = sum(1 for j in range(st.W)
                        if int(st.scatter[t, d, j]) != st.trash)
                if n:
                    got[(src_of[si][d], d)] = n
            if meta != got:
                diags.append(_err(
                    "perm/edges-mismatch",
                    f"round {t}: decoded lane counts {dict(got)} "
                    f"disagree with the lane metadata {dict(meta)}",
                    round=t))

        if plan is not None:
            diags += _check_conservation(
                (e for edges in st.lane_edges for e in edges), plan)
    return diags


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def verify_artifact(obj, plan: CommPlan | None = None, *,
                    fanin_max: int = FANIN_MAX) -> List[PlanDiagnostic]:
    """Run the checker pipeline appropriate to one lowered artifact:
    a :class:`~.plan.CommPlan`, :class:`~.plan.ExecPlan`,
    :class:`~.plan.OverlappedExec`, or :class:`~.stream.StreamTables`.
    Passing the originating ``plan`` alongside an executor artifact adds
    the byte-conservation cross-check."""
    if isinstance(obj, CommPlan):
        return check_plan(obj)
    if isinstance(obj, OverlappedExec):
        return check_overlap(obj, plan, fanin_max=fanin_max)
    if isinstance(obj, StreamTables):
        return check_stream(obj, plan)
    if isinstance(obj, ExecPlan):
        return check_exec(obj)
    raise TypeError(
        f"verify_artifact cannot lint {type(obj).__name__} — expected "
        "CommPlan, ExecPlan, OverlappedExec, or StreamTables")


def verify_program(prog, *, fanin_max: int = FANIN_MAX
                   ) -> List[PlanDiagnostic]:
    """Lint everything a compiled ``pselinv_dist.PSelInvProgram``
    carries: the CommPlan IR plus whichever executor lowerings were
    compiled (level-serial tables, overlapped rounds, stream tables) —
    each cross-checked against the plan where applicable."""
    diags: List[PlanDiagnostic] = []
    plan = getattr(prog, "plan", None)
    if plan is not None:
        diags += check_plan(plan)
    ex = getattr(prog, "exec_plan", None)
    if ex is not None:
        diags += check_exec(ex)
    ov = getattr(prog, "overlap_plan", None)
    if ov is not None:
        diags += check_overlap(ov, plan, fanin_max=fanin_max)
    st = getattr(prog, "stream_tables", None)
    if st is not None:
        # conservation already covered through the overlapped rounds the
        # tables were lowered from — lint structure/gates/routing here
        diags += check_stream(st, None)
    return diags


def lint_report(diags: List[PlanDiagnostic]) -> str:
    """Human-readable multi-line report (errors first)."""
    order = sorted(diags, key=lambda d: (d.severity != "error", d.code))
    nerr = sum(1 for d in diags if d.severity == "error")
    nwarn = len(diags) - nerr
    head = f"PlanLint: {nerr} error(s), {nwarn} warning(s)"
    return "\n".join([head] + [f"  {d}" for d in order])


def enforce_verification(diags: List[PlanDiagnostic], mode: str = "error",
                         where: str = "plan") -> List[PlanDiagnostic]:
    """Apply a ``PlanOptions(verify=...)`` mode to a diagnostic list:
    ``"error"`` raises :class:`PlanVerificationError` when any
    ERROR-severity diagnostic is present (warnings still warn),
    ``"warn"`` downgrades everything to one ``warnings.warn`` summary,
    ``"off"`` is a no-op. Returns the diagnostics for chaining."""
    if mode not in VERIFY_MODES:
        raise ValueError(
            f"verify mode {mode!r} not in {VERIFY_MODES}")
    if mode == "off" or not diags:
        return diags
    errors = [d for d in diags if d.severity == "error"]
    if mode == "error" and errors:
        raise PlanVerificationError(
            f"PlanLint rejected {where}:\n{lint_report(diags)}", diags)
    warnings.warn(f"PlanLint flagged {where}:\n{lint_report(diags)}",
                  stacklevel=2)
    return diags
