"""Deterministic discrete-event simulator for PSelInv communication.

The container is CPU-only, so the paper's Edison (Cray XC30) wall-clock
experiments are reproduced with a processor-timeline simulation driven by
the CommPlan IR of `core.plan` — the *same* plan object (same trees, same
tags, same per-edge byte counts) that `core.pselinv_dist` compiles into
the executable ppermute sweep, so simulated bytes equal executed bytes by
construction (tested in tests/test_plan.py).

Two modes:

* :func:`volumes` — pure structural accounting of per-rank *outgoing*
  bytes per event kind (no timing). Reproduces Table 1 / Figs 4–7.
* :func:`simulate` — α-β timing with per-rank send/recv serialization, a
  node-hierarchical (intra-node vs inter-node) network, optional per-pair
  bandwidth jitter (run-to-run variance of §4.2), and elimination-tree
  pipelining with data-dependency gating. Reproduces Figs 8–9.

The timing model intentionally captures the three phenomena the paper
isolates: (1) flat-tree root serialization (p−1 sequential sends), (2)
binary-tree internal-node pile-up under concurrent collectives, (3) the
shifted tree smoothing that pile-up.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from .plan import (CommPlan, ExecPlan, OverlappedExec, PlanOp, build_plan,
                   peak_arena_blocks)
from .schedule import BYTES_PER_ELT, ComputeTask, Grid2D
from .symbolic import BlockStructure
from .trees import HYBRID_FLAT_MAX, TreeKind, cached_tree

__all__ = ["NetworkModel", "SimResult", "volumes", "volumes_from_plan",
           "volume_stats", "simulate", "RoundSchedule",
           "round_schedule_from_exec", "round_schedule_from_overlap",
           "round_schedule_from_stream",
           "round_schedule_of", "simulate_schedule",
           "executed_wire_bytes"]


@dataclass(frozen=True)
class NetworkModel:
    """Edison-like hierarchical network + compute rates."""
    gemm_gflops: float = 8.0          # per-core effective DGEMM rate
    alpha_intra: float = 1.0e-6      # latency, same node
    alpha_inter: float = 4.0e-6      # latency, across nodes
    bw_intra: float = 5.0e9          # B/s shared-memory copies
    bw_inter: float = 1.0e9          # B/s effective per-rank across nodes
    cores_per_node: int = 24
    jitter_sigma: float = 0.0        # lognormal σ on inter-node bandwidth
    placement_seed: int = 0

    def node_of(self, rank: int) -> int:
        return rank // self.cores_per_node


@dataclass
class SimResult:
    nranks: int
    total_time: float
    send_bytes: Dict[str, np.ndarray]       # kind -> per-rank outgoing bytes
    recv_bytes: Dict[str, np.ndarray]
    compute_time: np.ndarray                 # per-rank busy seconds
    comm_time: np.ndarray                    # per-rank link-busy seconds
    #: peak per-device working-buffer footprint in (b, b) blocks of the
    #: schedule that was timed (``plan.peak_arena_blocks``; 0 when the
    #: simulation was not built from a compiled schedule)
    peak_arena_blocks: int = 0

    def comm_to_comp_ratio(self) -> float:
        c = float(self.compute_time.sum())
        return float(self.comm_time.sum()) / max(c, 1e-30)


# ---------------------------------------------------------------------------
# structural volume accounting (Table 1, Figs 4-7)
# ---------------------------------------------------------------------------

def volumes_from_plan(plan: CommPlan
                      ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """Per-rank outgoing/incoming bytes by op kind, read off the IR's
    trees (``exec_only`` bookkeeping transfers are excluded — §4.1
    reports the four algorithmic collectives)."""
    size = plan.grid.size
    out: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(size))
    inc: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(size))
    for op in plan.ops:
        if op.exec_only:
            continue
        for src, kids in op.tree.children:
            nk = len(kids)
            out[op.kind][src] += nk * op.nbytes
            for k in kids:
                inc[op.kind][k] += op.nbytes
    return dict(out), dict(inc)


def volumes(bs: BlockStructure, grid: Grid2D, kind: TreeKind
            ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """Per-rank outgoing/incoming bytes by event kind.

    For broadcasts a rank's outgoing volume counts every tree edge it
    sources; for reductions the mirrored tree makes the same edge count as
    *incoming* at the combining node (paper §4.1 reports received volume
    for Row-Reduce)."""
    return volumes_from_plan(build_plan(bs, grid, kind))


def _msgs_vector(kind: TreeKind, root: int, receivers: Tuple[int, ...],
                 shift: int, n: int) -> np.ndarray:
    """messages-sent-per-rank vector for one tree, ranks in [0, n)."""
    if kind is TreeKind.HYBRID:
        # resolve to the concrete kind ``build_tree`` would pick at this
        # participant count — building a "hybrid" cached_tree here with
        # tag=0 would yield a shift-0 rotation that disagrees with
        # ``plan.tree_for``'s tag-derived one above the threshold
        kind = (TreeKind.FLAT if len(receivers) + 1 <= HYBRID_FLAT_MAX
                else TreeKind.SHIFTED)
    if kind is TreeKind.SHIFTED:
        from .trees import shifted_binary_tree
        tree = shifted_binary_tree(root, receivers, shift=shift)
    else:
        tree = cached_tree(kind.value, root, receivers, 0)
    v = np.zeros(n)
    for src, kids in tree.children:
        v[src] = len(kids)
    return v


def volumes_fast(bs: BlockStructure, grid: Grid2D, kind: TreeKind
                 ) -> Dict[str, np.ndarray]:
    """Vectorized volume accounting for the two collectives the paper
    tracks (§4.1). Exploits that for a fixed supernode K every col-bcast
    shares one participant-row set (and every row-reduce one
    participant-col set); only the mesh column/row, message size, and the
    shifted-tree rotation vary per event.

    Returns {"col-bcast": per-rank *outgoing* bytes,
             "row-reduce": per-rank *incoming* bytes} — matching the
    quantities of paper Table 1 and Fig. 7. Bit-identical to the
    :func:`volumes` slow path (tested)."""
    from .trees import stable_hash

    pr, pc = grid.pr, grid.pc
    w = bs.widths().astype(np.float64)
    out_cb = np.zeros(grid.size)
    inc_rr = np.zeros(grid.size)

    for K in range(bs.nsuper):
        C = bs.struct[K]
        if len(C) == 0:
            continue
        wk = float(w[K])
        krow, kcol = K % pr, K % pc

        # ---- col-bcast: root (krow, I%pc); receivers rows {J%pr} -------
        rows = np.unique(C % pr)
        recv_rows = tuple(int(r) for r in rows if r != krow)
        if recv_rows:
            nrecv = len(recv_rows)
            cols = (C % pc).astype(np.int64)
            nbytes = w[C] * wk * BYTES_PER_ELT
            if kind is TreeKind.SHIFTED or (
                    kind is TreeKind.HYBRID and nrecv + 1 > HYBRID_FLAT_MAX):
                cache = {}
                for i, I in enumerate(C):
                    root_rank = krow * pc + int(cols[i])
                    tag = (K << 20) ^ (int(I) << 1)
                    s = stable_hash(root_rank, tag) % nrecv
                    if s not in cache:
                        cache[s] = _msgs_vector(TreeKind.SHIFTED, krow,
                                                recv_rows, s, pr)
                    m = cache[s]
                    nz = np.nonzero(m)[0]
                    out_cb[nz * pc + cols[i]] += m[nz] * nbytes[i]
            else:
                # HYBRID below threshold resolves inside _msgs_vector —
                # the one place that mirrors build_tree's rule
                m = _msgs_vector(kind, krow, recv_rows, 0, pr)
                nz = np.nonzero(m)[0]
                for r in nz:
                    np.add.at(out_cb, r * pc + cols, m[r] * nbytes)

        # ---- row-reduce: root (J%pr, kcol); participant cols {I%pc} ----
        cols_u = np.unique(C % pc)
        recv_cols = tuple(int(c) for c in cols_u if c != kcol)
        if recv_cols:
            nrecv = len(recv_cols)
            rows_j = (C % pr).astype(np.int64)
            nbytes = w[C] * wk * BYTES_PER_ELT
            if kind is TreeKind.SHIFTED or (
                    kind is TreeKind.HYBRID and nrecv + 1 > HYBRID_FLAT_MAX):
                cache = {}
                for j, J in enumerate(C):
                    root_rank = int(rows_j[j]) * pc + kcol
                    tag = (K << 20) ^ (int(J) << 1) ^ 1
                    s = stable_hash(root_rank, tag) % nrecv
                    if s not in cache:
                        cache[s] = _msgs_vector(TreeKind.SHIFTED, kcol,
                                                recv_cols, s, pc)
                    m = cache[s]
                    nz = np.nonzero(m)[0]
                    inc_rr[rows_j[j] * pc + nz] += m[nz] * nbytes[j]
            else:
                m = _msgs_vector(kind, kcol, recv_cols, 0, pc)
                nz = np.nonzero(m)[0]
                for ccc in nz:
                    np.add.at(inc_rr, rows_j * pc + ccc, m[ccc] * nbytes)

    return {"col-bcast": out_cb, "row-reduce": inc_rr}


def volume_stats(v: np.ndarray) -> Dict[str, float]:
    active = v
    return {
        "min": float(active.min()),
        "max": float(active.max()),
        "median": float(np.median(active)),
        "mean": float(active.mean()),
        "std": float(active.std()),
    }


# ---------------------------------------------------------------------------
# timing simulation (Figs 8-9)
# ---------------------------------------------------------------------------

class _Net:
    def __init__(self, model: NetworkModel, nranks: int):
        self.m = model
        self.nranks = nranks
        self._jit: Dict[Tuple[int, int], float] = {}
        self._rng = np.random.default_rng(model.placement_seed)
        # sample per node-pair jitter lazily but deterministically
        self._pair_seed = int(self._rng.integers(1 << 31))

    def _jitter(self, na: int, nb: int) -> float:
        if self.m.jitter_sigma <= 0:
            return 1.0
        key = (min(na, nb), max(na, nb))
        if key not in self._jit:
            r = np.random.default_rng(
                (self._pair_seed, key[0], key[1]))
            self._jit[key] = float(
                np.exp(r.normal(0.0, self.m.jitter_sigma)))
        return self._jit[key]

    def edge_cost(self, u: int, v: int, nbytes: float) -> float:
        nu, nv = self.m.node_of(u), self.m.node_of(v)
        if nu == nv:
            return self.m.alpha_intra + nbytes / self.m.bw_intra
        bw = self.m.bw_inter * self._jitter(nu, nv)
        return self.m.alpha_inter + nbytes / bw


def simulate(bs: BlockStructure, grid: Grid2D, kind: TreeKind,
             model: NetworkModel | None = None) -> SimResult:
    model = model or NetworkModel()
    net = _Net(model, grid.size)
    P = grid.size
    flop_rate = model.gemm_gflops * 1e9

    busy = np.zeros(P)          # compute availability per rank
    link_out = np.zeros(P)      # send-port availability
    link_in = np.zeros(P)       # recv-port availability
    comp_acc = np.zeros(P)      # accumulated compute seconds
    comm_acc = np.zeros(P)      # accumulated send-port busy seconds

    send_bytes: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    recv_bytes: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))

    def run_bcast(ev: PlanOp, t_root: float) -> Dict[int, float]:
        """Propagate a broadcast; returns arrival time per rank."""
        tree = ev.tree
        arrive = {ev.root: t_root}
        order = [ev.root]
        kmap = tree.children_map()
        i = 0
        while i < len(order):
            u = order[i]; i += 1
            for c in kmap.get(u, ()):
                start = max(arrive[u], link_out[u], link_in[c])
                dt = net.edge_cost(u, c, ev.nbytes)
                link_out[u] = start + dt
                link_in[c] = start + dt
                comm_acc[u] += dt
                arrive[c] = start + dt
                send_bytes[ev.kind][u] += ev.nbytes
                recv_bytes[ev.kind][c] += ev.nbytes
                order.append(c)
        return arrive

    def run_reduce(ev: PlanOp, ready: Dict[int, float]) -> float:
        """Propagate a reduction (leaves -> root); returns root finish."""
        tree = ev.tree
        kmap = tree.children_map()

        def finish(u: int) -> float:
            t = ready.get(u, 0.0)
            for c in kmap.get(u, ()):
                tc = finish(c)
                start = max(tc, link_out[c], link_in[u])
                dt = net.edge_cost(c, u, ev.nbytes)
                link_out[c] = start + dt
                link_in[u] = start + dt
                comm_acc[c] += dt
                send_bytes[ev.kind][c] += ev.nbytes
                recv_bytes[ev.kind][u] += ev.nbytes
                t = max(t, start + dt)
            return t

        return finish(ev.root)

    # -- group the IR's ops/tasks by supernode ----------------------------
    plan = build_plan(bs, grid, kind)
    tasks = plan.tasks
    ev_by_sn: Dict[int, List[PlanOp]] = defaultdict(list)
    tk_by_sn: Dict[int, List[ComputeTask]] = defaultdict(list)
    for e in plan.ops:
        if not e.exec_only:
            ev_by_sn[e.supernode].append(e)
    for t in tasks:
        tk_by_sn[t.supernode].append(t)

    nb = bs.nsuper

    # -- phase 1 (forward): diag-bcast + trsm -----------------------------
    for K in range(nb):
        for ev in ev_by_sn[K]:
            if ev.kind != "diag-bcast":
                continue
            arr = run_bcast(ev, t_root=busy[ev.root])
            for t in tk_by_sn[K]:
                if t.kind != "trsm":
                    continue
                start = max(arr.get(t.rank, 0.0), busy[t.rank])
                dt = t.flops / flop_rate
                busy[t.rank] = start + dt
                comp_acc[t.rank] += dt

    # -- phase 2 (reverse): xfer, col-bcast, gemm, row-reduce, diag -------
    done = np.zeros(nb)
    for K in range(nb - 1, -1, -1):
        C = [int(i) for i in bs.struct[K]]
        t_dep = max((done[i] for i in C), default=0.0)

        evs = ev_by_sn[K]
        # xfer handoffs first (L̂ -> Û owner); data is L-side, no dep gate
        xfer_done: Dict[int, float] = {}
        for ev in evs:
            if ev.kind != "xfer":
                continue
            dst = [r for r in ev.participants if r != ev.root][0]
            start = max(link_out[ev.root], link_in[dst])
            dt = net.edge_cost(ev.root, dst, ev.nbytes)
            link_out[ev.root] = start + dt
            link_in[dst] = start + dt
            comm_acc[ev.root] += dt
            send_bytes[ev.kind][ev.root] += ev.nbytes
            recv_bytes[ev.kind][dst] += ev.nbytes
            xfer_done[ev.consumes if ev.consumes >= 0 else ev.tag] = start + dt

        # col-bcasts: root holds Û(K,I); GEMMs gate on done[I] (A⁻¹ dep)
        gemm_ready: Dict[int, float] = defaultdict(float)
        gemm_last: Dict[int, float] = defaultdict(float)
        for ev in evs:
            if ev.kind != "col-bcast":
                continue
            arr = run_bcast(ev, t_root=link_in[ev.root])
            dep_I = done[ev.consumes] if ev.consumes >= 0 else 0.0
            for r, t_arr in arr.items():
                gemm_ready[r] = max(gemm_ready[r], t_arr, dep_I)
        for t in tk_by_sn[K]:
            if t.kind != "gemm":
                continue
            start = max(gemm_ready[t.rank], busy[t.rank], t_dep)
            dt = t.flops / flop_rate
            busy[t.rank] = start + dt
            comp_acc[t.rank] += dt
            gemm_last[t.rank] = busy[t.rank]

        # row-reduces: leaf contribution ready after that rank's GEMMs
        t_done = t_dep
        for ev in evs:
            if ev.kind != "row-reduce":
                continue
            ready = {r: max(gemm_last[r], busy[r] * 0.0) for r in ev.participants}
            t_done = max(t_done, run_reduce(ev, ready))

        for t in tk_by_sn[K]:
            if t.kind != "diag":
                continue
            start = max(t_done, busy[t.rank])
            dt = t.flops / flop_rate
            busy[t.rank] = start + dt
            comp_acc[t.rank] += dt
            t_done = max(t_done, busy[t.rank])

        done[K] = t_done

    total = float(max(busy.max(), link_out.max(), link_in.max(),
                      done.max() if nb else 0.0))
    return SimResult(
        nranks=P, total_time=total,
        send_bytes=dict(send_bytes), recv_bytes=dict(recv_bytes),
        compute_time=comp_acc, comm_time=comm_acc)


# ---------------------------------------------------------------------------
# executed-schedule timing: account the *compiled* round stream
# ---------------------------------------------------------------------------

@dataclass
class RoundSchedule:
    """A compiled sweep flattened to its executed timeline: alternating
    ``("comm", [(src, dst, kind, nbytes), ...])`` ppermute rounds (every
    transfer of one round ships in the same barriered permute; coalesced
    lanes of a pair appear as several tuples) and ``("comp", flops)``
    round boundaries (per-rank flops fired between two rounds). Built
    from the same :class:`~.plan.ExecPlan` / :class:`~.plan.OverlappedExec`
    the device program runs, so the time :func:`simulate_schedule` reports
    is the time of the schedule that *executes* — the overlapped stream
    is accounted round for round, not approximated per supernode.
    ``peak_arena_blocks`` carries the compiled schedule's per-device
    peak block footprint (``plan.peak_arena_blocks``) so the serial /
    overlapped comparison covers the memory axis, not just time —
    regression guard for the arena slot recycling."""
    nranks: int
    events: List[Tuple[str, object]]
    peak_arena_blocks: int = 0


def _level_task_flops(plan: CommPlan, Ks, kind: str) -> np.ndarray:
    flops = np.zeros(plan.grid.size)
    sel = set(int(k) for k in Ks)
    for t in plan.tasks:
        if t.kind == kind and t.supernode in sel:
            flops[t.rank] += t.flops
    return flops


def round_schedule_from_exec(ex: ExecPlan, plan: CommPlan) -> RoundSchedule:
    """Flatten the level-serial executor: each level's phases in order,
    with the level GEMM at the bcast→reduce boundary and the diagonal
    update after the diag-reduce (the A/B baseline timeline)."""
    events: List[Tuple[str, object]] = []

    def comm(rounds, kind):
        for rnd in rounds:
            events.append(("comm", [(s, d, kind, nb_)
                                    for (s, d, _ss, _ds, nb_) in rnd.edges]))

    for lv in ex.levels:
        comm(lv.xfer_in, "xfer")
        comm(lv.bcast, "col-bcast")
        events.append(("comp", _level_task_flops(plan, lv.Ks, "gemm")))
        comm(lv.reduce, "row-reduce")
        comm(lv.xfer_out, "xfer-out")
        comm(lv.diag_reduce, "diag-reduce")
        events.append(("comp", _level_task_flops(plan, lv.Ks, "diag")))
    return RoundSchedule(nranks=ex.pr * ex.pc, events=events,
                         peak_arena_blocks=peak_arena_blocks(ex))


def _overlap_event_groups(ov: OverlappedExec, plan: CommPlan
                          ) -> List[List[Tuple[str, object]]]:
    """The overlapped timeline grouped per executed round: entry ``t``
    (for ``t < nrounds``) holds boundary ``t``'s compute events followed
    by round ``t``'s coalesced comm event; the final entry holds the
    trailing boundary compute. Flattening the groups in order IS the
    :func:`round_schedule_from_overlap` event list (one definition) —
    the grouping exists so ``obs.rounds`` can join *measured* per-round
    walls against the α-β cost of exactly the same executed round."""
    groups: List[List[Tuple[str, object]]] = []
    for t in range(len(ov.rounds) + 1):
        g: List[Tuple[str, object]] = []
        for op in ov.compute_at[t]:
            if op.kind in ("gemm", "diagw"):
                kind = "gemm" if op.kind == "gemm" else "diag"
                g.append(("comp", _level_task_flops(
                    plan, ov.levels[op.level].Ks, kind)))
        if t < len(ov.rounds):
            rnd = ov.rounds[t]
            if rnd.perm:
                g.append(("comm", [(s, d, kind, nb_)
                                   for (s, d, kind, _lv, nb_)
                                   in rnd.edges]))
        groups.append(g)
    return groups


def round_schedule_from_overlap(ov: OverlappedExec,
                                plan: CommPlan) -> RoundSchedule:
    """Flatten the overlapped executor: the global coalesced round
    sequence with compute ops at the boundaries the dependence scheduler
    pinned them to (GEMM flops at ``gemm`` boundaries, diagonal flops at
    ``diagw``)."""
    events = [e for g in _overlap_event_groups(ov, plan) for e in g]
    return RoundSchedule(nranks=ov.pr * ov.pc, events=events,
                         peak_arena_blocks=peak_arena_blocks(ov))


def _event_seconds(net: "_Net", flop_rate: float, what: str,
                   payload) -> float:
    """Seconds one timeline event costs under the executed BSP
    semantics — the same charging rule :func:`simulate_schedule`
    applies: a compute boundary completes when its busiest rank does, a
    ppermute round when its slowest pair does (coalesced lanes of one
    pair share the latency and serialize their bytes)."""
    if what == "comp":
        dt = payload / flop_rate
        return float(dt.max()) if len(dt) else 0.0
    pair_bytes: Dict[Tuple[int, int], float] = defaultdict(float)
    for (s, d, _kind, nb_) in payload:
        pair_bytes[(s, d)] += nb_
    return max((net.edge_cost(s, d, nb_)
                for (s, d), nb_ in pair_bytes.items()), default=0.0)


def simulated_round_times(prog_or_engine,
                          model: NetworkModel | None = None) -> np.ndarray:
    """Per-round α-β times of the executed overlapped stream, the
    simulated side of the measured-vs-simulated residual join: entry
    ``t < nrounds`` covers boundary ``t``'s compute plus round ``t``'s
    coalesced permute, entry ``nrounds`` the trailing compute — the same
    cut :func:`~.pselinv_dist.make_sweep_segments` applies to the device
    program, so ``measured[t] - simulated[t]`` is a like-for-like
    residual. Sums to ``simulate_schedule(...).total_time`` of the
    overlapped schedule (tested). Accepts a program or engine; stream
    programs are profiled through the overlapped schedule they were
    lowered from (round-for-round identical, see
    :func:`round_schedule_from_stream`)."""
    prog = getattr(prog_or_engine, "program", prog_or_engine)
    ov = getattr(prog, "overlap_plan", None)
    if ov is None:
        raise ValueError("per-round simulation needs an overlapped "
                         "schedule — build with PlanOptions(overlap=True) "
                         "or PlanOptions(stream=True)")
    model = model or NetworkModel()
    net = _Net(model, ov.pr * ov.pc)
    flop_rate = model.gemm_gflops * 1e9
    return np.array([sum(_event_seconds(net, flop_rate, what, payload)
                         for what, payload in g)
                     for g in _overlap_event_groups(ov, prog.plan)])


def round_schedule_from_stream(st, plan: CommPlan) -> RoundSchedule:
    """Flatten the uniform round-stream tables (``core/stream.py``'s
    :class:`~.stream.StreamTables`) to the executed timeline: real comm
    lanes per round (the stream's padded ring-shift lanes ship garbage
    into the trash block and are not algorithmic traffic — the same
    accounting rule the coalesced overlapped rounds already use for
    their padded lanes) and GEMM/diagonal flops at the boundaries the
    phase flags fire them. The stream replays the overlapped
    :class:`~.plan.GlobalRound` list round-for-round, so this equals
    :func:`round_schedule_from_overlap` of the same plan (tested) —
    derived from the stream's own tables/metadata, not from the object
    it was lowered from, so simulated bytes stay pinned to what
    executes."""
    from .stream import COMP_DIAGW, COMP_GEMM

    events: List[Tuple[str, object]] = []
    for t in range(st.steps):
        for j in range(st.comp_kind.shape[1]):
            k = int(st.comp_kind[t, j])
            if k in (COMP_GEMM, COMP_DIAGW):
                Ks = st.level_Ks[int(st.comp_level[t, j])]
                events.append(("comp", _level_task_flops(
                    plan, Ks, "gemm" if k == COMP_GEMM else "diag")))
        if t < st.nrounds and st.lane_edges[t]:
            events.append(("comm", [(s, d, kind, nb_)
                                    for (s, d, kind, _lv, nb_)
                                    in st.lane_edges[t]]))
    return RoundSchedule(nranks=st.pr * st.pc, events=events,
                         peak_arena_blocks=st.peak_blocks)


def executed_wire_bytes(prog_or_engine) -> float:
    """Physical permute traffic of one compiled sweep, in bytes — what
    the executor's ``ppermute`` ops actually ship, padding included
    (unlike the algorithmic lane bytes of :class:`RoundSchedule`, which
    never counted coalescing padding).

    For the uniform round stream this is the *independent* lens of the
    simulated-equals-executed wire invariant: the per-round active slot
    sets are re-derived from ``recv_slot`` (which devices receive on
    which slot), cross-checked against the ``slot_active`` gate table
    the device program branches on (through PlanLint's
    ``verify.check_stream_gates`` — the one shared implementation), and
    only then priced — so a gate table that drifted from the receive
    table fails loudly instead of producing an agreeing-but-wrong byte
    count. Must equal ``stream.stream_wire_bytes`` of the same tables
    (tested, and asserted against the unrolled overlapped executor's
    wire in the bench). For an unrolled overlapped program it prices
    each round's single static permute (``len(perm) × width``
    blocks)."""
    prog = getattr(prog_or_engine, "program", prog_or_engine)
    b = prog.b
    st = getattr(prog, "stream_tables", None)
    if st is not None:
        from .verify import check_stream_gates
        bad = check_stream_gates(st)
        if bad:
            raise ValueError(
                "stream gate tables drifted from the receive tables:\n"
                + "\n".join(f"  {d}" for d in bad))
        blocks = 0
        for t in range(st.steps):
            gated = {si for si in range(st.nslots)
                     if st.slot_active[t, si]}
            blocks += sum(len(st.slot_perm[si]) * st.slot_width[si]
                          for si in gated)
        return float(blocks) * b * b * BYTES_PER_ELT
    ov = getattr(prog, "overlap_plan", None)
    if ov is not None:
        blocks = sum(len(rnd.perm) * rnd.width for rnd in ov.rounds)
        return float(blocks) * b * b * BYTES_PER_ELT
    raise ValueError(
        "executed wire accounting covers the overlapped and stream "
        "lowerings — compile with PlanOptions(overlap=True) or "
        "PlanOptions(stream=True)")


def round_schedule_of(prog_or_engine) -> RoundSchedule:
    """Flatten a compiled program to its executed timeline, deriving
    everything from the object itself: accepts a
    ``pselinv_dist.PSelInvProgram`` (or anything carrying one under
    ``.program``, e.g. a :class:`~.engine.PSelInvEngine`) and builds the
    :class:`RoundSchedule` from whichever lowering it compiled — no more
    hand-passing the (exec, plan) pair the program already owns."""
    prog = getattr(prog_or_engine, "program", prog_or_engine)
    if getattr(prog, "stream_tables", None) is not None:
        return round_schedule_from_stream(prog.stream_tables, prog.plan)
    if getattr(prog, "overlap_plan", None) is not None:
        return round_schedule_from_overlap(prog.overlap_plan, prog.plan)
    if getattr(prog, "exec_plan", None) is not None:
        return round_schedule_from_exec(prog.exec_plan, prog.plan)
    raise ValueError(
        "program has no compiled IR lowering (exec_plan/overlap_plan) — "
        "build it through build_program()/PSelInvEngine.analyze(), not "
        "the legacy unrolled path")


def simulate_schedule(sched,
                      model: NetworkModel | None = None) -> SimResult:
    """α-β timing of a compiled round stream under the executed BSP
    semantics: a ppermute round completes when its slowest pair does
    (coalesced lanes of one pair share the latency and serialize their
    bytes), a compute boundary when its busiest rank does. Comparing the
    level-serial and the overlapped :class:`RoundSchedule` of one plan
    quantifies the cross-level overlap win under the same network; the
    result also carries the schedule's ``peak_arena_blocks`` so the
    comparison covers per-device memory alongside time.

    ``sched`` may be a ready :class:`RoundSchedule`, or a compiled
    program / engine — anything :func:`round_schedule_of` accepts — in
    which case the timeline is derived here."""
    if not isinstance(sched, RoundSchedule):
        sched = round_schedule_of(sched)
    model = model or NetworkModel()
    P = sched.nranks
    net = _Net(model, P)
    flop_rate = model.gemm_gflops * 1e9

    T = 0.0
    comp_acc = np.zeros(P)
    comm_acc = np.zeros(P)
    send_bytes: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    recv_bytes: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))

    for what, payload in sched.events:
        if what == "comp":
            dt = payload / flop_rate
            T += float(dt.max()) if len(dt) else 0.0
            comp_acc += dt
            continue
        pair_bytes: Dict[Tuple[int, int], float] = defaultdict(float)
        for (s, d, kind, nb_) in payload:
            pair_bytes[(s, d)] += nb_
            send_bytes[kind][s] += nb_
            recv_bytes[kind][d] += nb_
        round_dt = 0.0
        for (s, d), nb_ in pair_bytes.items():
            dt = net.edge_cost(s, d, nb_)
            comm_acc[s] += dt
            round_dt = max(round_dt, dt)
        T += round_dt
    return SimResult(
        nranks=P, total_time=T,
        send_bytes=dict(send_bytes), recv_bytes=dict(recv_bytes),
        compute_time=comp_acc, comm_time=comm_acc,
        peak_arena_blocks=sched.peak_arena_blocks)
