"""The one HLO / StableHLO / jaxpr parsing code path of the repo.

Three compiled-artifact layers carry the program XLA actually runs, and
two consumers read them: ``launch/dryrun.py`` prices multi-pod
collective traffic off the optimized HLO, and ``core/hlo_verify.py``
(HloLint) cross-checks every compiled sweep against the CommPlan it was
lowered from. Both used to need their own text scraping; this module is
the shared parser so the regexes, the defining-line-vs-operand-use
guard, and the while-loop trip-count propagation exist exactly once.

Layers and what each yields:

* **optimized HLO** (``compiled.as_text()``): named computations with
  ``while(...), condition=%c, body=%b`` edges — trip counts are read
  from the loop-condition constants and propagated through nesting
  (:func:`computation_multipliers`, the dryrun accounting), optionally
  through ``conditional``/``fusion``/``call`` edges too (multiplier
  inherited, needed to reach the gated comm slots the stream executor
  hides two regions deep). :func:`parse_collectives` extracts every
  *defining* collective op with its ``source_target_pairs``, result
  shape/dtype and enclosing-computation multiplier.
* **StableHLO** (``lowered.as_text()``): loops are inline
  ``stablehlo.while`` regions, not named computations — membership is
  tracked by brace depth and trip counts read from the loop-condition
  ``stablehlo.constant``/``compare LT`` idiom the fori_loop lowering
  emits (:func:`parse_collectives` again; it sniffs the dialect).
* **jaxpr** (``traced.jaxpr``): walked structurally, not as text —
  ``ppermute`` equations carry their ``perm`` parameter verbatim, and
  a ``scan``'s ``length`` parameter is the exact trip count the
  fori_loop stream body runs under (:func:`jaxpr_collectives`).

``collective_bytes`` keeps the exact dryrun semantics (while-edge
multipliers only) — ``launch/dryrun.py`` re-exports it unchanged.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

__all__ = [
    "DTYPE_BYTES", "CollectiveOp", "ConvertOp", "JaxprCollective",
    "split_computations", "computation_multipliers", "collective_bytes",
    "parse_collectives", "parse_converts", "host_transfer_lines",
    "jaxpr_collectives", "jaxpr_converts", "is_stablehlo",
]

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)\b")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

#: the stablehlo op mnemonics of the same five collectives, normalized
#: to the HLO dash spelling so consumers match on one vocabulary
_STABLEHLO_COLL = {
    "all_gather": "all-gather", "all_reduce": "all-reduce",
    "reduce_scatter": "reduce-scatter", "all_to_all": "all-to-all",
    "collective_permute": "collective-permute",
}
_STABLEHLO_COLL_RE = re.compile(
    r"stablehlo\.(all_gather|all_reduce|reduce_scatter|all_to_all"
    r"|collective_permute)\b")


def is_stablehlo(txt: str) -> bool:
    """Dialect sniff: optimized HLO is the classic ``HloModule`` text
    format; anything else is treated as MLIR StableHLO."""
    return not txt.lstrip()[:400].startswith("HloModule")


# ---------------------------------------------------------------------------
# optimized HLO: computations, trip-count multipliers, byte pricing
# ---------------------------------------------------------------------------

def split_computations(txt: str) -> Dict[str, str]:
    """Top-level ``%name (args) -> ty {`` blocks of an HLO module."""
    blocks: Dict[str, list] = {}
    cur = None
    for line in txt.splitlines():
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([^\s(]+)\s*\(", line)
            cur = m.group(1) if m else None
            if cur:
                blocks[cur] = []
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            blocks[cur].append(line)
    return {k: "\n".join(v) for k, v in blocks.items()}


_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([^\s,]+), body=%?([^\s,]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_BRANCH_RE = re.compile(
    r"(?:true|false)_computation=%?([^\s,}]+)")
_CALLS_RE = re.compile(r"\b(?:calls|to_apply)=%?([^\s,}]+)")


def computation_multipliers(txt: str, *,
                            through_calls: bool = False) -> Dict[str, int]:
    """Execution-count multiplier per HLO computation: while-loop bodies
    execute trip-count times (xla's cost/temp analyses count them once —
    verified; scan bodies would otherwise be undercounted). Trip count is
    read from the loop-condition constant; nested loops multiply.

    ``through_calls=True`` additionally propagates the parent's
    multiplier through ``conditional`` branch computations and
    ``fusion``/``call`` callee edges (×1 — executed at most once per
    parent execution). HloLint needs this to see the stream executor's
    gated comm slots, which live in conditional branches inside the
    while body; the dryrun byte pricing keeps the historical
    while-edges-only behavior."""
    blocks = split_computations(txt)
    mult: Dict[str, int] = {name: 1 for name in blocks}

    edges = []  # (parent, callee, trip)
    for parent, body_txt in blocks.items():
        for cond, body in _WHILE_RE.findall(body_txt):
            consts = [int(c) for c in _CONST_RE.findall(blocks.get(cond, ""))]
            trip = max(consts) if consts else 1
            edges.append((parent, body, trip))
        if through_calls:
            for line in body_txt.splitlines():
                for blob in _BRANCHES_RE.findall(line):
                    for br in blob.split(","):
                        br = br.strip().lstrip("%")
                        if br:
                            edges.append((parent, br, 1))
                for br in _TF_BRANCH_RE.findall(line):
                    edges.append((parent, br, 1))
                for callee in _CALLS_RE.findall(line):
                    edges.append((parent, callee, 1))

    changed = True
    while changed:                      # propagate through nesting
        changed = False
        for parent, body, trip in edges:
            want = mult.get(parent, 1) * trip
            if mult.get(body, 1) != want:
                mult[body] = want
                changed = True
    return mult


def _line_bytes(line: str, opname: str) -> int:
    lhs_rhs = line.split("=", 1)[1]
    head = lhs_rhs[:lhs_rhs.find(opname)]
    if "%" in head:
        # ``opname`` first appears inside the operand list (e.g.
        # ``%add = f32[...] add(... %all-reduce.1)``): this line *uses* a
        # collective result, it does not define one — don't count it.
        return 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * DTYPE_BYTES[dt]
    return nbytes


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic from the optimized HLO: sum of
    result-shape bytes of every collective op, weighted by the execution
    count of its enclosing computation (while-loop bodies × trip count).
    all-gather/all-to-all results count the full gathered buffer — an
    upper bound within (n-1)/n of wire traffic."""
    mult = computation_multipliers(hlo_text)
    blocks = split_computations(hlo_text)
    out: Dict[str, float] = {}
    for name, body in blocks.items():
        k = mult.get(name, 1)
        for line in body.splitlines():
            line = line.strip()
            m = _COLL_RE.search(line)
            if not m or "=" not in line:
                continue
            nbytes = _line_bytes(line, m.group(1))
            if nbytes:
                out[m.group(1)] = out.get(m.group(1), 0.0) + float(nbytes) * k
    return out


# ---------------------------------------------------------------------------
# collective op extraction (both text dialects)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CollectiveOp:
    """One *defining* collective op of a lowered/compiled program:
    ``op`` in the HLO dash vocabulary, its ``source_target_pairs``
    (collective-permute only, else None), the result tensor dims and
    dtype, the enclosing computation (``""`` for inline StableHLO
    regions), the execution-count ``multiplier`` of that context
    (while trip counts, nesting multiplied), and the 1-based source
    line in the text it was parsed from."""
    op: str
    pairs: Optional[Tuple[Tuple[int, int], ...]]
    dims: Tuple[int, ...]
    dtype: str
    computation: str
    multiplier: int
    line: int


_HLO_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}")
_SH_PAIRS_RE = re.compile(r"source_target_pairs\s*=\s*dense<(.*?)>")
_SH_RESULT_RE = re.compile(r"->\s*tensor<([0-9x]*)([a-z0-9]+)>\s*$")
_SH_TRIP_CONST_RE = re.compile(
    r"stablehlo\.constant dense<(\d+)>\s*:\s*tensor<i(?:32|64)>")


def _parse_hlo_pairs(line: str) -> Optional[Tuple[Tuple[int, int], ...]]:
    m = _HLO_PAIRS_RE.search(line)
    if not m:
        return None
    body = m.group(1) + "}"          # restore the inner closing brace
    return tuple((int(a), int(b)) for a, b in
                 re.findall(r"\{(\d+),\s*(\d+)\}", body))


def _parse_sh_pairs(line: str) -> Optional[Tuple[Tuple[int, int], ...]]:
    m = _SH_PAIRS_RE.search(line)
    if not m:
        return None
    return tuple((int(a), int(b)) for a, b in
                 re.findall(r"\[(\d+),\s*(\d+)\]", m.group(1)))


def _parse_hlo_result(line: str) -> Tuple[Tuple[int, ...], str]:
    lhs_rhs = line.split("=", 1)[1]
    coll = _COLL_RE.search(lhs_rhs)
    head = lhs_rhs[:coll.start()] if coll else lhs_rhs
    m = _SHAPE_RE.search(head)
    if not m:
        return (), ""
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return dims, m.group(1)


def _parse_sh_result(line: str) -> Tuple[Tuple[int, ...], str]:
    m = _SH_RESULT_RE.search(line.rstrip())
    if not m:
        return (), ""
    dims = tuple(int(d) for d in m.group(1).split("x") if d)
    return dims, m.group(2)


def _parse_hlo_collectives(txt: str, *, through_calls: bool
                           ) -> List[CollectiveOp]:
    mult = computation_multipliers(txt, through_calls=through_calls)
    out: List[CollectiveOp] = []
    cur = None
    for lineno, line in enumerate(txt.splitlines(), 1):
        if line and not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"(?:ENTRY\s+)?%?([^\s(]+)\s*\(", line)
            cur = m.group(1) if m else None
            continue
        if line.startswith("}"):
            cur = None
            continue
        ls = line.strip()
        m = _COLL_RE.search(ls)
        if not m or "=" not in ls:
            continue
        op = m.group(1)
        # defining-line guard, same as _line_bytes: an operand reference
        # like ``add(... %collective-permute.1)`` is a *use*
        lhs_rhs = ls.split("=", 1)[1]
        if "%" in lhs_rhs[:lhs_rhs.find(op)]:
            continue
        dims, dtype = _parse_hlo_result(ls)
        out.append(CollectiveOp(
            op=op, pairs=_parse_hlo_pairs(ls) if
            op == "collective-permute" else None,
            dims=dims, dtype=dtype, computation=cur or "",
            multiplier=mult.get(cur or "", 1), line=lineno))
    return out


_SH_FUNC_RE = re.compile(r"func\.func\s+(?:[a-z]+\s+)?@([\w$.\-]+)")
_SH_CALL_RE = re.compile(r"(?:func\.call|call)\s+@([\w$.\-]+)")


def _parse_sh_collectives(txt: str) -> List[CollectiveOp]:
    """StableHLO: loops are inline ``stablehlo.while`` regions, but a
    region body is often just a ``func.call`` to an out-of-line
    ``func.func`` (the fori_loop lowering does exactly this) — so loop
    membership needs both brace-depth region tracking *and* call-graph
    multiplier propagation. A while's trip count is the loop-condition
    integer constant (the ``i < steps`` idiom) found before the
    condition region's ``compare``."""
    lines = txt.splitlines()
    # pass 1: per-function local loop context — collect collective ops
    # and call edges with the *local* multiplier at their site
    ops: List[Tuple[str, CollectiveOp]] = []    # (func, op @ local mult)
    edges: List[Tuple[str, str, int]] = []      # (caller, callee, mult)
    func = ""
    depth = 0
    loops: List[Tuple[int, int]] = []           # (entry_depth, trip)
    pending_while = None
    for lineno, line in enumerate(lines, 1):
        fm = _SH_FUNC_RE.search(line)
        if fm:
            func = fm.group(1)
            loops, pending_while = [], None
        if "stablehlo.while" in line:
            trip = 1
            for look in lines[lineno:lineno + 20]:
                c = _SH_TRIP_CONST_RE.search(look)
                if c:
                    trip = int(c.group(1))
                if "stablehlo.compare" in look:
                    break
            pending_while = (depth, trip)
        local = 1
        for _, t in loops:
            local *= t
        cm = _SH_CALL_RE.search(line)
        if cm:
            edges.append((func, cm.group(1), local))
        m = _STABLEHLO_COLL_RE.search(line)
        if m:
            dims, dtype = _parse_sh_result(line)
            op = _STABLEHLO_COLL[m.group(1)]
            ops.append((func, CollectiveOp(
                op=op, pairs=_parse_sh_pairs(line) if
                op == "collective-permute" else None,
                dims=dims, dtype=dtype, computation=func,
                multiplier=local, line=lineno)))
        depth += line.count("{") - line.count("}")
        if pending_while is not None and depth > pending_while[0]:
            loops.append(pending_while)
            pending_while = None
        while loops and depth <= loops[-1][0]:
            loops.pop()
    # pass 2: propagate function execution counts through call edges
    fmult: Dict[str, int] = {}
    fmult["main"] = 1
    changed = True
    while changed:
        changed = False
        for caller, callee, k in edges:
            want = fmult.get(caller, 1) * k
            if fmult.get(callee, 1) != want:
                fmult[callee] = want
                changed = True
    return [CollectiveOp(op=c.op, pairs=c.pairs, dims=c.dims,
                         dtype=c.dtype, computation=c.computation,
                         multiplier=c.multiplier * fmult.get(f, 1),
                         line=c.line)
            for f, c in ops]


def parse_collectives(txt: str, *, through_calls: bool = True
                      ) -> List[CollectiveOp]:
    """Every defining collective op of an HLO or StableHLO module text,
    with source-target pairs, result shape and loop-context multiplier
    (dialect auto-detected). ``through_calls`` (HLO dialect only)
    extends trip-count propagation through conditional/fusion/call
    edges so ops inside gated branches inherit the loop multiplier."""
    if is_stablehlo(txt):
        return _parse_sh_collectives(txt)
    return _parse_hlo_collectives(txt, through_calls=through_calls)


# ---------------------------------------------------------------------------
# converts and host transfers (hygiene inputs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvertOp:
    """One dtype conversion: operand dtype → result dtype."""
    src: str
    dst: str
    line: int


_SH_CONVERT_RE = re.compile(
    r"stablehlo\.convert\b.*\(tensor<(?:[0-9x]*)([a-z0-9]+)>\)\s*->"
    r"\s*tensor<(?:[0-9x]*)([a-z0-9]+)>")
_HLO_CONVERT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[[0-9,]*\][^ ]*\s+convert\(\s*([a-z0-9]+)\[")


def parse_converts(txt: str) -> List[ConvertOp]:
    """Every dtype-convert op of an HLO or StableHLO module text."""
    out: List[ConvertOp] = []
    sh = is_stablehlo(txt)
    for lineno, line in enumerate(txt.splitlines(), 1):
        ls = line.strip()
        if sh:
            m = _SH_CONVERT_RE.search(ls)
            if m:
                out.append(ConvertOp(src=m.group(1), dst=m.group(2),
                                     line=lineno))
        else:
            m = _HLO_CONVERT_RE.search(ls)
            if m:
                out.append(ConvertOp(src=m.group(2), dst=m.group(1),
                                     line=lineno))
    return out


#: op / custom-call markers that move data off the device on the hot
#: path (the ``@Sharding`` annotation custom-calls are benign and
#: excluded)
_HOST_XFER_RE = re.compile(
    r"\b(infeed|outfeed|send|recv|send-done|recv-done)\(|"
    r"custom[-_]call.*(?:MoveToHost|MoveToDevice"
    r"|annotate_device_placement)|"
    r"stablehlo\.(infeed|outfeed|send|recv)\b")


def host_transfer_lines(txt: str) -> List[Tuple[int, str]]:
    """(line number, stripped line) of every host-transfer op."""
    out = []
    for lineno, line in enumerate(txt.splitlines(), 1):
        ls = line.strip()
        if "=" not in ls and "stablehlo" not in ls:
            continue
        if _HOST_XFER_RE.search(ls):
            out.append((lineno, ls))
    return out


# ---------------------------------------------------------------------------
# jaxpr layer: structural walk (no text parsing)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class JaxprCollective:
    """One collective equation of a traced program: the primitive name,
    its ``perm`` parameter (ppermute only), and the product of enclosing
    loop trip counts (``scan`` lengths; an unbounded ``while``
    contributes ``None`` → trip is None)."""
    prim: str
    perm: Optional[Tuple[Tuple[int, int], ...]]
    trip: Optional[int]


_COLLECTIVE_PRIMS = {
    "ppermute", "pshuffle", "psum", "pmax", "pmin", "all_gather",
    "all_to_all", "reduce_scatter", "psum_scatter",
}


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for sub in vals:
            if hasattr(sub, "eqns"):               # raw Jaxpr
                yield sub
            elif hasattr(sub, "jaxpr") and hasattr(
                    getattr(sub, "jaxpr"), "eqns"):  # ClosedJaxpr
                yield sub.jaxpr


def jaxpr_collectives(closed_jaxpr) -> List[JaxprCollective]:
    """Walk a ``ClosedJaxpr`` structurally and return every collective
    equation with its loop-trip context. A ``scan``'s exact trip count
    is its ``length`` parameter; a ``while``'s is unknowable statically
    (trip → None)."""
    out: List[JaxprCollective] = []

    def walk(jaxpr, trip):
        for eqn in jaxpr.eqns:
            nm = eqn.primitive.name
            if nm in _COLLECTIVE_PRIMS:
                perm = eqn.params.get("perm")
                out.append(JaxprCollective(
                    prim=nm,
                    perm=tuple((int(s), int(d)) for s, d in perm)
                    if perm is not None else None,
                    trip=trip))
            sub_trip = trip
            if nm == "scan":
                n = int(eqn.params.get("length", 1))
                sub_trip = None if trip is None else trip * n
            elif nm == "while":
                sub_trip = None
            for sub in _sub_jaxprs(eqn):
                walk(sub, sub_trip)

    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    walk(jaxpr, 1)
    return out


def jaxpr_converts(closed_jaxpr, src: str = "float64",
                   dst: str = "float32") -> int:
    """Count ``convert_element_type`` equations narrowing ``src`` →
    ``dst`` anywhere in a traced program (the silent-precision-loss
    hygiene input)."""
    count = 0

    def walk(jaxpr):
        nonlocal count
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "convert_element_type":
                try:
                    s = str(eqn.invars[0].aval.dtype)
                    d = str(eqn.params.get("new_dtype", ""))
                except Exception:       # pragma: no cover - exotic avals
                    s = d = ""
                if s == src and d == dst:
                    count += 1
            for sub in _sub_jaxprs(eqn):
                walk(sub)

    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    walk(jaxpr)
    return count
