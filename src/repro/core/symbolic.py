"""Supernode partition + block symbolic factorization + block etree.

PSelInv consumes a supernodal LU factorization. Following the paper
(§2.1), supernodes are *relaxed*: maximal same-structure column runs,
capped at ``max_size`` columns. We operate directly at the block
(supernode) level:

1. partition columns into supernodes,
2. form the quotient (block) structure of ``A``,
3. run a right-looking *block* symbolic factorization to obtain the filled
   block structure of ``L`` (struct-symmetric => ``U = Lᵀ`` structurally),
4. derive the block elimination tree: ``parent(K) = min struct(K)``.

All downstream machinery — the comm-event schedule, the simulator, the
numeric factorization and the selected inversion — works on the resulting
:class:`BlockStructure`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np
import scipy.sparse as sp

__all__ = ["BlockStructure", "partition_supernodes", "symbolic_factorize"]


def partition_supernodes(n: int, max_size: int,
                         sizes: np.ndarray | None = None) -> np.ndarray:
    """Column offsets of the supernode partition.

    If per-element ``sizes`` are given (e.g. dense atom blocks from
    ``sparse.dg_like_matrix``), supernodes are groups of whole elements
    with total width <= max_size; else fixed-width blocking of columns.
    Returns ``offsets`` with supernode K owning columns
    [offsets[K], offsets[K+1]).
    """
    if sizes is None:
        cuts = list(range(0, n, max_size)) + [n]
        return np.asarray(cuts, dtype=np.int64)
    offs = [0]
    acc = 0
    for s in sizes:
        if acc and acc + s > max_size:
            offs.append(offs[-1] + acc)
            acc = 0
        acc += int(s)
    offs.append(offs[-1] + acc)
    if offs[-1] != n:
        raise ValueError(
            f"supernode cuts cover {offs[-1]} of {n} columns — the "
            "given sizes do not partition the matrix")
    return np.asarray(offs, dtype=np.int64)


@dataclass
class BlockStructure:
    """Filled block (supernodal) structure of the LU factors."""

    offsets: np.ndarray                 # (NB+1,) supernode column offsets
    struct: List[np.ndarray]            # struct[K] = sorted I>K with L(I,K)!=0
    a_struct: List[np.ndarray]          # pre-fill block structure of A
    parent: np.ndarray                  # block etree, -1 at roots

    @property
    def nsuper(self) -> int:
        return len(self.offsets) - 1

    def width(self, K: int) -> int:
        return int(self.offsets[K + 1] - self.offsets[K])

    def widths(self) -> np.ndarray:
        return np.diff(self.offsets)

    def children(self) -> List[List[int]]:
        out: List[List[int]] = [[] for _ in range(self.nsuper)]
        for k, p in enumerate(self.parent):
            if p >= 0:
                out[int(p)].append(k)
        return out

    def roots(self) -> List[int]:
        return [k for k, p in enumerate(self.parent) if p < 0]

    def postorder(self) -> np.ndarray:
        """Children-before-parents ordering (factorization order)."""
        order: List[int] = []
        ch = self.children()
        for r in self.roots():
            stack = [(r, False)]
            while stack:
                node, done = stack.pop()
                if done:
                    order.append(node)
                else:
                    stack.append((node, True))
                    for c in reversed(ch[node]):
                        stack.append((c, False))
        return np.asarray(order, dtype=np.int64)

    def fill_nnz_blocks(self) -> int:
        return sum(len(s) for s in self.struct)

    def postordered(self) -> "BlockStructure":
        """Relabel supernodes by etree postorder (children before parents,
        subtrees contiguous) — the ordering SuperLU_DIST hands PSelInv.
        Ancestor chains become near-contiguous, which concentrates
        flat-tree roots near the grid diagonal (paper Fig. 5a)."""
        order = self.postorder()                     # new -> old
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order))           # old -> new
        w = self.widths()
        new_offsets = np.concatenate([[0], np.cumsum(w[order])])
        new_struct = [np.sort(inv[self.struct[int(o)]]) for o in order]
        new_a = [np.sort(inv[self.a_struct[int(o)]]) for o in order]
        new_parent = np.array(
            [inv[self.parent[int(o)]] if self.parent[int(o)] >= 0 else -1
             for o in order], dtype=np.int64)
        return BlockStructure(offsets=new_offsets, struct=new_struct,
                              a_struct=new_a, parent=new_parent)

    def factor_nnz(self) -> int:
        """nnz in L+U (both triangles + diagonal blocks)."""
        w = self.widths()
        tri = sum(int(w[K]) * int(w[K]) for K in range(self.nsuper))
        off = sum(int(w[K]) * int(w[int(I)]) for K in range(self.nsuper)
                  for I in self.struct[K])
        return tri + 2 * off


def symbolic_factorize_elements(G: sp.spmatrix, sizes: np.ndarray,
                                max_supernode: int = 32) -> BlockStructure:
    """Block symbolic factorization straight from an *element* graph
    (nodes = dense element blocks of ``sizes[e]`` columns, as produced by
    ``sparse.dg_like_structure``/``fem3d_like_structure``) — avoids
    materializing the kron-expanded scalar pattern at bench scale."""
    G = sp.csr_matrix(G)
    ne = G.shape[0]
    sizes = np.asarray(sizes, dtype=np.int64)
    n = int(sizes.sum())

    # group consecutive elements into supernodes of width <= max_supernode
    el2sn = np.zeros(ne, dtype=np.int64)
    offsets = [0]
    acc = 0
    sn = 0
    for e in range(ne):
        s = int(sizes[e])
        if acc and acc + s > max_supernode:
            offsets.append(offsets[-1] + acc)
            sn += 1
            acc = 0
        el2sn[e] = sn
        acc += s
    offsets.append(offsets[-1] + acc)
    offsets = np.asarray(offsets, dtype=np.int64)
    nb = len(offsets) - 1

    coo = G.tocoo()
    bi = el2sn[coo.row]
    bj = el2sn[coo.col]
    mask = bi > bj
    pairs = np.unique(np.stack([bj[mask], bi[mask]], axis=1), axis=0)
    a_struct: List[List[int]] = [[] for _ in range(nb)]
    for J, I in pairs:
        a_struct[int(J)].append(int(I))

    struct: List[set] = [set(s) for s in a_struct]
    parent = np.full(nb, -1, dtype=np.int64)
    for K in range(nb):
        s = struct[K]
        if not s:
            continue
        p = min(s)
        parent[K] = p
        struct[p].update(x for x in s if x != p)

    return BlockStructure(
        offsets=offsets,
        struct=[np.asarray(sorted(s), dtype=np.int64) for s in struct],
        a_struct=[np.asarray(sorted(s), dtype=np.int64) for s in a_struct],
        parent=parent,
    )


def symbolic_factorize(A: sp.spmatrix, max_supernode: int = 32,
                       sizes: np.ndarray | None = None) -> BlockStructure:
    """Block symbolic factorization of a structurally-symmetric pattern.

    For non-symmetric input the pattern of ``A + Aᵀ`` is used (what
    SuperLU_DIST does before MC64/ND). Right-looking fill rule at block
    granularity: for each supernode K with parent P = min(struct(K)),
    struct(P) ∪= struct(K) \\ {P}.
    """
    A = sp.csr_matrix(A)
    n = A.shape[0]
    S = ((A != 0) + (A.T != 0)).tocsr()
    offsets = partition_supernodes(n, max_supernode, sizes)
    nb = len(offsets) - 1

    # map columns -> supernode
    col2sn = np.zeros(n, dtype=np.int64)
    for K in range(nb):
        col2sn[offsets[K]:offsets[K + 1]] = K

    # quotient structure of A (lower block triangle, strict)
    coo = S.tocoo()
    bi = col2sn[coo.row]
    bj = col2sn[coo.col]
    mask = bi > bj
    pairs = np.unique(np.stack([bj[mask], bi[mask]], axis=1), axis=0)
    a_struct: List[List[int]] = [[] for _ in range(nb)]
    for J, I in pairs:
        a_struct[int(J)].append(int(I))

    struct: List[set] = [set(s) for s in a_struct]
    parent = np.full(nb, -1, dtype=np.int64)
    for K in range(nb):
        s = struct[K]
        if not s:
            continue
        p = min(s)
        parent[K] = p
        struct[p].update(x for x in s if x != p)

    return BlockStructure(
        offsets=offsets,
        struct=[np.asarray(sorted(s), dtype=np.int64) for s in struct],
        a_struct=[np.asarray(sorted(s), dtype=np.int64) for s in a_struct],
        parent=parent,
    )
