"""HloLint — compiled-artifact conformance against the CommPlan.

PlanLint (``core/verify.py``) proves the *lowered tables* sound; this
module closes the remaining gap: the traced jaxpr / StableHLO /
optimized HLO that XLA actually compiles could still drift from those
tables — a packing bug that survives table construction, a gating bug
in the fori_loop body, or a JAX upgrade that re-lowers
``ppermute``/``lax.cond`` differently would ship silently-wrong or
silently-slow collectives. HloLint parses each compiled layer through
``core/hlo_ir.py`` into a small op graph and cross-checks it against
the :class:`~.pselinv_dist.PSelInvProgram` it was built from, emitting
the same typed :class:`~.verify.PlanDiagnostic` records.

Check families (stable codes):

* **collective conformance** — every compiled ``collective-permute``'s
  source-target pairs must match a plan round (unrolled executors) or a
  gated comm slot (stream; inside the fori_loop body, with the loop's
  trip count): a pair set no plan entry owns is ``hlo/perm-unknown``
  (a retargeted or foreign permute), a plan entry no compiled op
  matches is ``hlo/perm-missing`` (a dropped round/slot), and a
  matched op whose loop-context multiplier disagrees with the plan's
  trip count is ``hlo/loop-trip``.
* **compiled byte conservation** — compiled wire blocks (pairs × payload
  width × slot activations) must equal the plan yardstick
  (``stream.stream_wire_blocks`` / ``overlap_wire_blocks`` / the
  level-serial round sum) and ``simulator.executed_wire_bytes``
  (``hlo/bytes-drift``) — the compiled corner of the
  simulated == executed == compiled triangle.
* **hot-path hygiene** — any all-gather/all-reduce/reduce-scatter/
  all-to-all in a program whose whole design is point-to-point rounds
  is ``hlo/stray-collective``; infeed/outfeed/host-placement transfers
  are ``hlo/host-transfer``; a silent f64 → f32 convert on the value
  path is ``hlo/precision-loss``.
* **program-size regression** (WARN) — ``hlo_bytes`` / ``jaxpr_lines``
  more than :data:`SIZE_REGRESS_RATIO` over the recorded
  ``BENCH_pselinv.json`` baseline is ``hlo/size-regress``.

Entry points: :func:`lint_text` (one StableHLO or optimized-HLO text),
:func:`lint_jaxpr` (a traced ``ClosedJaxpr``), and
:func:`lint_program` — which traces and lowers the program's own sweep
on an **abstract mesh** (no devices required: an 8×4 grid lints on a
single-CPU host) and runs every family. ``PSelInvEngine.lint_compiled``
adds the optimized-HLO layer from a real compile, and
``tools/hlo_lint.py`` is the CLI with the same exit-nonzero contract
as ``tools/plan_lint.py``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import hlo_ir
from .schedule import BYTES_PER_ELT
from .verify import PlanDiagnostic, _err, _warn

__all__ = [
    "HLO_CODES", "SIZE_REGRESS_RATIO", "ExpectedPermute",
    "expected_permutes", "expected_wire_blocks", "compiled_wire_blocks",
    "check_collectives", "check_hygiene", "check_size",
    "lint_text", "lint_jaxpr", "lint_program", "abstract_lower",
    "load_size_baseline",
]

#: every diagnostic code this linter can emit, and what it means
HLO_CODES = {
    "hlo/perm-unknown": "compiled collective-permute whose pair set "
                        "matches no plan round or comm slot",
    "hlo/perm-missing": "plan round / comm slot with no compiled "
                        "collective-permute",
    "hlo/loop-trip": "loop-context execution count disagrees with the "
                     "plan trip count",
    "hlo/bytes-drift": "compiled wire bytes drift from the plan tables "
                       "/ executed wire accounting",
    "hlo/stray-collective": "all-gather/all-reduce/reduce-scatter/"
                            "all-to-all on the point-to-point hot path",
    "hlo/host-transfer": "host transfer op on the hot path",
    "hlo/precision-loss": "silent f64 -> f32 convert on the value path",
    "hlo/size-regress": "compiled program size regressed past the "
                        "recorded baseline (WARN)",
}

#: WARN threshold for the program-size regression lint
SIZE_REGRESS_RATIO = 1.5


# ---------------------------------------------------------------------------
# what the plan says the compiled program must contain
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExpectedPermute:
    """One permute the plan demands of the compiled program: its pair
    set, payload width in (b, b) blocks, the loop trip count of its
    lowering context (1 = unrolled), the number of rounds that actually
    activate it (gated stream slots < trip), and a human label."""
    pairs: frozenset
    width: int
    trip: int
    activations: int
    where: str


def expected_permutes(prog) -> List[ExpectedPermute]:
    """The permute dictionary a compiled sweep of ``prog`` must realize,
    derived from whichever executor lowering the program carries (the
    stream's gated slot tables, the overlapped global rounds, or the
    level-serial per-phase rounds)."""
    st = getattr(prog, "stream_tables", None)
    if st is not None:
        out = []
        for si in range(st.nslots):
            perm = st.slot_perm[si]
            if not perm:
                continue
            out.append(ExpectedPermute(
                pairs=frozenset((int(s), int(d)) for s, d in perm),
                width=int(st.slot_width[si]), trip=int(st.steps),
                activations=int(st.slot_active[:, si].sum()),
                where=f"comm slot {si}"))
        return out
    ov = getattr(prog, "overlap_plan", None)
    if ov is not None:
        return [ExpectedPermute(
            pairs=frozenset((int(s), int(d)) for s, d in rnd.perm),
            width=int(rnd.width), trip=1, activations=1,
            where=f"round {t}")
            for t, rnd in enumerate(ov.rounds) if rnd.perm]
    ex = getattr(prog, "exec_plan", None)
    if ex is not None:
        out = []
        for lvl, lv in enumerate(ex.levels):
            for phase in ("xfer_in", "bcast", "reduce", "xfer_out",
                          "diag_reduce"):
                for i, rnd in enumerate(getattr(lv, phase)):
                    if rnd.perm:
                        out.append(ExpectedPermute(
                            pairs=frozenset((int(s), int(d))
                                            for s, d in rnd.perm),
                            width=1, trip=1, activations=1,
                            where=f"level {lvl} {phase}[{i}]"))
        return out
    raise ValueError(
        "expected_permutes needs a program with stream_tables, "
        "overlap_plan or exec_plan")


def expected_wire_blocks(prog) -> int:
    """The plan-table wire yardstick in (b, b) blocks: what every
    compiled sweep of ``prog`` must ship (activations × pairs × width
    summed over the permute dictionary). Equals
    ``stream.stream_wire_blocks`` / ``overlap_wire_blocks`` for those
    lowerings by construction."""
    return sum(e.activations * len(e.pairs) * e.width
               for e in expected_permutes(prog))


# ---------------------------------------------------------------------------
# conformance + conservation over parsed collective ops
# ---------------------------------------------------------------------------

def _op_width(op: hlo_ir.CollectiveOp, b: int, batch: int
              ) -> Optional[int]:
    """Payload width of one compiled permute in (b, b) blocks, dividing
    out the trailing block dims and a leading vmapped batch axis.
    ``None`` when the result shape was unparseable."""
    if not op.dims:
        return None
    n = math.prod(op.dims)
    denom = batch * b * b
    if n % denom:
        return -1                     # not a whole number of blocks
    return n // denom


def check_collectives(ops: List[hlo_ir.CollectiveOp], prog, *,
                      batch: int = 1, layer: str = "hlo"
                      ) -> List[PlanDiagnostic]:
    """Collective conformance + compiled byte conservation over the
    parsed op list of one compiled layer."""
    diags: List[PlanDiagnostic] = []
    b = prog.b
    expected = expected_permutes(prog)
    # pool keyed by pair set; exact (pairs, width) matches drain first
    pool: Dict[frozenset, List[ExpectedPermute]] = {}
    for e in expected:
        pool.setdefault(e.pairs, []).append(e)

    compiled_blocks = 0
    cps = [op for op in ops if op.op == "collective-permute"]
    for op in cps:
        pairs = frozenset(op.pairs or ())
        cands = pool.get(pairs)
        if not cands:
            diags.append(_err(
                "hlo/perm-unknown",
                f"{layer} collective-permute (line {op.line}) with pairs "
                f"{sorted(pairs)} matches no plan round or comm slot — "
                "a retargeted or foreign permute",
                round=-1, slot=-1))
            continue
        w = _op_width(op, b, batch)
        exact = [e for e in cands if e.width == w]
        exp = exact[0] if exact else cands[0]
        cands.remove(exp)
        if not cands:
            del pool[pairs]
        if w is not None and w != exp.width:
            diags.append(_err(
                "hlo/bytes-drift",
                f"{layer} collective-permute (line {op.line}) for "
                f"{exp.where} carries {w} block lane(s) "
                f"({'non-integral payload' if w < 0 else 'payload'} "
                f"dims {op.dims}) but the plan packs width "
                f"{exp.width}"))
        if op.multiplier != exp.trip:
            diags.append(_err(
                "hlo/loop-trip",
                f"{layer} collective-permute (line {op.line}) for "
                f"{exp.where} executes x{op.multiplier} but the plan "
                f"runs it under trip count {exp.trip}"))
        compiled_blocks += (exp.activations * len(pairs)
                            * (w if w is not None and w > 0
                               else exp.width))
    for cands in pool.values():
        for e in cands:
            diags.append(_err(
                "hlo/perm-missing",
                f"plan {e.where} (pairs {sorted(e.pairs)}, width "
                f"{e.width}) has no compiled collective-permute in the "
                f"{layer} layer — a dropped round/slot"))

    # conservation: only meaningful when the permute census is complete
    if not any(d.code in ("hlo/perm-unknown", "hlo/perm-missing")
               for d in diags):
        want = expected_wire_blocks(prog)
        if compiled_blocks != want:
            diags.append(_err(
                "hlo/bytes-drift",
                f"{layer} wire volume is {compiled_blocks} blocks "
                f"({compiled_blocks * b * b * BYTES_PER_ELT:.0f} B) but "
                f"the plan tables ship {want} blocks"))
        else:
            ex_bytes = _executed_wire_bytes(prog)
            if ex_bytes is not None and not np.isclose(
                    compiled_blocks * b * b * BYTES_PER_ELT, ex_bytes):
                diags.append(_err(
                    "hlo/bytes-drift",
                    f"{layer} wire volume "
                    f"{compiled_blocks * b * b * BYTES_PER_ELT:.0f} B "
                    f"!= executed_wire_bytes {ex_bytes:.0f} B"))
    return diags


def _executed_wire_bytes(prog) -> Optional[float]:
    """``simulator.executed_wire_bytes`` where defined (overlapped /
    stream lowerings; the level-serial executor has no global round
    stream to price)."""
    if getattr(prog, "stream_tables", None) is None and \
            getattr(prog, "overlap_plan", None) is None:
        return None
    from .simulator import executed_wire_bytes
    return executed_wire_bytes(prog)


def compiled_wire_blocks(ops: List[hlo_ir.CollectiveOp], prog, *,
                         batch: int = 1) -> int:
    """Wire blocks of one parsed compiled layer, priced with the plan's
    slot activations (gated stream slots execute ``activations`` of
    their ``trip`` rounds) — the compiled corner of the wire triangle."""
    b = prog.b
    expected = expected_permutes(prog)
    pool: Dict[frozenset, List[ExpectedPermute]] = {}
    for e in expected:
        pool.setdefault(e.pairs, []).append(e)
    total = 0
    for op in ops:
        if op.op != "collective-permute":
            continue
        pairs = frozenset(op.pairs or ())
        cands = pool.get(pairs, [])
        w = _op_width(op, b, batch)
        exact = [e for e in cands if e.width == w]
        exp = exact[0] if exact else (cands[0] if cands else None)
        if exp is not None:
            cands.remove(exp)
        act = exp.activations if exp is not None else op.multiplier
        total += act * len(pairs) * (w if w is not None and w > 0
                                     else (exp.width if exp else 0))
    return total


# ---------------------------------------------------------------------------
# hygiene + size regression
# ---------------------------------------------------------------------------

def check_hygiene(txt: str, *, layer: str = "hlo"
                  ) -> List[PlanDiagnostic]:
    """Stray collectives, host transfers, and silent f64 → f32 value
    converts in one compiled text layer."""
    diags: List[PlanDiagnostic] = []
    for op in hlo_ir.parse_collectives(txt):
        if op.op != "collective-permute":
            diags.append(_err(
                "hlo/stray-collective",
                f"{layer} {op.op} (line {op.line}) on the hot path — "
                "every collective of this schedule lowers to "
                "point-to-point collective-permute rounds"))
    for lineno, line in hlo_ir.host_transfer_lines(txt):
        diags.append(_err(
            "hlo/host-transfer",
            f"{layer} host transfer (line {lineno}): {line[:80]}"))
    for cv in hlo_ir.parse_converts(txt):
        if cv.src == "f64" and cv.dst == "f32":
            diags.append(_err(
                "hlo/precision-loss",
                f"{layer} silent f64 -> f32 convert (line {cv.line}) "
                "on the value path"))
    return diags


def load_size_baseline(path: str = "BENCH_pselinv.json", *,
                       stream: bool = True) -> Optional[Dict[str, float]]:
    """The recorded ``hlo_bytes`` baseline for the nb=16 4×2 f32
    single-matrix shape class, from the latest ``BENCH_pselinv.json``
    entry (``selinv/stream_hlo_bytes`` records the stream program's
    size as its value and the overlapped one in the derived column).
    ``None`` when no baseline is recorded."""
    import json
    import os
    import re
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            hist = json.load(f)
        for entry in reversed(hist):
            for row in entry.get("benches", []):
                if row.get("name") == "selinv/stream_hlo_bytes":
                    if stream:
                        return {"hlo_bytes": float(row["us_per_call"])}
                    m = re.search(r"overlap_hlo_bytes=(\d+)",
                                  row.get("derived", ""))
                    if m:
                        return {"hlo_bytes": float(m.group(1))}
    except (ValueError, KeyError, OSError):      # corrupt history
        return None
    return None


def check_size(metrics: Dict[str, float],
               baseline: Optional[Dict[str, float]], *,
               ratio: float = SIZE_REGRESS_RATIO
               ) -> List[PlanDiagnostic]:
    """WARN when a compiled program's ``hlo_bytes`` / ``jaxpr_lines``
    regressed more than ``ratio`` × over the recorded baseline."""
    if not baseline:
        return []
    diags: List[PlanDiagnostic] = []
    for key in ("hlo_bytes", "jaxpr_lines"):
        have, want = metrics.get(key), baseline.get(key)
        if have and want and have > ratio * want:
            diags.append(_warn(
                "hlo/size-regress",
                f"compiled {key} = {have:.0f} is "
                f"{have / want:.2f}x the recorded baseline "
                f"({want:.0f}) — program size regression"))
    return diags


# ---------------------------------------------------------------------------
# layer entry points
# ---------------------------------------------------------------------------

def lint_text(txt: str, prog, *, batch: int = 1,
              layer: Optional[str] = None) -> List[PlanDiagnostic]:
    """Full HloLint pass over one compiled text layer (StableHLO or
    optimized HLO, auto-detected): conformance, conservation, hygiene."""
    if layer is None:
        layer = "stablehlo" if hlo_ir.is_stablehlo(txt) else "hlo"
    ops = hlo_ir.parse_collectives(txt)
    return (check_collectives(ops, prog, batch=batch, layer=layer)
            + check_hygiene(txt, layer=layer))


def lint_jaxpr(closed_jaxpr, prog, *, batch: int = 1
               ) -> List[PlanDiagnostic]:
    """HloLint over the traced jaxpr: structural walk (no text) —
    ppermute perm conformance, loop trip counts from ``scan`` lengths,
    stray collective primitives, f64 → f32 value converts."""
    diags: List[PlanDiagnostic] = []
    expected = expected_permutes(prog)
    pool: Dict[frozenset, List[ExpectedPermute]] = {}
    for e in expected:
        pool.setdefault(e.pairs, []).append(e)
    for jc in hlo_ir.jaxpr_collectives(closed_jaxpr):
        if jc.prim != "ppermute":
            diags.append(_err(
                "hlo/stray-collective",
                f"jaxpr {jc.prim} equation on the hot path — every "
                "collective of this schedule lowers to ppermute"))
            continue
        pairs = frozenset(jc.perm or ())
        cands = pool.get(pairs)
        if not cands:
            diags.append(_err(
                "hlo/perm-unknown",
                f"jaxpr ppermute with pairs {sorted(pairs)} matches no "
                "plan round or comm slot"))
            continue
        exp = cands.pop(0)
        if not cands:
            del pool[pairs]
        if jc.trip is not None and jc.trip != exp.trip:
            diags.append(_err(
                "hlo/loop-trip",
                f"jaxpr ppermute for {exp.where} executes x{jc.trip} "
                f"but the plan runs it under trip count {exp.trip}"))
    for cands in pool.values():
        for e in cands:
            diags.append(_err(
                "hlo/perm-missing",
                f"plan {e.where} (pairs {sorted(e.pairs)}) has no "
                "ppermute equation in the traced jaxpr"))
    n64 = hlo_ir.jaxpr_converts(closed_jaxpr)
    if n64:
        diags.append(_err(
            "hlo/precision-loss",
            f"traced jaxpr narrows f64 -> f32 in {n64} "
            "convert_element_type equation(s) on the value path"))
    return diags


# ---------------------------------------------------------------------------
# whole-program lint on an abstract mesh (no devices required)
# ---------------------------------------------------------------------------

def _traced_sweep(prog, *, batched: bool = False, dtype=None,
                  batch_size: int = 1, mesh=None):
    """AOT-trace the program's own sweep (per whichever executor
    lowering it carries) over ``mesh`` — an
    ``jax.sharding.AbstractMesh`` of the right size when None, so no
    physical devices are required. Returns the jax ``Traced`` object
    (``.jaxpr``, ``.lower()``)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map
    from .pselinv_dist import (make_sweep, make_sweep_overlapped,
                               make_sweep_stream)
    if dtype is None:
        dtype = jnp.float32
    if getattr(prog, "stream_tables", None) is not None:
        mk = make_sweep_stream
    elif getattr(prog, "overlap_plan", None) is not None:
        mk = make_sweep_overlapped
    else:
        mk = make_sweep
    P_dev = prog.pr * prog.pc
    if mesh is None:
        mesh = AbstractMesh((("xy", P_dev),))
    spec = P(None, "xy") if batched else P("xy")
    fn = shard_map(mk(prog, batched=batched), mesh=mesh,
                   in_specs=(spec, spec), out_specs=spec)
    shape = ((int(batch_size),) if batched else ()) + (
        P_dev, prog.nbr, prog.nbc, prog.b, prog.b)
    sd = jax.ShapeDtypeStruct(shape, dtype)
    return jax.jit(fn).trace(sd, sd)


def abstract_lower(prog, *, batched: bool = False, dtype=None,
                   batch_size: int = 1):
    """Trace + lower the program's own sweep on a
    ``jax.sharding.AbstractMesh`` — no physical devices: an 8×4-grid
    program lints on a single-CPU host (the ``bigmesh``-free compiled
    conformance path). Returns ``(closed_jaxpr, stablehlo_text)``.
    XLA *compilation* still needs real devices — the optimized-HLO
    layer is the engine's job (``PSelInvEngine.lint_compiled``) or
    :func:`lint_program`'s ``compile=True`` with a real mesh."""
    traced = _traced_sweep(prog, batched=batched, dtype=dtype,
                           batch_size=batch_size)
    return traced.jaxpr, traced.lower().as_text()


def lint_program(prog, *, batched: bool = False, dtype=None,
                 batch_size: int = 1,
                 baseline: Optional[Dict[str, float]] = None,
                 compile: bool = False
                 ) -> List[PlanDiagnostic]:
    """HloLint a program end to end without devices: abstract-mesh
    trace + lower, then the jaxpr and StableHLO layer passes (plus the
    size-regression lint when a ``baseline`` is supplied).
    ``compile=True`` additionally runs a real XLA compile on a mesh of
    ``prog.pr * prog.pc`` physical devices (which must exist) and lints
    the optimized HLO too — the full three-layer pass
    ``PSelInvEngine.lint_compiled`` runs for live sessions."""
    mesh = None
    if compile:
        import jax
        import numpy as _np
        from jax.sharding import Mesh
        P_dev = prog.pr * prog.pc
        if len(jax.devices()) < P_dev:
            raise ValueError(
                f"lint_program(compile=True) needs {P_dev} devices for "
                f"the {prog.pr}x{prog.pc} grid, found "
                f"{len(jax.devices())}")
        mesh = Mesh(_np.array(jax.devices()[:P_dev]), ("xy",))
    traced = _traced_sweep(prog, batched=batched, dtype=dtype,
                           batch_size=batch_size, mesh=mesh)
    jaxpr = traced.jaxpr
    lowered = traced.lower()
    sh_text = lowered.as_text()
    batch = int(batch_size) if batched else 1
    diags = (lint_jaxpr(jaxpr, prog, batch=batch)
             + lint_text(sh_text, prog, batch=batch, layer="stablehlo"))
    if compile:
        diags += lint_text(lowered.compile().as_text(), prog,
                           batch=batch, layer="hlo")
    if baseline:
        metrics = {"hlo_bytes": float(len(sh_text)),
                   "jaxpr_lines": float(
                       len(str(jaxpr).splitlines()))}
        diags += check_size(metrics, baseline)
    return diags
