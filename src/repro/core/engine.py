"""PSelInvEngine — the analyze/plan/bind/solve session API.

The paper's central observation is that the *structure* of the
restricted collectives (trees, rounds, tables) is fully known before a
single value moves. Production selected-inversion libraries split their
API exactly there (PSelInv's ``SymbolicFactorize``/``NumericalSelInv``,
Serinv's symbolic setup vs repeated numeric solves); this module is that
split for the JAX reproduction:

    engine = PSelInvEngine.analyze(A_or_structure, b=8,
                                   grid=Grid(4, 2),
                                   options=PlanOptions(...))
    out = engine.solve(values)            # value-only hot path

``analyze`` performs symbolic analysis → CommPlan IR → (overlapped)
round schedule → per-device gather/scatter tables → the jitted
shard_map sweep **once**, and caches the whole session keyed on
(block-structure hash, supernode width, grid, :class:`PlanOptions`) —
a second ``analyze`` with an identical structure returns the *same*
engine, compiled program included. ``solve`` moves values only: the
host numeric factorization (when given a matrix) plus one call of the
cached jitted sweep — no symbolic work, no re-lowering, no retrace.

**Multi-matrix batching** comes from the same structure/value split:
the compiled tables are value-independent, so ``solve`` accepts a
leading batch axis (``values`` shaped (B, P, nbr, nbc, b, b)) and runs
all B matrices through one ``vmap``-ed sweep — one trace, one compile,
B results (``solve_many`` stacks a list of matrices for you). This is
the ROADMAP's "many matrices, same structure" serving path.

``run_distributed``/``prepare_inputs`` in ``pselinv_dist`` remain as
thin back-compat shims over this engine.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import ClassVar, Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..compat import shard_map
from ..obs.registry import REGISTRY
from ..obs.trace import TRACER
from .plan import (PlanOptions, peak_arena_blocks, ppermute_round_count)
from .pselinv_dist import (PSelInvProgram, analyze_structure, build_program,
                           check_grid_devices, make_sweep,
                           make_sweep_overlapped, make_sweep_stream,
                           pad_nb, prepare_values, prepare_values_many,
                           validate_uniform_widths)
from .schedule import Grid2D
from .symbolic import BlockStructure

__all__ = ["Grid", "PlanOptions", "PSelInvEngine", "SolveValues",
           "structure_key", "stack_values", "bucket_size"]

#: the session API's name for the 2-D process grid (one definition —
#: ``schedule.Grid2D`` — reused, not duplicated)
Grid = Grid2D


class SolveValues(NamedTuple):
    """One matrix's numeric payload in device layout: ``Lh`` and ``Dinv``
    shaped (P, nbr, nbc, b, b) — or (B, P, nbr, nbc, b, b) with a
    leading batch axis for multi-matrix solves."""
    Lh: np.ndarray
    Dinv: np.ndarray


def stack_values(values: Sequence[SolveValues]) -> SolveValues:
    """Stack per-matrix :class:`SolveValues` along a new leading batch
    axis (same structure, many matrices)."""
    return SolveValues(np.stack([v.Lh for v in values]),
                       np.stack([v.Dinv for v in values]))


def structure_key(bs: BlockStructure) -> str:
    """Content hash of a block structure — the value-independent part of
    the engine cache key. Two matrices with equal sparsity structure
    (same supernodes, same fill, same etree) hash equal and share one
    compiled session."""
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(bs.offsets, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(bs.parent, dtype=np.int64).tobytes())
    for s in bs.struct:
        h.update(np.ascontiguousarray(s, dtype=np.int64).tobytes())
        h.update(b"|")
    return h.hexdigest()


def bucket_size(B: int) -> int:
    """The padded batch bucket for B matrices: the next power of two.

    Each distinct batch length traces (and XLA-compiles) its own vmapped
    sweep, so a serving workload with organic batch sizes 3, 5, 13, …
    would retrace per length. Rounding up to power-of-2 buckets bounds
    the program population at log₂(max batch) per structure — a burst of
    13 rides the B=16 program (pad lanes carry zeros and are sliced off
    the result)."""
    if B < 1:
        raise ValueError(f"batch size must be >= 1, got {B}")
    return 1 << (B - 1).bit_length()


def _approx_nbytes(obj, _seen=None, _depth=0) -> int:
    """Approximate resident bytes of a program/table object: the sum of
    every reachable numpy array's ``nbytes`` (dataclasses, dicts, lists,
    tuples walked; shared arrays counted once). The engine cache's
    size-aware eviction bound runs on this — an *approximation* is fine,
    the arrays dominate and python-object overhead is noise."""
    if _seen is None:
        _seen = set()
    if _depth > 16 or id(obj) in _seen:
        return 0
    if isinstance(obj, np.ndarray):
        _seen.add(id(obj))
        return int(obj.nbytes)
    if isinstance(obj, (str, bytes, int, float, bool, complex,
                        type(None))):
        return 0
    _seen.add(id(obj))
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(_approx_nbytes(getattr(obj, f.name), _seen, _depth + 1)
                   for f in dataclasses.fields(obj))
    if isinstance(obj, dict):
        return sum(_approx_nbytes(v, _seen, _depth + 1)
                   for v in obj.values())
    if isinstance(obj, (list, tuple, set, frozenset)):
        return sum(_approx_nbytes(v, _seen, _depth + 1) for v in obj)
    return 0


def _is_matrix(x) -> bool:
    """A numeric matrix (dense 2-D array or scipy sparse) as opposed to
    prepared value shards."""
    try:
        import scipy.sparse as sp
        if sp.issparse(x):
            return True
    except ImportError:                       # pragma: no cover
        pass
    return hasattr(x, "ndim") and getattr(x, "ndim", 0) == 2


@dataclass
class PSelInvEngine:
    """One compiled selected-inversion session: structure + grid +
    options bound to a jitted sweep. Construct through
    :meth:`analyze` — the constructor itself performs no work."""
    bs: BlockStructure
    b: int
    nb: int
    grid: Grid2D
    options: PlanOptions
    program: PSelInvProgram
    mesh: object
    key: Tuple = ()
    #: times the jitted sweep body was (re)traced — regression handle for
    #: the "solve does not retrace" contract
    trace_count: int = 0
    solve_calls: int = 0
    _fns: Dict[bool, object] = field(default_factory=dict)
    _compile_metrics: Dict[Tuple, Dict[str, float]] = \
        field(default_factory=dict, repr=False)
    _hlo_lint: Dict[Tuple, list] = field(default_factory=dict,
                                         repr=False)
    _jit_lock: threading.Lock = field(default_factory=threading.Lock,
                                      repr=False)
    _round_schedule: Optional[object] = None
    _table_bytes: Optional[int] = field(default=None, repr=False)
    #: span-derived gauges (µs): wall of the most recent solve dispatch
    #: and the most recent host value-prep — surfaced by :meth:`stats`
    #: and published to the global metrics registry
    _last_solve_us: Optional[float] = field(default=None, repr=False)
    _last_prepare_us: Optional[float] = field(default=None, repr=False)

    # ---- the structure cache (class-level, all sessions) --------------
    _cache: ClassVar["OrderedDict[Tuple, PSelInvEngine]"] = OrderedDict()
    _cache_lock: ClassVar[threading.Lock] = threading.Lock()
    #: LRU eviction bounds — a long-lived server analyzing a stream of
    #: distinct structures must not pin every session's tables and
    #: compiled executables for process lifetime. A cache *hit* moves
    #: the session to the back of the queue, so the structures real
    #: traffic keeps re-hitting stay resident (the serving layer's warm
    #: engines) while one-off structures age out the front.
    #: ``cache_max`` bounds the session count; ``cache_max_bytes``
    #: bounds the summed per-engine table footprint
    #: (:meth:`table_bytes`) — the real production bound, since table
    #: bytes vary ~nb²·b² per structure while the count does not. The
    #: most-recently-inserted session is never evicted, so a single
    #: over-budget structure still solves.
    cache_max: ClassVar[int] = 16
    cache_max_bytes: ClassVar[int] = 1 << 30
    cache_hits: ClassVar[int] = 0
    cache_misses: ClassVar[int] = 0
    cache_evictions: ClassVar[int] = 0

    @classmethod
    def analyze(cls, structure_or_A, b: int, grid: Grid2D,
                options: PlanOptions = PlanOptions(), *,
                verify: str | None = None,
                verify_compiled: str | None = None) -> "PSelInvEngine":
        """Symbolic analysis → CommPlan → schedule → tables → jitted
        sweep, **once per structure**. Accepts a matrix (symbolically
        factorized here) or a ready :class:`BlockStructure`; returns the
        cached engine when an identical (structure, b, grid, options)
        session already exists.

        ``verify`` overrides ``options.verify`` — the PlanLint mode
        (``"error"`` | ``"warn"`` | ``"off"``) applied to the lowered
        program at build time. ``verify_compiled`` likewise overrides
        ``options.verify_compiled`` — the HloLint mode applied to the
        compiled jaxpr/StableHLO layers of the program's own sweep
        (``core/hlo_verify.py``; traced on an abstract mesh at build
        time). Both are part of the cache key (two sessions that differ
        only in verification mode compile independently)."""
        check_grid_devices(grid.pr, grid.pc)
        if verify is not None:
            options = dataclasses.replace(options, verify=verify)
        if verify_compiled is not None:
            options = dataclasses.replace(options,
                                          verify_compiled=verify_compiled)
        with TRACER.span("engine.analyze", b=b,
                         grid=f"{grid.pr}x{grid.pc}") as sp:
            if isinstance(structure_or_A, BlockStructure):
                bs = structure_or_A
                validate_uniform_widths(bs, b)
                nb = pad_nb(bs.nsuper, grid.pr, grid.pc)
            else:
                with TRACER.span("analyze.symbolic"):
                    bs, nb = analyze_structure(structure_or_A, b,
                                               grid.pr, grid.pc)
            sp.set(nb=nb)

            key = (structure_key(bs), b, grid, options)
            with cls._cache_lock:
                hit = cls._cache.get(key)
                if hit is not None:
                    cls.cache_hits += 1
                    cls._cache.move_to_end(key)  # LRU: a hit stays warm
                    sp.set(cache="hit")
                    return hit
                cls.cache_misses += 1
            sp.set(cache="miss")

            from jax.sharding import Mesh
            program = build_program(bs, nb, b, grid.pr, grid.pc,
                                    options=options)
            devs = np.array(jax.devices()[:grid.size]).reshape(grid.size)
            engine = cls(bs=bs, b=b, nb=nb, grid=grid, options=options,
                         program=program, mesh=Mesh(devs, ("xy",)),
                         key=key)
        with cls._cache_lock:
            # somebody may have raced us past the miss above; keep the
            # first published session so `analyze` stays idempotent
            engine = cls._cache.setdefault(key, engine)
            cls._cache.move_to_end(key)
            cls._evict_locked()
        return engine

    @classmethod
    def _evict_locked(cls) -> None:
        """LRU eviction under ``_cache_lock``: pop the front while the
        session count exceeds ``cache_max`` or the summed table bytes
        exceed ``cache_max_bytes`` — keeping at least the most recent
        session so one over-budget structure still solves."""
        def over():
            if len(cls._cache) > cls.cache_max:
                return True
            return sum(e.table_bytes()
                       for e in cls._cache.values()) > cls.cache_max_bytes
        while len(cls._cache) > 1 and over():
            cls._cache.popitem(last=False)
            cls.cache_evictions += 1

    @classmethod
    def cache_bytes(cls) -> int:
        """Summed approximate table bytes of every cached session (the
        quantity ``cache_max_bytes`` bounds)."""
        with cls._cache_lock:
            return sum(e.table_bytes() for e in cls._cache.values())

    @classmethod
    def clear_cache(cls) -> None:
        with cls._cache_lock:
            cls._cache.clear()
            cls.cache_hits = cls.cache_misses = 0
            cls.cache_evictions = 0

    # ---- lowering / jit (once per (batched, dtype) shape class) -------
    def _shard_mapped_sweep(self, batched: bool, counted: bool):
        """The session's sweep (per its :class:`PlanOptions` executor)
        wrapped for shard_map — the one builder :meth:`jitted` and
        :meth:`compile_stats` share. ``counted=True`` wraps the body so
        each (re)trace bumps ``trace_count`` (the no-retrace regression
        handle); measurement paths pass False so they never touch the
        counter."""
        from jax.sharding import PartitionSpec as P
        if self.options.stream:
            mk = make_sweep_stream
        elif self.options.overlap:
            mk = make_sweep_overlapped
        else:
            mk = make_sweep
        sweep = mk(self.program, batched=batched)
        if counted:
            inner = sweep

            def sweep(Lh, Dinv):
                self.trace_count += 1         # fires at trace time only
                return inner(Lh, Dinv)

        spec = P(None, "xy") if batched else P("xy")
        return shard_map(sweep, mesh=self.mesh,
                         in_specs=(spec, spec), out_specs=spec)

    def jitted(self, batched: bool = False):
        """The compiled shard_map sweep as a ``jax.jit`` callable.
        Single-matrix signature: (Lh, Dinv) each (P, nbr, nbc, b, b),
        sharded over mesh axis "xy". Batched: (B, P, nbr, nbc, b, b) —
        the leading axis is vmapped through the value tensors while the
        static tables are shared (no per-item retrace)."""
        with self._jit_lock:     # cached sessions are shared: one
            fn = self._fns.get(batched)      # builder per shape class
            if fn is None:
                fn = jax.jit(self._shard_mapped_sweep(batched,
                                                      counted=True))
                self._fns[batched] = fn
        return fn

    # ---- the value-only hot path --------------------------------------
    def prepare_values(self, A, dtype=None) -> SolveValues:
        """Numeric host factorization of one matrix against the cached
        structure → device-layout shards. No symbolic work."""
        t0 = time.perf_counter()
        with TRACER.span("engine.prepare_values"):
            Lh, Dinv = prepare_values(A, self.bs, self.nb, self.b,
                                      self.grid.pr, self.grid.pc)
            if dtype is not None:
                Lh, Dinv = Lh.astype(dtype), Dinv.astype(dtype)
        self._last_prepare_us = (time.perf_counter() - t0) * 1e6
        return SolveValues(Lh, Dinv)

    def prepare_values_many(self, mats: Sequence,
                            dtype=None) -> SolveValues:
        """Batched numeric host factorization of B same-structure
        matrices → stacked (B, P, nbr, nbc, b, b) shards in one
        structure-driven pass (:func:`~.pselinv_dist
        .prepare_values_many`) — the supernode loop runs once with
        (B, b, b) block stacks, so the interpreter overhead that
        dominates single-matrix prep amortizes across the batch (~9×
        cheaper per matrix at B=16). The serving layer's host half of
        the coalescing win."""
        t0 = time.perf_counter()
        with TRACER.span("engine.prepare_values_many", B=len(mats)):
            Lh, Dinv = prepare_values_many(mats, self.bs, self.nb,
                                           self.b, self.grid.pr,
                                           self.grid.pc)
            if dtype is not None:
                Lh, Dinv = Lh.astype(dtype), Dinv.astype(dtype)
        self._last_prepare_us = (time.perf_counter() - t0) * 1e6
        return SolveValues(Lh, Dinv)

    def solve(self, values, dtype=jnp.float32, *, bucket: bool = False):
        """Selected inversion of one matrix — or a whole batch.

        ``values`` is a matrix (numeric-factorized here against the
        cached structure), a :class:`SolveValues`, or a plain
        ``(Lh, Dinv)`` pair. Arrays of rank 5 ((P, nbr, nbc, b, b))
        solve one matrix; rank 6 ((B, P, nbr, nbc, b, b), the leading
        **batch axis**) solve B same-structure matrices through one
        vmapped sweep call. Returns the A⁻¹ shards in the same layout
        (rank 5 or 6). ``dtype`` casts the values (f32 default,
        matching ``run_distributed``); pass ``None`` to keep the
        arrays' own dtype.

        ``bucket=True`` pads a batched solve up to the next power-of-2
        bucket (:func:`bucket_size`) with zero-valued lanes and slices
        the real results back out — every distinct batch length
        otherwise traces and compiles its own program, while bucketed
        batches of 3, 5, 13 all ride the B∈{4, 8, 16} programs (the
        serving layer's retrace bound)."""
        if _is_matrix(values):
            values = self.prepare_values(values)
        Lh, Dinv = values
        if dtype is not None:
            Lh = jnp.asarray(Lh, dtype=dtype)
            Dinv = jnp.asarray(Dinv, dtype=dtype)
        if Lh.ndim not in (5, 6):
            raise ValueError(
                f"values must be rank 5 (single) or rank 6 (leading "
                f"batch axis), got shape {Lh.shape}")
        self.solve_calls += 1
        t0 = time.perf_counter()
        with TRACER.span("engine.solve",
                         B=Lh.shape[0] if Lh.ndim == 6 else 1):
            if Lh.ndim == 6 and bucket:
                B = Lh.shape[0]
                Bp = bucket_size(B)
                if Bp != B:
                    pad = ((0, Bp - B),) + ((0, 0),) * (Lh.ndim - 1)
                    out = self.jitted(batched=True)(jnp.pad(Lh, pad),
                                                    jnp.pad(Dinv, pad))
                    out = out[:B]
                else:
                    out = self.jitted(batched=True)(Lh, Dinv)
            else:
                out = self.jitted(batched=(Lh.ndim == 6))(Lh, Dinv)
        # dispatch wall, not device wall: the result stays async (the
        # caller decides when to block), so this gauge measures host
        # prep + jit dispatch — and trace+compile when it's a cold class
        self._last_solve_us = (time.perf_counter() - t0) * 1e6
        return out

    def solve_many(self, mats: Sequence, dtype=jnp.float32, *,
                   bucket: bool = False, batched_prep: bool = True):
        """Convenience: numeric-factorize each same-structure matrix,
        stack along the batch axis, and run ONE batched solve.
        ``batched_prep`` routes the host factorization through the
        stacked :meth:`prepare_values_many` pass (numerics match the
        per-matrix path to rounding); ``bucket`` pads the batch to its
        power-of-2 bucket so odd batch lengths share compiled
        programs."""
        if batched_prep and len(mats) > 1:
            vals = self.prepare_values_many(mats)
        else:
            vals = stack_values([self.prepare_values(A) for A in mats])
        return self.solve(vals, dtype=dtype, bucket=bucket)

    def table_bytes(self) -> int:
        """Approximate resident bytes of this session's compiled tables
        (every numpy array reachable from the program object, counted
        once). Computed once and cached — the LRU cache's size-aware
        eviction bound (``cache_max_bytes``) sums this across
        sessions."""
        if self._table_bytes is None:
            self._table_bytes = _approx_nbytes(self.program)
        return self._table_bytes

    def aot_compile(self, batch_size: int = 1, dtype=jnp.float32, *,
                    batched: bool = True):
        """AOT trace → lower → XLA-compile the session's sweep for one
        exact shape class and hand back the ``jax.stages.Compiled``
        executable (*uncounted*: the no-retrace regression handle
        ``trace_count`` never moves). This is the serialization seam the
        serving layer's on-disk program cache
        (``repro.serve.progcache``) builds on — a compiled executable
        can be serialized, persisted, and reloaded after a restart
        without re-tracing or re-compiling the hot structure."""
        shape = ((int(batch_size),) if batched else ()) + (
            self.grid.size, self.nb // self.grid.pr,
            self.nb // self.grid.pc, self.b, self.b)
        sd = jax.ShapeDtypeStruct(shape, dtype)
        fn = jax.jit(self._shard_mapped_sweep(batched, counted=False))
        return fn.trace(sd, sd).lower().compile()

    # ---- plan introspection (no re-lowering) --------------------------
    def round_schedule(self):
        """The cached program's executed :class:`~.simulator.RoundSchedule`
        (built once, then reused — nothing is re-lowered)."""
        if self._round_schedule is None:
            from .simulator import round_schedule_of
            self._round_schedule = round_schedule_of(self.program)
        return self._round_schedule

    def simulate(self, model=None):
        """α-β timing of the cached compiled schedule
        (:func:`~.simulator.simulate_schedule` on :meth:`round_schedule`
        — replaces the hand-wired ``round_schedule_from_*`` plumbing)."""
        from .simulator import simulate_schedule
        return simulate_schedule(self.round_schedule(), model)

    def compile_stats(self, batched: bool = False, dtype=jnp.float32,
                      batch_size: int = 1) -> Dict[str, float]:
        """Compile metrics of the session's sweep program, measured once
        per (batched, dtype, batch size) shape class and cached:
        ``trace_lower_ms`` (trace + StableHLO lowering wall time),
        ``compile_ms`` (XLA compile wall time), ``jaxpr_lines`` (traced
        program size), ``hlo_bytes`` (lowered HLO text size),
        ``ppermute_count`` (collective-permute ops in the optimized HLO
        XLA actually runs) and ``collective_bytes`` (their per-device
        traffic priced with while-loop trip counts —
        ``core/hlo_ir.collective_bytes``). This is
        how the uniform round-stream's program-size win over the
        unrolled executors is inspected without running the bench — the
        stream's jaxpr/HLO no longer grow with the round count. Uses
        abstract ``ShapeDtypeStruct`` inputs: no values move, but trace,
        lowering and XLA compilation really run (seconds, not
        microseconds). Pass the ``batched``/``dtype``/``batch_size``
        your solves use to measure that exact shape class (jit
        specializes on all three). Measures a fresh *uncounted* build of
        the same program, so the no-retrace regression handle
        (``trace_count``) is never touched — even when solves run
        concurrently on the shared session."""
        key = (batched, jnp.dtype(dtype).name,
               int(batch_size) if batched else 1)
        with self._jit_lock:
            m = self._compile_metrics.get(key)
        if m is not None:
            return m
        shape = ((int(batch_size),) if batched else ()) + (
            self.grid.size, self.nb // self.grid.pr,
            self.nb // self.grid.pc, self.b, self.b)
        sd = jax.ShapeDtypeStruct(shape, dtype)
        fn = jax.jit(self._shard_mapped_sweep(batched, counted=False))
        # the AOT path traces ONCE and hands back jaxpr + lowering
        t0 = time.perf_counter()
        with TRACER.span("engine.trace_lower", batched=batched):
            traced = fn.trace(sd, sd)
            lowered = traced.lower()
        t_lower = time.perf_counter() - t0
        jaxpr_lines = len(str(traced.jaxpr).splitlines())
        hlo_bytes = len(lowered.as_text())
        t0 = time.perf_counter()
        with TRACER.span("engine.compile", batched=batched):
            compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        # compiled-collective census off the optimized HLO (the program
        # XLA actually runs): permute op count and per-device collective
        # traffic priced with while-loop trip counts
        from . import hlo_ir
        compiled_txt = compiled.as_text()
        ppermute_count = sum(
            1 for op in hlo_ir.parse_collectives(compiled_txt)
            if op.op == "collective-permute")
        coll_bytes = float(sum(
            hlo_ir.collective_bytes(compiled_txt).values()))
        m = {"trace_lower_ms": t_lower * 1e3,
             "compile_ms": t_compile * 1e3,
             "jaxpr_lines": jaxpr_lines,
             "hlo_bytes": hlo_bytes,
             "ppermute_count": ppermute_count,
             "collective_bytes": coll_bytes}
        with self._jit_lock:
            m = self._compile_metrics.setdefault(key, m)
        return m

    def lint_compiled(self, batched: bool = False, dtype=jnp.float32,
                      batch_size: int = 1, *, verify_compiled:
                      str | None = None):
        """HloLint the session's compiled sweep at **all three layers**
        — traced jaxpr, lowered StableHLO, and the optimized HLO of a
        real XLA compile (``core/hlo_verify.py``; cross-checks permute
        conformance, loop trip counts, wire-byte conservation and
        hot-path hygiene against the session's own plan tables).
        Measured once per (batched, dtype, batch size) shape class and
        cached. ``verify_compiled`` applies an enforcement mode to the
        result (``"error"`` raises
        :class:`~.verify.PlanVerificationError` on any ERROR
        diagnostic, ``"warn"`` warns once, default ``None`` just
        returns the diagnostics)."""
        from . import hlo_verify
        from .verify import enforce_verification

        key = (batched, jnp.dtype(dtype).name,
               int(batch_size) if batched else 1)
        with self._jit_lock:
            diags = self._hlo_lint.get(key)
        if diags is None:
            shape = ((int(batch_size),) if batched else ()) + (
                self.grid.size, self.nb // self.grid.pr,
                self.nb // self.grid.pc, self.b, self.b)
            sd = jax.ShapeDtypeStruct(shape, dtype)
            fn = jax.jit(self._shard_mapped_sweep(batched,
                                                  counted=False))
            traced = fn.trace(sd, sd)
            lowered = traced.lower()
            batch = int(batch_size) if batched else 1
            diags = (hlo_verify.lint_jaxpr(traced.jaxpr, self.program,
                                           batch=batch)
                     + hlo_verify.lint_text(lowered.as_text(),
                                            self.program, batch=batch,
                                            layer="stablehlo")
                     + hlo_verify.lint_text(lowered.compile().as_text(),
                                            self.program, batch=batch,
                                            layer="hlo"))
            with self._jit_lock:
                diags = self._hlo_lint.setdefault(key, diags)
        if verify_compiled is not None:
            enforce_verification(
                diags, mode=verify_compiled,
                where=f"compiled sweep (nb={self.nb}, "
                      f"grid={self.grid.pr}x{self.grid.pc})")
        return diags

    def profile_rounds(self, values, *, chunk: int = 1, reps: int = 3,
                       dtype=jnp.float32, model=None):
        """Measured per-round timeline of this session's sweep: re-runs
        the overlapped schedule as per-round jitted segments with
        ``block_until_ready`` fencing and joins the walls against the
        plan's wire tables — residuals vs the α-β simulator, the
        per-rank inbound skew report, and best-fit α/β estimates.
        Returns a :class:`~repro.obs.rounds.RoundProfile`; see
        :func:`repro.obs.rounds.profile_rounds` for the knobs
        (``chunk`` coarsens to level-chunk segments, ``reps`` keeps the
        per-segment minimum). The replay runs the *same* device code as
        the fused sweep (bit-identical result, tested), so the timeline
        is a measurement, not an estimate."""
        from ..obs.rounds import profile_rounds
        return profile_rounds(self, values, chunk=chunk, reps=reps,
                              dtype=dtype, model=model)

    def stats(self, compile: bool = False) -> Dict[str, float]:
        """Static schedule metrics of the cached program: ppermute round
        count and peak per-device arena footprint (blocks). Stream
        sessions additionally report their executed wire traffic —
        ``stream_wire_bytes`` (physical permute bytes per sweep from the
        gated slot tables, padding included) and
        ``stream_shifts_per_round`` (mean gated permutes executed per
        comm round) — the two numbers the grid-factored encoding exists
        to shrink. The span-derived gauges ``last_solve_us`` /
        ``prepare_us`` report the most recent solve-dispatch and host
        value-prep walls (None until the session has solved/prepared).
        ``compile=True`` additionally reports compile metrics for the
        f32 single-matrix shape class (:meth:`compile_stats` —
        trace+lower / compile wall time, jaxpr line count, HLO text
        size), so the stream's compile-time/program-size win is
        inspectable straight off the session; call
        :meth:`compile_stats` directly for a batched or non-f32 class.
        Every scalar reported here is also published to the global
        metrics registry (``repro.obs.registry.REGISTRY``) under
        ``selinv_engine_*`` — the process-wide scrape surface."""
        ex = (self.program.overlap_plan if self.options.overlap
              else self.program.exec_plan)
        cls = type(self)
        out = {"ppermute_rounds": ppermute_round_count(ex),
               "peak_arena_blocks": peak_arena_blocks(ex),
               # structure-cache health (class-level, all sessions) +
               # this session's own table footprint — the serving
               # layer's warm-engine dashboard reads these
               "table_bytes": self.table_bytes(),
               "cache_engines": len(cls._cache),
               "cache_hits": cls.cache_hits,
               "cache_misses": cls.cache_misses,
               "cache_evictions": cls.cache_evictions,
               "solve_calls": self.solve_calls,
               "last_solve_us": self._last_solve_us,
               "prepare_us": self._last_prepare_us}
        if self.options.stream:
            from .stream import stream_shifts_per_round, stream_wire_bytes
            st = self.program.stream_tables
            out["stream_wire_bytes"] = stream_wire_bytes(st, self.b)
            out["stream_shifts_per_round"] = stream_shifts_per_round(st)
        if compile:
            # compile metrics require a live trace + XLA compile of the
            # session's sweep when this shape class was never measured
            # (a multi-second side effect, cached afterwards) — and a
            # cached session can outlive the device topology it was
            # analyzed under, so guard with the canonical device check
            # instead of dying deep inside shard_map
            check_grid_devices(self.grid.pr, self.grid.pc)
            out.update(self.compile_stats())
        for k, v in out.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                REGISTRY.gauge(f"selinv_engine_{k}",
                               "engine.stats() gauge").set(v)
        return out
