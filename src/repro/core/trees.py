"""Communication-tree construction for restricted collectives (paper §3).

The paper implements restricted (subset) broadcast / reduction with
asynchronous point-to-point messages routed along an explicit tree:

* ``FLAT``    — root sends ``p-1`` messages (PSelInv v0.7.3 baseline).
* ``BINARY``  — the ordered receiver list is split in halves recursively;
  the *first* rank of each half becomes an internal (forwarding) node.
* ``SHIFTED`` — a (pseudo-random, tag-seeded) circular shift is applied to
  the sorted receiver list before the binary construction, so that
  *concurrent* collectives pick different internal nodes (the paper's
  load-balancing heuristic).
* ``HYBRID``  — flat below a participant-count threshold (intra-node fast
  path, paper §4.2), shifted-binary above it.

The same :class:`CommTree` objects drive both the discrete-event network
simulator (`core/simulator.py`) and the executable ``ppermute`` lowering
(`comm/treecomm.py`), so the schedule that is *simulated* is the schedule
that *runs*.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Sequence, Tuple

__all__ = [
    "TreeKind",
    "CommTree",
    "flat_tree",
    "binary_tree",
    "shifted_binary_tree",
    "build_tree",
    "stable_hash",
]


class TreeKind(enum.Enum):
    FLAT = "flat"
    BINARY = "binary"
    SHIFTED = "shifted"
    HYBRID = "hybrid"


def stable_hash(*vals: int) -> int:
    """Deterministic 32-bit FNV-1a over integers (independent of
    PYTHONHASHSEED, stable across processes — required so that every rank
    of an SPMD program derives the *same* shift for the same collective)."""
    h = 2166136261
    for v in vals:
        for b in int(v).to_bytes(8, "little", signed=True):
            h ^= b
            h = (h * 16777619) & 0xFFFFFFFF
    return h


@dataclass(frozen=True)
class CommTree:
    """An explicit communication tree over integer ranks.

    ``children`` lists are *ordered*: a node forwards to its children one
    message per round, in order (each rank can source at most one
    point-to-point transfer per round — the ``collective-permute``
    constraint, and also how MPI_Isend progression was modeled in the
    paper's cost analysis).
    """

    root: int
    ranks: Tuple[int, ...]  # all participants, root included
    children: Tuple[Tuple[int, Tuple[int, ...]], ...]  # (rank, ordered kids)

    # -- derived ---------------------------------------------------------
    def children_map(self) -> Dict[int, Tuple[int, ...]]:
        return dict(self.children)

    def parent_map(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for p, kids in self.children:
            for k in kids:
                out[k] = p
        return out

    def messages_sent(self) -> Dict[int, int]:
        """Number of point-to-point messages each rank *sends* during a
        broadcast over this tree (== messages *received* during the mirrored
        reduction). This is the quantity behind the paper's Table 1."""
        return {p: len(kids) for p, kids in self.children if kids}

    def recv_round(self) -> Dict[int, int]:
        """Round at which each rank holds the data, under the one-message-
        per-round-per-sender schedule. root -> 0."""
        kmap = self.children_map()
        t: Dict[int, int] = {self.root: 0}
        stack = [self.root]
        while stack:
            u = stack.pop()
            for i, c in enumerate(kmap.get(u, ())):
                t[c] = t[u] + i + 1
                stack.append(c)
        return t

    def bcast_rounds(self) -> List[List[Tuple[int, int]]]:
        """Per-round (src, dst) edge lists for a broadcast. Round ``r``
        contains edges whose destination receives at round ``r+1``."""
        t = self.recv_round()
        nrounds = max(t.values(), default=0)
        rounds: List[List[Tuple[int, int]]] = [[] for _ in range(nrounds)]
        pmap = self.parent_map()
        for dst, r in t.items():
            if dst == self.root:
                continue
            rounds[r - 1].append((pmap[dst], dst))
        return rounds

    def reduce_rounds(self) -> List[List[Tuple[int, int]]]:
        """Per-round (src, dst) edge lists for the mirrored reduction
        (leaves send first; root combines last)."""
        return [[(d, s) for (s, d) in rnd] for rnd in reversed(self.bcast_rounds())]

    def depth(self) -> int:
        t = self.recv_round()
        return max(t.values(), default=0)

    def validate(self) -> None:
        """Every participant is reached exactly once; no cycles."""
        seen = {self.root}
        for p, kids in self.children:
            for k in kids:
                if k in seen:
                    raise ValueError(f"rank {k} reached twice")
                seen.add(k)
        if seen != set(self.ranks):
            raise ValueError(f"tree covers {sorted(seen)} != {sorted(self.ranks)}")


# -- construction ---------------------------------------------------------

def _binary_children(root: int, ordered: Sequence[int]) -> List[Tuple[int, Tuple[int, ...]]]:
    """Paper §3: repeatedly split the ordered receiver list in two halves;
    the first rank of each half is the internal node at the current level.

    Example (paper Fig. 3b): root=4, receivers [1,2,3,5,6] ->
    4 sends to 1 and 5; 1 sends to 2 and 3; 5 sends to 6.
    """
    out: Dict[int, List[int]] = {}

    def rec(local_root: int, lst: Sequence[int]) -> None:
        if not lst:
            return
        mid = (len(lst) + 1) // 2
        for half in (lst[:mid], lst[mid:]):
            if half:
                head = half[0]
                out.setdefault(local_root, []).append(head)
                rec(head, half[1:])

    rec(root, list(ordered))
    return [(p, tuple(kids)) for p, kids in out.items()]


def flat_tree(root: int, receivers: Sequence[int]) -> CommTree:
    recv = tuple(sorted(receivers))
    return CommTree(root=root, ranks=(root,) + recv,
                    children=((root, recv),) if recv else ())


def binary_tree(root: int, receivers: Sequence[int]) -> CommTree:
    recv = tuple(sorted(receivers))
    return CommTree(root=root, ranks=(root,) + recv,
                    children=tuple(_binary_children(root, recv)))


def shifted_binary_tree(root: int, receivers: Sequence[int], tag: int = 0,
                        shift: int | None = None) -> CommTree:
    """Binary tree over a circularly shifted receiver list (paper §3).

    ``shift`` may be given explicitly; otherwise it is derived from a
    stable hash of ``(root, tag)`` — deterministic, but decorrelated across
    collectives so concurrent trees pick different internal nodes.
    """
    recv = tuple(sorted(receivers))
    if not recv:
        return CommTree(root=root, ranks=(root,), children=())
    s = (stable_hash(root, tag) if shift is None else shift) % len(recv)
    rotated = recv[s:] + recv[:s]
    return CommTree(root=root, ranks=(root,) + recv,
                    children=tuple(_binary_children(root, rotated)))


#: Participant-count threshold below which HYBRID uses a flat tree
#: (paper §4.2: intra-node shared-memory message passing is cheap and a
#: single send buffer improves cache reuse; Edison nodes had 24 cores).
HYBRID_FLAT_MAX = 24


def build_tree(kind: TreeKind, root: int, receivers: Sequence[int],
               tag: int = 0, shift: int | None = None) -> CommTree:
    if kind is TreeKind.FLAT:
        return flat_tree(root, receivers)
    if kind is TreeKind.BINARY:
        return binary_tree(root, receivers)
    if kind is TreeKind.SHIFTED:
        return shifted_binary_tree(root, receivers, tag=tag, shift=shift)
    if kind is TreeKind.HYBRID:
        if len(receivers) + 1 <= HYBRID_FLAT_MAX:
            return flat_tree(root, receivers)
        return shifted_binary_tree(root, receivers, tag=tag, shift=shift)
    raise ValueError(f"unknown tree kind {kind!r}")


@lru_cache(maxsize=200_000)
def cached_tree(kind: str, root: int, receivers: Tuple[int, ...], tag: int) -> CommTree:
    """Memoized construction keyed on structure — PSelInv re-issues many
    collectives with identical participant sets; the simulator exploits
    this heavily."""
    return build_tree(TreeKind(kind), root, receivers, tag=tag)
