"""PSelInv communication schedule on a 2-D block-cyclic processor grid.

Derives, from a :class:`BlockStructure`, the exact set of restricted
collectives PSelInv issues (paper §2.2/§3, Fig. 2):

* ``diag-bcast``  (step a of loop 1): owner of L(K,K) → owners of blocks
  L(I,K) within the processor-*column* group of supernode K.
* ``xfer``        (step a, Fig. 2): point-to-point L̂(I,K) → owner of
  Û(K,I) (the symmetric-transpose handoff).
* ``col-bcast``   (paper "Col-Bcast"): owner of Û(K,I) → owners of
  A⁻¹(J,I), J ∈ struct(K) — a *subset* of a grid-column group.
* ``row-reduce``  (paper "Row-Reduce"): partial products A⁻¹(J,I)·L̂(I,K)
  reduced onto the owner of A⁻¹(J,K) — a *subset* of a grid-row group.

Block (I,J) is owned by grid processor (I mod Pr, J mod Pc) with rank
``row·Pc + col`` (SuperLU_DIST layout). Bytes assume float64.

This module is the *enumeration front-end* of the CommPlan IR
(`core/plan.py`): it decides **what** must be communicated;
:func:`~.plan.build_plan` lowers these events — once, for every consumer
— into concrete trees and executable rounds. Do not derive trees or
rounds anywhere else.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Tuple

import numpy as np

from .symbolic import BlockStructure

__all__ = ["Grid2D", "CommEvent", "ComputeTask", "pselinv_events",
           "pselinv_supernode_program"]

BYTES_PER_ELT = 8.0


@dataclass(frozen=True)
class Grid2D:
    pr: int
    pc: int

    @property
    def size(self) -> int:
        return self.pr * self.pc

    def owner(self, I: int, J: int) -> int:
        return (I % self.pr) * self.pc + (J % self.pc)

    def rank_of(self, prow: int, pcol: int) -> int:
        return prow * self.pc + pcol

    def coords(self, rank: int) -> Tuple[int, int]:
        return rank // self.pc, rank % self.pc


@dataclass(frozen=True)
class CommEvent:
    """One restricted collective: broadcast from / reduction onto ``root``
    among ``participants`` (global ranks, root included), ``nbytes`` per
    edge message. ``tag`` seeds the shifted-tree rotation. ``supernode``
    links the event to its position in the elimination-tree pipeline."""
    kind: str                      # "diag-bcast" | "xfer" | "col-bcast" | "row-reduce"
    supernode: int
    root: int
    participants: Tuple[int, ...]  # sorted, root included
    nbytes: float
    tag: int
    # index of the supernode whose A⁻¹ data this event consumes (dependency)
    consumes: int = -1
    # supernode index of the block the event's payload carries (I for
    # xfer/col-bcast, J for row-reduce, K for diag-bcast) — the CommPlan
    # executor uses it to derive gather/scatter slots
    block: int = -1


@dataclass(frozen=True)
class ComputeTask:
    """Local dense work attributed to one rank at one supernode step."""
    kind: str          # "trsm" | "gemm" | "diag"
    supernode: int
    rank: int
    flops: float


def _col_group_rows(grid: Grid2D, rows: List[int], pcol: int) -> Tuple[int, ...]:
    return tuple(sorted({grid.rank_of(r % grid.pr, pcol) for r in rows}))


def pselinv_events(bs: BlockStructure, grid: Grid2D
                   ) -> Tuple[List[CommEvent], List[ComputeTask]]:
    """Materialize every restricted collective + compute task of one
    selected-inversion pass (both Alg. 1 loops)."""
    w = bs.widths()
    events: List[CommEvent] = []
    tasks: List[ComputeTask] = []
    nb = bs.nsuper

    for K in range(nb):
        C = [int(i) for i in bs.struct[K]]
        wk = int(w[K])
        kcol = K % grid.pc
        krow = K % grid.pr

        # ---- loop 1: diagonal-block broadcast + local TRSMs ------------
        if C:
            parts = _col_group_rows(grid, C + [K], kcol)
            root = grid.owner(K, K)
            if len(parts) > 1:
                events.append(CommEvent(
                    "diag-bcast", K, root, parts,
                    nbytes=wk * wk * BYTES_PER_ELT,
                    tag=(K << 1) | 0, consumes=-1, block=K))
            for I in C:
                tasks.append(ComputeTask(
                    "trsm", K, grid.owner(I, K),
                    flops=float(w[I]) * wk * wk))

        # ---- loop 2 ----------------------------------------------------
        # xfer: L̂(I,K) -> owner of Û(K,I)   (transpose handoff, p2p)
        for I in C:
            src = grid.owner(I, K)
            dst = grid.owner(K, I)
            if src != dst:
                events.append(CommEvent(
                    "xfer", K, src, tuple(sorted({src, dst})),
                    nbytes=float(w[I]) * wk * BYTES_PER_ELT,
                    tag=(K << 20) ^ I, consumes=-1, block=I))

        # col-bcast: Û(K,I) broadcast down grid-column (I mod Pc) to the
        # owners of A⁻¹(J,I) for J in C
        for I in C:
            root = grid.owner(K, I)
            parts = tuple(sorted(
                {root} | {grid.owner(J, I) for J in C}))
            if len(parts) > 1:
                events.append(CommEvent(
                    "col-bcast", K, root, parts,
                    nbytes=float(w[I]) * wk * BYTES_PER_ELT,
                    tag=(K << 20) ^ (I << 1), consumes=I, block=I))
            # local GEMM at each owner of A⁻¹(J,I): (wJ x wI) @ (wI x wK)
            for J in C:
                tasks.append(ComputeTask(
                    "gemm", K, grid.owner(J, I),
                    flops=2.0 * float(w[J]) * float(w[I]) * wk))

        # row-reduce: Σ_I A⁻¹(J,I)·L̂(I,K) onto owner of A⁻¹(J,K),
        # within grid-row (J mod Pr)
        for J in C:
            root = grid.owner(J, K)
            parts = tuple(sorted(
                {root} | {grid.owner(J, I) for I in C}))
            if len(parts) > 1:
                events.append(CommEvent(
                    "row-reduce", K, root, parts,
                    nbytes=float(w[J]) * wk * BYTES_PER_ELT,
                    tag=(K << 20) ^ (J << 1) ^ 1, consumes=-1, block=J))

        # step 4/5 local work on the diagonal/row owners
        csum = float(sum(w[i] for i in C))
        tasks.append(ComputeTask(
            "diag", K, grid.owner(K, K),
            flops=2.0 * wk * wk * max(csum, 1.0) + 2.0 * wk ** 3))

    return events, tasks


def pselinv_supernode_program(bs: BlockStructure, grid: Grid2D,
                              kind=None):
    """Ops/tasks grouped per supernode, in *reverse* elimination order
    (the selected-inversion sweep), with the etree dependency:
    supernode K may start once every I ∈ struct(K) has finished.
    Yields (K, deps, ops_K, tasks_K) — ops are the CommPlan IR's
    :class:`~.plan.PlanOp` (tree kind defaults to SHIFTED)."""
    from .plan import build_plan          # lazy: plan builds on this module
    from .trees import TreeKind
    plan = build_plan(bs, grid, kind or TreeKind.SHIFTED)
    by_sn_e = plan.ops_by_supernode()
    by_sn_t: dict[int, list] = {}
    for t in plan.tasks:
        by_sn_t.setdefault(t.supernode, []).append(t)
    for K in range(bs.nsuper - 1, -1, -1):
        deps = [int(i) for i in bs.struct[K]]
        yield K, deps, by_sn_e.get(K, []), by_sn_t.get(K, [])
