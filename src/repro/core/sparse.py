"""Synthetic structured sparse matrices + orderings.

The paper evaluates on two matrices we cannot redistribute offline:

* ``DG_PNF14000`` — Kohn-Sham Hamiltonian of a 2-D phosphorene nanoflake
  (14,000 atoms, adaptive-local-basis DG discretization). N = 512,000 with
  0.2% nnz: *block-dense* — each atom/element carries a dense basis block
  (~37 columns) coupled to its 2-D lattice neighbours.
* ``audikw_1`` — 3-D FEM (UF collection), N = 943,695, 0.009% nnz.

We generate structure-faithful stand-ins: a 2-D lattice of dense
element-blocks ("dg_like") and a 3-D 27-point stencil grid ("fem3d_like"),
both ordered by geometric nested dissection (the ordering SuperLU_DIST
would get from METIS on these geometries). Generators return scipy CSR
structure; numerics helpers make them diagonally dominant so unpivoted
supernodal LU is stable (PSelInv consumes a static-pivoting SuperLU_DIST
factorization — same regime).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np
import scipy.sparse as sp

__all__ = [
    "grid_graph_2d", "grid_graph_3d", "nested_dissection_grid",
    "dg_like_matrix", "fem3d_like_matrix", "laplacian_2d",
    "make_numeric", "MatrixSuite", "PAPER_SUITE",
]


# -- geometric nested dissection -----------------------------------------

def nested_dissection_grid(dims: Sequence[int], leaf: int = 2) -> np.ndarray:
    """Geometric nested-dissection permutation of an n-D grid.

    Recursively splits the longest axis with a one-plane separator;
    separator nodes are ordered *last* (eliminated last => they form the
    top supernodes / etree root path, exactly the structure PSelInv's
    communication pattern feeds on).
    Returns ``perm`` with ``perm[new_index] = old_index``.
    """
    dims = tuple(int(d) for d in dims)
    idx = np.arange(int(np.prod(dims))).reshape(dims)

    def rec(block: np.ndarray) -> List[int]:
        shape = block.shape
        axis = int(np.argmax(shape))
        n = shape[axis]
        if n <= leaf or block.size <= leaf ** len(dims):
            return block.reshape(-1).tolist()
        mid = n // 2
        sl_lo = [slice(None)] * len(shape)
        sl_sep = [slice(None)] * len(shape)
        sl_hi = [slice(None)] * len(shape)
        sl_lo[axis] = slice(0, mid)
        sl_sep[axis] = slice(mid, mid + 1)
        sl_hi[axis] = slice(mid + 1, n)
        lo = rec(block[tuple(sl_lo)])
        hi = rec(block[tuple(sl_hi)])
        sep = block[tuple(sl_sep)].reshape(-1).tolist()
        return lo + hi + sep

    return np.asarray(rec(idx), dtype=np.int64)


def grid_graph_2d(nx: int, ny: int, stencil: int = 5,
                  radius: int = 1) -> sp.csr_matrix:
    """Structure of a 2-D grid graph (5-/9-point stencil, or a dense
    radius-r neighbourhood for DG-like strongly-coupled lattices)."""
    n = nx * ny
    ii: List[int] = []
    jj: List[int] = []
    if radius > 1:
        offs = [(dx, dy) for dx in range(-radius, radius + 1)
                for dy in range(-radius, radius + 1)
                if dx * dx + dy * dy <= radius * radius]
    elif stencil == 5:
        offs = [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)]
    else:
        offs = [(dx, dy) for dx in (-1, 0, 1) for dy in (-1, 0, 1)]
    X, Y = np.meshgrid(np.arange(nx), np.arange(ny), indexing="ij")
    X = X.ravel(); Y = Y.ravel()
    for dx, dy in offs:
        Xn, Yn = X + dx, Y + dy
        ok = (Xn >= 0) & (Xn < nx) & (Yn >= 0) & (Yn < ny)
        ii.append((X[ok] * ny + Y[ok]))
        jj.append((Xn[ok] * ny + Yn[ok]))
    i = np.concatenate(ii); j = np.concatenate(jj)
    return sp.csr_matrix((np.ones_like(i, dtype=np.int8), (i, j)), shape=(n, n))


def grid_graph_3d(nx: int, ny: int, nz: int, stencil: int = 27) -> sp.csr_matrix:
    n = nx * ny * nz
    if stencil == 7:
        offs = [(0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
                (0, 0, 1), (0, 0, -1)]
    else:
        offs = [(a, b, c) for a in (-1, 0, 1) for b in (-1, 0, 1)
                for c in (-1, 0, 1)]
    X, Y, Z = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz),
                          indexing="ij")
    X = X.ravel(); Y = Y.ravel(); Z = Z.ravel()
    ii: List[np.ndarray] = []
    jj: List[np.ndarray] = []
    for dx, dy, dz in offs:
        Xn, Yn, Zn = X + dx, Y + dy, Z + dz
        ok = ((Xn >= 0) & (Xn < nx) & (Yn >= 0) & (Yn < ny)
              & (Zn >= 0) & (Zn < nz))
        ii.append(X[ok] * ny * nz + Y[ok] * nz + Z[ok])
        jj.append(Xn[ok] * ny * nz + Yn[ok] * nz + Zn[ok])
    i = np.concatenate(ii); j = np.concatenate(jj)
    return sp.csr_matrix((np.ones_like(i, dtype=np.int8), (i, j)), shape=(n, n))


def _permute(A: sp.csr_matrix, perm: np.ndarray) -> sp.csr_matrix:
    """Symmetric permutation: B = A[perm][:, perm]."""
    return A[perm][:, perm].tocsr()


# -- paper-matrix stand-ins ----------------------------------------------

def dg_like_structure(atoms_x: int = 12, atoms_y: int = 12,
                      block: int = 8,
                      radius: int = 3) -> Tuple[sp.csr_matrix, np.ndarray]:
    """Element graph of the DG_PNF14000 stand-in: 2-D lattice of atoms,
    each a dense basis block of ``block`` columns, radius-3 neighbour
    coupling (the adaptive-local-basis DG Hamiltonian is *relatively
    dense* — each element couples tens of neighbours)."""
    G = grid_graph_2d(atoms_x, atoms_y, radius=radius)
    perm = nested_dissection_grid((atoms_x, atoms_y))
    G = _permute(G, perm)
    sizes = np.full(atoms_x * atoms_y, block, dtype=np.int64)
    return G, sizes


def fem3d_like_structure(nx: int = 12, ny: int = 12, nz: int = 12,
                         block: int = 3) -> Tuple[sp.csr_matrix, np.ndarray]:
    """Element graph of the audikw_1 stand-in: 3-D solid-mechanics mesh,
    27-point coupling, ``block`` dof per node (audikw_1 has 3 displacement
    dof)."""
    G = grid_graph_3d(nx, ny, nz, stencil=27)
    perm = nested_dissection_grid((nx, ny, nz))
    G = _permute(G, perm)
    sizes = np.full(nx * ny * nz, block, dtype=np.int64)
    return G, sizes


def dg_like_matrix(atoms_x: int = 12, atoms_y: int = 12,
                   block: int = 8) -> Tuple[sp.csr_matrix, np.ndarray]:
    """Scalar (kron-expanded) pattern of the DG stand-in, for numerics."""
    G, sizes = dg_like_structure(atoms_x, atoms_y, block)
    A = sp.kron(G, np.ones((block, block), dtype=np.int8), format="csr")
    return A, sizes


def fem3d_like_matrix(nx: int = 12, ny: int = 12, nz: int = 12,
                      block: int = 3) -> Tuple[sp.csr_matrix, np.ndarray]:
    """Scalar (kron-expanded) pattern of the FEM stand-in, for numerics."""
    G, sizes = fem3d_like_structure(nx, ny, nz, block)
    A = sp.kron(G, np.ones((block, block), dtype=np.int8), format="csr")
    return A, sizes


def laplacian_2d(nx: int, ny: int, nd_order: bool = True) -> sp.csr_matrix:
    """Numeric 2-D Laplacian (SPD), optionally ND-ordered — the small
    correctness workhorse for the LU/SelInv tests."""
    n = nx * ny
    S = grid_graph_2d(nx, ny, stencil=5)
    if nd_order:
        S = _permute(S, nested_dissection_grid((nx, ny)))
    A = S.astype(np.float64)
    A.setdiag(0.0)
    A.eliminate_zeros()
    A = -A
    deg = -np.asarray(A.sum(axis=1)).ravel()
    A = A + sp.diags(deg + 4.0)
    return A.tocsr()


def make_numeric(struct: sp.csr_matrix, seed: int = 0,
                 symmetric_values: bool = False) -> sp.csr_matrix:
    """Fill a structure with random values, strongly diagonally dominant
    (=> unpivoted LU is stable; mirrors SuperLU_DIST static pivoting)."""
    rng = np.random.default_rng(seed)
    A = struct.astype(np.float64).tocsr().copy()
    A.data = rng.uniform(-1.0, 1.0, size=A.nnz)
    if symmetric_values:
        A = (A + A.T) * 0.5
    rowsum = np.abs(A).sum(axis=1)
    A = A + sp.diags(np.asarray(rowsum).ravel() + 1.0)
    return A.tocsr()


# -- named suite -----------------------------------------------------------

@dataclass(frozen=True)
class MatrixSuite:
    name: str
    kind: str          # "dg_like" | "fem3d_like"
    params: tuple      # generator args
    description: str

    def build(self) -> Tuple[sp.csr_matrix, np.ndarray]:
        if self.kind == "dg_like":
            return dg_like_matrix(*self.params)
        if self.kind == "fem3d_like":
            return fem3d_like_matrix(*self.params)
        raise ValueError(self.kind)


#: Benchmark-scale stand-ins (structure only; sized so the discrete-event
#: simulator finishes in minutes on one CPU while preserving the papers'
#: dense-vs-sparse contrast).
PAPER_SUITE = {
    # relatively dense block structure, large supernodes, comm-volume bound
    "dg_small":   MatrixSuite("dg_small", "dg_like", (10, 10, 6),
                              "DG nanoflake-like, tiny (tests)"),
    "dg_bench":   MatrixSuite("dg_bench", "dg_like", (26, 26, 12),
                              "DG nanoflake-like, bench scale"),
    # sparser 3-D FEM: comm/compute ratio bound
    "fem_small":  MatrixSuite("fem_small", "fem3d_like", (6, 6, 6, 3),
                              "audikw-like, tiny (tests)"),
    "fem_bench":  MatrixSuite("fem_bench", "fem3d_like", (14, 14, 14, 3),
                              "audikw-like, bench scale"),
}
