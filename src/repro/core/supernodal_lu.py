"""Supernodal (block) sparse LU factorization — the PSelInv pre-step.

PSelInv consumes an unpivoted supernodal LU (SuperLU_DIST with static
pivoting). We factorize right-looking at the supernode-block level over
the filled structure from :mod:`repro.core.symbolic`.

Block math runs through a pluggable backend:

* ``numpy``  — plain BLAS, the orchestration default,
* ``jax``    — jnp ops under jit (shape-keyed cache; supernodal codes
  re-use few distinct block shapes so the cache hit-rate is high),
* ``pallas`` — jax backend with the Pallas ``block_gemm``/``trsm`` kernels
  (interpret mode on CPU; compiled on TPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, Tuple

import numpy as np
import scipy.sparse as sp

from .symbolic import BlockStructure, symbolic_factorize

__all__ = ["LUFactors", "factorize", "get_backend", "dense_lu_nopivot"]

Key = Tuple[int, int]


# -- backends ---------------------------------------------------------------

class _NumpyBackend:
    name = "numpy"

    @staticmethod
    def gemm(acc, a, b, alpha=-1.0):
        return acc + alpha * (a @ b)

    @staticmethod
    def matmul(a, b):
        return a @ b

    @staticmethod
    def solve_tri_right_upper(b, u):
        """X U = B  (U upper)."""
        import scipy.linalg as sla
        return sla.solve_triangular(u, b.T, lower=False, trans="T").T

    @staticmethod
    def solve_tri_left_unit_lower(l, b):
        """L X = B  (L unit lower)."""
        import scipy.linalg as sla
        return sla.solve_triangular(l, b, lower=True, unit_diagonal=True)

    @staticmethod
    def asarray(x):
        return np.asarray(x, dtype=np.float64)


class _JaxBackend:
    name = "jax"

    def __init__(self):
        import jax
        import jax.numpy as jnp
        import jax.scipy.linalg as jla
        self._jnp = jnp
        self._dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
        self._gemm = jax.jit(lambda acc, a, b: acc - a @ b)
        self._matmul = jax.jit(lambda a, b: a @ b)
        self._solve_ru = jax.jit(
            lambda b, u: jla.solve_triangular(u.T, b.T, lower=True).T)
        self._solve_ll = jax.jit(
            lambda l, b: jla.solve_triangular(l, b, lower=True,
                                              unit_diagonal=True))

    def gemm(self, acc, a, b, alpha=-1.0):
        if alpha != -1.0:
            raise ValueError(f"gemm supports only alpha=-1.0 (the "
                             f"Schur-update sign), got {alpha}")
        return self._gemm(acc, a, b)

    def matmul(self, a, b):
        return self._matmul(a, b)

    def solve_tri_right_upper(self, b, u):
        return self._solve_ru(b, u)

    def solve_tri_left_unit_lower(self, l, b):
        return self._solve_ll(l, b)

    def asarray(self, x):
        return self._jnp.asarray(x, dtype=self._dtype)


class _PallasBackend(_JaxBackend):
    """JAX backend with Pallas kernels for the GEMM hot spot."""
    name = "pallas"

    def __init__(self):
        super().__init__()
        from repro.kernels import ops as kops
        self._kops = kops

    def gemm(self, acc, a, b, alpha=-1.0):
        if alpha != -1.0:
            raise ValueError(f"gemm supports only alpha=-1.0 (the "
                             f"Schur-update sign), got {alpha}")
        return self._kops.block_gemm_acc(acc, a, b, alpha=-1.0)

    def matmul(self, a, b):
        return self._kops.block_gemm(a, b)


_BACKENDS: Dict[str, Callable[[], object]] = {
    "numpy": _NumpyBackend,
    "jax": _JaxBackend,
    "pallas": _PallasBackend,
}
_CACHE: Dict[str, object] = {}


def get_backend(name: str):
    if name not in _CACHE:
        _CACHE[name] = _BACKENDS[name]()
    return _CACHE[name]


# -- dense unpivoted LU -------------------------------------------------------

def dense_lu_nopivot(a: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Doolittle LU without pivoting: A = L U, L unit-lower.
    Stable for the diagonally-dominant blocks we feed it (static pivoting
    regime, as in SuperLU_DIST under PSelInv)."""
    a = np.array(a, dtype=np.float64, copy=True)
    n = a.shape[0]
    for k in range(n - 1):
        piv = a[k, k]
        a[k + 1:, k] /= piv
        a[k + 1:, k + 1:] -= np.outer(a[k + 1:, k], a[k, k + 1:])
    L = np.tril(a, -1) + np.eye(n)
    U = np.triu(a)
    return L, U


# -- factorization ------------------------------------------------------------

@dataclass
class LUFactors:
    bs: BlockStructure
    Ldiag: Dict[int, np.ndarray]      # unit-lower diagonal factors
    Udiag: Dict[int, np.ndarray]      # upper diagonal factors
    L: Dict[Key, np.ndarray]          # off-diag L(I,K), I > K
    U: Dict[Key, np.ndarray]          # off-diag U(K,J), J > K
    backend: str = "numpy"

    def nnz_blocks(self) -> int:
        return len(self.L) + len(self.U) + len(self.Ldiag) * 2


def _get_block(A: sp.csr_matrix, bs: BlockStructure, I: int, J: int) -> np.ndarray:
    r0, r1 = bs.offsets[I], bs.offsets[I + 1]
    c0, c1 = bs.offsets[J], bs.offsets[J + 1]
    return np.asarray(A[r0:r1, c0:c1].todense(), dtype=np.float64)


def factorize(A: sp.spmatrix, bs: BlockStructure | None = None,
              max_supernode: int = 32, backend: str = "numpy") -> LUFactors:
    """Right-looking supernodal LU over the filled block structure."""
    A = sp.csr_matrix(A)
    if bs is None:
        bs = symbolic_factorize(A, max_supernode=max_supernode)
    be = get_backend(backend)
    nb = bs.nsuper

    # working Schur storage, lazily initialized from A
    work: Dict[Key, np.ndarray] = {}

    def load(I: int, J: int):
        key = (I, J)
        if key not in work:
            work[key] = be.asarray(_get_block(A, bs, I, J))
        return work[key]

    Ldiag: Dict[int, np.ndarray] = {}
    Udiag: Dict[int, np.ndarray] = {}
    L: Dict[Key, np.ndarray] = {}
    U: Dict[Key, np.ndarray] = {}

    for K in range(nb):
        lkk, ukk = dense_lu_nopivot(np.asarray(load(K, K)))
        Ldiag[K] = be.asarray(lkk)
        Udiag[K] = be.asarray(ukk)
        C = bs.struct[K]
        for I in C:
            I = int(I)
            L[(I, K)] = be.solve_tri_right_upper(load(I, K), Udiag[K])
            U[(K, I)] = be.solve_tri_left_unit_lower(Ldiag[K], load(K, I))
        # Schur complement update over the clique struct(K) x struct(K)
        for I in C:
            I = int(I)
            lik = L[(I, K)]
            for J in C:
                J = int(J)
                work[(I, J)] = be.gemm(load(I, J), lik, U[(K, int(J))])

    return LUFactors(bs=bs, Ldiag=Ldiag, Udiag=Udiag, L=L, U=U,
                     backend=backend)
