"""Stream lowering — the uniform round-stream form of the overlapped
sweep, executable as ONE ``lax.fori_loop`` body.

The overlapped executor (``plan.schedule_overlapped`` +
``pselinv_dist.make_sweep_overlapped``) replays its global
:class:`~.plan.GlobalRound` list by unrolling a Python loop: every round
contributes its own ``lax.ppermute`` (a *static* perm) plus per-round
gather/scatter constants, so jaxpr/HLO size and trace+compile time grow
linearly with the round count — the binding constraint on scaling ``nb``
and grid size. This module lowers a compiled :class:`~.plan.OverlappedExec`
once more, into **uniform-width, round-indexed device tables**
(:class:`StreamTables`): every per-round quantity is stacked on a leading
round axis and padded to the stream-wide maximum width, so a single loop
body driven by ``dynamic_slice`` on the round axis executes the entire
sweep — comm lanes, owner-local copies, and the level GEMM / write /
S-einsum / diagonal phases behind per-round phase flags.

**Permute encoding (the one static-shape obstacle).** ``lax.ppermute``
takes a static perm, but the overlapped stream's perm differs per round.
The encoding chosen here composes each round from a small fixed set of
**ring shifts**: within one round every device sends to at most one
destination and receives from at most one source (the ppermute
constraint), so each (src, dst) pair belongs to exactly one ring offset
``(dst - src) mod P``, a round is a disjoint union of subsets of the
``len(shifts)`` full-ring permutes (one per offset *used anywhere* in
the stream), and — crucially — the per-round lane tables collapse to
``[round, device, lane]``, not ``[round, shift, device, lane]``: a
device gathers its one outgoing lane stack, ships it on *every* shift's
ring permute, and each receiver keeps only the arrival of its one
receive shift (``recv_shift``) and scatters it once — the same
gather-snapshot → permute → scatter semantics as the unrolled round,
hence bit-identical (padded lanes scatter into the trash block exactly
like the unrolled executor's coalescing padding). The tradeoff
(recorded in the ROADMAP PR-5 note): the loop body issues
``len(shifts)`` permutes per round instead of one, shipping every
device's payload on every shift — more wire bytes per executed round —
in exchange for a program whose size is **independent of the round
count** (the tables are data, not code). Byte *accounting* stays at the
algorithmic-lane level, exactly as the overlapped stream's (padded
lanes of a coalesced permute were never counted either):
``simulator.round_schedule_from_stream`` derives the timeline from the
same real lanes, so simulated bytes still equal executed bytes.

**Compute encoding.** Round boundary ``t`` fires the compute ops the
dependence scheduler pinned there (``OverlappedExec.compute_at[t]``, in
dependence order). The stream gives every boundary the same fixed number
of compute *slots* (the stream-wide maximum); each slot holds a
(kind, level) pair — kind 0 is a no-op — dispatched through one
``lax.switch`` whose branches dynamic-index **level-stacked** mask/index
tables padded to the widest level ``NK``. Padded supernode rows carry a
zero struct mask (their GEMM/S rows compute exact zeros into the shared
partial/S regions' tail, which only the masked readers ever touch) and
their diagonal lanes target the trash block, so padding is numerically
inert — the executed arithmetic on real rows is the unrolled executor's,
value for value.

The lowering is pure host-side table construction (numpy); the executor
lives in ``pselinv_dist.make_sweep_stream`` and the end-to-end wiring in
``PlanOptions(stream=True)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from .plan import OverlappedExec, peak_arena_blocks

__all__ = ["COMP_NOOP", "COMP_GEMM", "COMP_WRITE", "COMP_SCOMP",
           "COMP_DIAGW", "COMP_KIND_ID", "StreamTables", "lower_stream",
           "decode_round_lanes", "decode_local_lanes"]

#: compute-slot kind ids of the per-boundary phase flags (0 = no-op slot)
COMP_NOOP, COMP_GEMM, COMP_WRITE, COMP_SCOMP, COMP_DIAGW = range(5)
COMP_KIND_ID: Dict[str, int] = {"gemm": COMP_GEMM, "write": COMP_WRITE,
                                "scomp": COMP_SCOMP, "diagw": COMP_DIAGW}


@dataclass
class StreamTables:
    """The uniform round-stream compilation of one overlapped sweep:
    every per-round table of the :class:`~.plan.GlobalRound` list stacked
    on a leading round axis (padded to the stream-wide widths), plus the
    level compute tables stacked on a level axis (padded to ``NK``).

    Geometry mirrors :class:`~.plan.OverlappedExec` (same arena layout,
    same trash block, same shared partial/S regions at ``base_p`` /
    ``base_s`` — asserted identical across levels at lowering time).
    ``shifts`` is the static ring-offset set. Comm tables are indexed
    ``[round, device, lane]`` — NOT per shift: within one round a device
    sends on at most one shift and receives on at most one (the ppermute
    constraint), so the sender tables (``gather``/``glh``) describe the
    device's single outgoing lane stack (shipped on *every* shift's ring
    permute — only the true destination keeps it), ``recv_shift`` names
    the one shift a device receives on (-1 = none), and the receiver
    tables (``scatter``/``addm``/``tmask``) describe where that single
    arrival lands. A lane is *real* iff its receiver scatter slot is not
    the trash block.
    ``comp_kind``/``comp_level`` hold each boundary's compute slots in
    dependence order (:data:`COMP_KIND_ID`; 0-filled tails are no-ops).
    ``steps = nrounds + 1`` is the ``fori_loop`` trip count — the final
    iteration runs only the last boundary's compute (its comm tables are
    all-trash no-ops).

    ``lane_edges``/``lmoves``/``level_Ks``/``peak_blocks`` are host-side
    metadata for byte accounting and the replay tests — never shipped to
    the device."""
    nb: int
    pr: int
    pc: int
    n_ainv: int
    arena_blocks: int
    trash: int
    base_p: int
    base_s: int
    nrounds: int
    steps: int
    shifts: Tuple[int, ...]
    W: int                         # comm lane width (max over rounds)
    LW: int                        # owner-local lane width
    C: int                         # compute slots per boundary
    NK: int                        # widest level's supernode count
    window: int | None
    peak_blocks: int
    diag_set_root: np.ndarray
    diag_set_slot: np.ndarray
    # ---- (steps, P, W) comm lane tables + (steps, P) receive shift ----
    gather: np.ndarray
    scatter: np.ndarray
    addm: np.ndarray
    tmask: np.ndarray
    glh: np.ndarray
    recv_shift: np.ndarray
    # ---- (steps, P, LW) owner-local lane tables -----------------------
    lgather: np.ndarray
    lscatter: np.ndarray
    ltmask: np.ndarray
    lglh: np.ndarray
    # ---- (steps, C) compute phase flags -------------------------------
    comp_kind: np.ndarray
    comp_level: np.ndarray
    # ---- (nlev, ...) level compute tables padded to NK ----------------
    u_gather: np.ndarray           # (nlev, P, NK*nbc), trash-padded
    cmask: np.ndarray              # (nlev, pc, NK, nbc), zero-padded
    kcs: np.ndarray                # (nlev, NK)
    krs: np.ndarray                # (nlev, NK)
    col_write_row: np.ndarray      # (nlev, pr, NK, nbr)
    col_write_col: np.ndarray      # (nlev, pc, NK)
    diag_rowmask: np.ndarray       # (nlev, pr, NK)
    diag_root: np.ndarray          # (nlev, NK), -1-padded (matches no id)
    diag_slot: np.ndarray          # (nlev, NK), trash-padded
    # ---- host-side metadata (accounting / replay tests) ---------------
    level_Ks: List[np.ndarray] = field(default_factory=list)
    lane_edges: List[List[Tuple[int, int, str, int, float]]] = \
        field(default_factory=list)
    lmoves: List[List[Tuple[int, str, int]]] = field(default_factory=list)

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc

    @property
    def nlev(self) -> int:
        return len(self.level_Ks)


def lower_stream(ov: OverlappedExec) -> StreamTables:
    """Lower a compiled overlapped round stream into the uniform
    round-indexed device tables of :class:`StreamTables`.

    Pure table construction: the stream replays the *identical* round
    order, lane order, and accumulation order as the unrolled
    :class:`~.plan.GlobalRound` list (the replay property test in
    ``tests/test_stream.py`` proves it round-for-round), so the executed
    f64 output is bit-identical to ``make_sweep_overlapped``'s."""
    P = ov.pr * ov.pc
    nrounds = len(ov.rounds)
    steps = nrounds + 1
    shifts = tuple(sorted({(d - s) % P
                           for rnd in ov.rounds for (s, d) in rnd.perm}))
    if 0 in shifts:
        raise ValueError("overlapped stream contains a self-edge "
                         "(src == dst) — those must be owner-local lanes")
    sidx = {delta: i for i, delta in enumerate(shifts)}
    S = len(shifts)
    W = max((rnd.width for rnd in ov.rounds), default=0)
    LW = max((rnd.lwidth for rnd in ov.rounds), default=0)
    C = max((len(ops) for ops in ov.compute_at), default=0)
    trash = ov.trash

    gather = np.zeros((steps, P, W), np.int32)
    scatter = np.full((steps, P, W), trash, np.int32)
    addm = np.zeros((steps, P, W), np.float32)
    tmask = np.zeros((steps, P, W), bool)
    glh = np.zeros((steps, P, W), bool)
    recv_shift = np.full((steps, P), -1, np.int32)
    lgather = np.zeros((steps, P, LW), np.int32)
    lscatter = np.full((steps, P, LW), trash, np.int32)
    ltmask = np.zeros((steps, P, LW), bool)
    lglh = np.zeros((steps, P, LW), bool)

    for t, rnd in enumerate(ov.rounds):
        for (s, d) in rnd.perm:
            # the ppermute constraint (unique sources / destinations per
            # round) is what makes the collapsed [round, device, lane]
            # layout lossless: one outgoing stack, one receive shift
            if recv_shift[t, d] != -1:
                raise ValueError(
                    f"round {t}: device {d} receives twice — the "
                    "overlapped round violates the ppermute constraint")
            w = rnd.width
            gather[t, s, :w] = rnd.gather[s]
            glh[t, s, :w] = rnd.glh[s]
            scatter[t, d, :w] = rnd.scatter[d]
            addm[t, d, :w] = rnd.addm[d]
            tmask[t, d, :w] = rnd.tmask[d]
            recv_shift[t, d] = sidx[(d - s) % P]
        if rnd.lwidth:
            lw = rnd.lwidth
            lgather[t, :, :lw] = rnd.lgather
            lscatter[t, :, :lw] = rnd.lscatter
            ltmask[t, :, :lw] = rnd.ltmask
            lglh[t, :, :lw] = rnd.lglh

    comp_kind = np.zeros((steps, max(C, 1)), np.int32)
    comp_level = np.zeros((steps, max(C, 1)), np.int32)
    for t, ops in enumerate(ov.compute_at):
        for j, op in enumerate(ops):
            comp_kind[t, j] = COMP_KIND_ID[op.kind]
            comp_level[t, j] = op.level

    # ---- level compute tables, padded to the widest level -------------
    nlev = len(ov.levels)
    nbr, nbc = ov.nbr, ov.nbc
    NK = max((len(lv.Ks) for lv in ov.levels), default=0)
    if nlev:
        # the shared partial/S regions are one address each across every
        # level (PR 3); the stream's static base offsets rely on it
        base_p = ov.levels[0].base_p
        base_s = ov.levels[0].base_s
        if any(lv.base_p != base_p or lv.base_s != base_s
               for lv in ov.levels):
            raise ValueError("overlapped levels disagree on the shared "
                             "partial/S region bases — the stream "
                             "lowering requires the PR-3 single-region "
                             "arena layout")
        if base_s - base_p != NK * nbr or trash - base_s != NK:
            raise ValueError(
                f"shared region extents (partial={base_s - base_p}, "
                f"S={trash - base_s}) do not match the widest level "
                f"(NK={NK}) — padded compute rows would escape them")
    else:
        base_p = base_s = ov.n_ainv

    u_gather = np.full((nlev, P, NK * nbc), trash, np.int32)
    cmask = np.zeros((nlev, ov.pc, NK, nbc))
    kcs = np.zeros((nlev, NK), np.int32)
    krs = np.zeros((nlev, NK), np.int32)
    col_write_row = np.zeros((nlev, ov.pr, NK, nbr))
    col_write_col = np.zeros((nlev, ov.pc, NK))
    diag_rowmask = np.zeros((nlev, ov.pr, NK))
    diag_root = np.full((nlev, NK), -1, np.int32)
    diag_slot = np.full((nlev, NK), trash, np.int32)
    for L, lv in enumerate(ov.levels):
        nk = len(lv.Ks)
        u_gather[L, :, :nk * nbc] = lv.u_gather
        cmask[L, :, :nk] = lv.cmask
        kcs[L, :nk] = lv.kcs
        krs[L, :nk] = lv.krs
        col_write_row[L, :, :nk] = lv.col_write_row
        col_write_col[L, :, :nk] = lv.col_write_col
        diag_rowmask[L, :, :nk] = lv.diag_rowmask
        diag_root[L, :nk] = lv.diag_root
        diag_slot[L, :nk] = lv.diag_slot

    return StreamTables(
        nb=ov.nb, pr=ov.pr, pc=ov.pc, n_ainv=ov.n_ainv,
        arena_blocks=ov.arena_blocks, trash=trash,
        base_p=base_p, base_s=base_s,
        nrounds=nrounds, steps=steps, shifts=shifts,
        W=W, LW=LW, C=C, NK=NK, window=ov.window,
        peak_blocks=peak_arena_blocks(ov),
        diag_set_root=ov.diag_set_root, diag_set_slot=ov.diag_set_slot,
        gather=gather, scatter=scatter, addm=addm, tmask=tmask, glh=glh,
        recv_shift=recv_shift,
        lgather=lgather, lscatter=lscatter, ltmask=ltmask, lglh=lglh,
        comp_kind=comp_kind, comp_level=comp_level,
        u_gather=u_gather, cmask=cmask, kcs=kcs, krs=krs,
        col_write_row=col_write_row, col_write_col=col_write_col,
        diag_rowmask=diag_rowmask, diag_root=diag_root,
        diag_slot=diag_slot,
        level_Ks=[np.asarray(lv.Ks) for lv in ov.levels],
        lane_edges=[list(rnd.edges) for rnd in ov.rounds],
        lmoves=[list(rnd.lmoves) for rnd in ov.rounds])


def decode_round_lanes(st: StreamTables, t: int
                       ) -> List[Tuple[int, int, int, int, float, bool,
                                       bool]]:
    """Reconstruct round ``t``'s *real* comm lanes from the device tables
    alone (no ``lane_edges`` metadata): one
    (src, dst, gather_slot, scatter_slot, addm, transpose, from_lh) tuple
    per lane whose receiver scatter slot is not the trash block: a
    receiver's one arrival comes from the device ``recv_shift`` steps
    behind it on the ring. The replay property test compares this
    against the overlapped :class:`~.plan.GlobalRound` the round was
    lowered from."""
    P = st.pr * st.pc
    out = []
    for d in range(P):
        si = int(st.recv_shift[t, d])
        if si < 0:
            continue
        s = (d - st.shifts[si]) % P
        for j in range(st.W):
            ds = int(st.scatter[t, d, j])
            if ds == st.trash:
                continue
            out.append((s, d, int(st.gather[t, s, j]), ds,
                        float(st.addm[t, d, j]),
                        bool(st.tmask[t, d, j]),
                        bool(st.glh[t, s, j])))
    return out


def decode_local_lanes(st: StreamTables, t: int
                       ) -> List[Tuple[int, int, int, bool, bool]]:
    """Round ``t``'s real owner-local lanes from the device tables:
    (device, gather_slot, scatter_slot, transpose, from_lh) per non-trash
    scatter."""
    P = st.pr * st.pc
    out = []
    for dev in range(P):
        for j in range(st.LW):
            ds = int(st.lscatter[t, dev, j])
            if ds == st.trash:
                continue
            out.append((dev, int(st.lgather[t, dev, j]), ds,
                        bool(st.ltmask[t, dev, j]),
                        bool(st.lglh[t, dev, j])))
    return out
