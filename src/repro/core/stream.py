"""Stream lowering — the uniform round-stream form of the overlapped
sweep, executable as ONE ``lax.fori_loop`` body.

The overlapped executor (``plan.schedule_overlapped`` +
``pselinv_dist.make_sweep_overlapped``) replays its global
:class:`~.plan.GlobalRound` list by unrolling a Python loop: every round
contributes its own ``lax.ppermute`` (a *static* perm) plus per-round
gather/scatter constants, so jaxpr/HLO size and trace+compile time grow
linearly with the round count — the binding constraint on scaling ``nb``
and grid size. This module lowers a compiled :class:`~.plan.OverlappedExec`
once more, into **uniform-width, round-indexed device tables**
(:class:`StreamTables`): every per-round quantity is stacked on a leading
round axis and padded to the stream-wide maximum width, so a single loop
body driven by ``dynamic_slice`` on the round axis executes the entire
sweep — comm lanes, owner-local copies, and the level GEMM / write /
S-einsum / diagonal phases behind per-round phase flags.

**Permute encoding (the one static-shape obstacle).** ``lax.ppermute``
takes a static perm, but the overlapped stream's perm differs per round.
The encoding here factors each round over the ``(pr, pc)`` **grid
torus**: within one round every device sends to at most one destination
and receives from at most one source (the ppermute constraint), and
each (src, dst) pair has one grid offset
``(dr, dc) = ((dst_r - src_r) mod pr, (dst_c - src_c) mod pc)`` — pure
column-phase traffic is ``(0, dc)`` (at most ``pc - 1`` offsets), pure
row-phase traffic ``(dr, 0)`` (at most ``pr - 1``), and the symmetric
xfer handoffs a few diagonals. Since an offset fully determines ``dst``
from ``src``, *any* union of same-offset pairs is a valid (partial)
permutation: the lowering groups each round's pairs by
(offset, lane width) into **comm slots** — one static perm (the union
of every pair that (offset, width) ever carries across the stream) and
one static width each — and a per-round boolean ``slot_active`` mask
gates each slot's permute behind a ``lax.cond``. The per-round lane
tables still collapse to ``[round, device, lane]``: a device gathers
its one outgoing lane stack, each *active* slot ships the stack's
leading ``width`` lanes along its perm, and each receiver keeps only
the arrival of its one receive slot (``recv_slot``) and scatters it
once — the same gather-snapshot → permute → scatter semantics as the
unrolled round, hence bit-identical (padded lanes scatter into the
trash block exactly like the unrolled executor's coalescing padding; a
slot's spurious deliveries — union-perm sources that did not pack a
lane this round — are discarded by the receive-slot select). A round
therefore pays only the wire bytes of the slots it actually uses,
``Σ len(perm) × width`` blocks (:func:`stream_wire_blocks`, near the
unrolled executor's instead of the flat-ring encoding's
every-shift-every-round ~7–200×), while the program size stays
**independent of the round count** (the tables are data, not code; the
slot dictionary saturates with the grid, not with ``nb``).
``shift_budget`` coarsens the dictionary (power-of-two width classes,
then one slot per offset) when fewer gated permutes are worth some
wire back; ``axis_factored=False`` recovers the PR-5 flat-ring
encoding (one always-active full-ring slot per ``(d - s) mod P``
shift) for A/B comparison. Algorithmic byte accounting is unchanged
(``simulator.round_schedule_from_stream`` derives the timeline from
the real lanes); *executed wire* accounting now has its own pair of
lenses — :func:`stream_wire_bytes` from the gated tables here, and
``simulator.executed_wire_bytes`` re-deriving the active sets from
``recv_slot`` — which must agree (tested).

**Compute encoding.** Round boundary ``t`` fires the compute ops the
dependence scheduler pinned there (``OverlappedExec.compute_at[t]``, in
dependence order). The stream gives every boundary the same fixed number
of compute *slots* (the stream-wide maximum); each slot holds a
(kind, level) pair — kind 0 is a no-op — dispatched through one
``lax.switch`` whose branches dynamic-index **level-stacked** mask/index
tables padded to the widest level ``NK``. Padded supernode rows carry a
zero struct mask (their GEMM/S rows compute exact zeros into the shared
partial/S regions' tail, which only the masked readers ever touch) and
their diagonal lanes target the trash block, so padding is numerically
inert — the executed arithmetic on real rows is the unrolled executor's,
value for value.

The lowering is pure host-side table construction (numpy); the executor
lives in ``pselinv_dist.make_sweep_stream`` and the end-to-end wiring in
``PlanOptions(stream=True)``.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from .plan import OverlappedExec, peak_arena_blocks
from .schedule import BYTES_PER_ELT

__all__ = ["COMP_NOOP", "COMP_GEMM", "COMP_WRITE", "COMP_SCOMP",
           "COMP_DIAGW", "COMP_KIND_ID", "StreamTables", "lower_stream",
           "decode_round_lanes", "decode_local_lanes",
           "stream_wire_blocks", "stream_wire_bytes",
           "stream_shifts_per_round", "overlap_wire_blocks"]

#: compute-slot kind ids of the per-boundary phase flags (0 = no-op slot)
COMP_NOOP, COMP_GEMM, COMP_WRITE, COMP_SCOMP, COMP_DIAGW = range(5)
COMP_KIND_ID: Dict[str, int] = {"gemm": COMP_GEMM, "write": COMP_WRITE,
                                "scomp": COMP_SCOMP, "diagw": COMP_DIAGW}


@dataclass
class StreamTables:
    """The uniform round-stream compilation of one overlapped sweep:
    every per-round table of the :class:`~.plan.GlobalRound` list stacked
    on a leading round axis (padded to the stream-wide widths), plus the
    level compute tables stacked on a level axis (padded to ``NK``).

    Geometry mirrors :class:`~.plan.OverlappedExec` (same arena layout,
    same trash block, same shared partial/S regions at ``base_p`` /
    ``base_s`` — asserted identical across levels at lowering time).

    Communication is a static dictionary of **comm slots** (see the
    module docstring): ``slot_perm[si]`` is slot ``si``'s static
    (src, dst) pair list (a valid partial permutation — all pairs share
    one grid offset), ``slot_width[si]`` how many leading lanes of the
    sender stack it ships, ``slot_shift[si]`` its grouping key — the
    grid-torus offset ``(dr, dc)`` when ``axis_factored``, the 1-tuple
    flat ring delta ``(d - s) mod P`` otherwise — ``slot_active`` the
    (steps, S) per-round gate, and ``recv_slot`` the (steps, P) index of
    the one slot each device receives on (-1 = none). Comm lane tables
    are indexed ``[round, device, lane]`` — NOT per slot: within one
    round a device sends on at most one slot and receives on at most one
    (the ppermute constraint), so the sender tables (``gather``/``glh``)
    describe the device's single outgoing lane stack (every active slot
    ships its leading ``slot_width`` lanes — only true destinations keep
    them), and the receiver tables (``scatter``/``addm``/``tmask``)
    describe where the single kept arrival lands. A lane is *real* iff
    its receiver scatter slot is not the trash block.
    ``comp_kind``/``comp_level`` hold each boundary's compute slots in
    dependence order (:data:`COMP_KIND_ID`; 0-filled tails are no-ops).
    ``steps = nrounds + 1`` is the ``fori_loop`` trip count — the final
    iteration runs only the last boundary's compute (its comm tables are
    all-trash no-ops).

    ``lane_edges``/``lmoves``/``level_Ks``/``peak_blocks`` are host-side
    metadata for byte accounting and the replay tests — never shipped to
    the device."""
    nb: int
    pr: int
    pc: int
    n_ainv: int
    arena_blocks: int
    trash: int
    base_p: int
    base_s: int
    nrounds: int
    steps: int
    axis_factored: bool
    slot_shift: Tuple[Tuple[int, ...], ...]
    slot_width: Tuple[int, ...]
    slot_perm: Tuple[Tuple[Tuple[int, int], ...], ...]
    W: int                         # comm lane width (max over rounds)
    LW: int                        # owner-local lane width
    C: int                         # compute slots per boundary
    NK: int                        # widest level's supernode count
    window: int | None
    peak_blocks: int
    diag_set_root: np.ndarray
    diag_set_slot: np.ndarray
    # ---- (steps, P, W) comm lane tables + per-round slot gating -------
    gather: np.ndarray
    scatter: np.ndarray
    addm: np.ndarray
    tmask: np.ndarray
    glh: np.ndarray
    slot_active: np.ndarray        # (steps, S) bool
    recv_slot: np.ndarray          # (steps, P) int32, -1 = none
    # ---- (steps, P, LW) owner-local lane tables -----------------------
    lgather: np.ndarray
    lscatter: np.ndarray
    ltmask: np.ndarray
    lglh: np.ndarray
    # ---- (steps, C) compute phase flags -------------------------------
    comp_kind: np.ndarray
    comp_level: np.ndarray
    # ---- (nlev, ...) level compute tables padded to NK ----------------
    u_gather: np.ndarray           # (nlev, P, NK*nbc), trash-padded
    cmask: np.ndarray              # (nlev, pc, NK, nbc), zero-padded
    kcs: np.ndarray                # (nlev, NK)
    krs: np.ndarray                # (nlev, NK)
    col_write_row: np.ndarray      # (nlev, pr, NK, nbr)
    col_write_col: np.ndarray      # (nlev, pc, NK)
    diag_rowmask: np.ndarray       # (nlev, pr, NK)
    diag_root: np.ndarray          # (nlev, NK), -1-padded (matches no id)
    diag_slot: np.ndarray          # (nlev, NK), trash-padded
    # ---- host-side metadata (accounting / replay tests) ---------------
    level_Ks: List[np.ndarray] = field(default_factory=list)
    lane_edges: List[List[Tuple[int, int, str, int, float]]] = \
        field(default_factory=list)
    lmoves: List[List[Tuple[int, str, int]]] = field(default_factory=list)

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc

    @property
    def nlev(self) -> int:
        return len(self.level_Ks)

    @property
    def nslots(self) -> int:
        return len(self.slot_perm)

    @property
    def shifts(self) -> Tuple[int, ...]:
        """The flat ring-offset set ``(d - s) mod P`` the slot perms
        cover — the PR-5 encoding's shift vocabulary, kept as derived
        introspection (the executor no longer runs one full-ring permute
        per entry)."""
        P = self.pr * self.pc
        return tuple(sorted({(d - s) % P
                             for perm in self.slot_perm
                             for (s, d) in perm}))


def lower_stream(ov: OverlappedExec, *, axis_factored: bool = True,
                 shift_budget: int | None = None) -> StreamTables:
    """Lower a compiled overlapped round stream into the uniform
    round-indexed device tables of :class:`StreamTables`.

    Pure table construction: the stream replays the *identical* round
    order, lane order, and accumulation order as the unrolled
    :class:`~.plan.GlobalRound` list (the replay property test in
    ``tests/test_stream.py`` proves it round-for-round), so the executed
    f64 output is bit-identical to ``make_sweep_overlapped``'s.

    ``axis_factored`` (default) builds the gated grid-torus slot
    dictionary — slots keyed by (grid offset, exact lane width), active
    only in the rounds that use them. ``shift_budget`` coarsens the
    width keying (exact → power-of-two classes → one slot per offset)
    until the dictionary fits; it cannot go below one slot per distinct
    grid offset. ``axis_factored=False`` recovers the PR-5 flat-ring
    encoding: one always-active full-ring slot per ``(d - s) mod P``
    shift, every device's whole stack shipped on each."""
    P = ov.pr * ov.pc
    pr, pc = ov.pr, ov.pc
    nrounds = len(ov.rounds)
    steps = nrounds + 1
    W = max((rnd.width for rnd in ov.rounds), default=0)
    LW = max((rnd.lwidth for rnd in ov.rounds), default=0)
    C = max((len(ops) for ops in ov.compute_at), default=0)
    trash = ov.trash

    # authoritative per-round pair -> lane count (from the edge lists;
    # the perm pair set and the edge pair set coincide by construction)
    pair_rounds: List[Dict[Tuple[int, int], int]] = []
    for t, rnd in enumerate(ov.rounds):
        cnt: Dict[Tuple[int, int], int] = defaultdict(int)
        for (s, d, _kind, _lv, _nb) in rnd.edges:
            cnt[(s, d)] += 1
        if set(cnt) != set(rnd.perm):
            raise ValueError(
                f"round {t}: edge pairs {sorted(cnt)} disagree with the "
                f"permute pairs {sorted(rnd.perm)}")
        if any(s == d for (s, d) in cnt):
            raise ValueError("overlapped stream contains a self-edge "
                             "(src == dst) — those must be owner-local "
                             "lanes")
        pair_rounds.append(dict(cnt))

    # ---- comm-slot dictionary -----------------------------------------
    slot_shift_l: List[Tuple[int, ...]] = []
    slot_width_l: List[int] = []
    slot_pairs: List[set] = []
    recv_slot = np.full((steps, P), -1, np.int32)
    active: List[set] = [set() for _ in range(steps)]

    if axis_factored:
        def off(s: int, d: int) -> Tuple[int, int]:
            return ((d // pc - s // pc) % pr, (d % pc - s % pc) % pc)

        maxn: Dict[Tuple[int, int], int] = defaultdict(int)
        for cnt in pair_rounds:
            for (s, d), n in cnt.items():
                maxn[off(s, d)] = max(maxn[off(s, d)], n)

        def _pow2(n: int) -> int:
            w = 1
            while w < n:
                w <<= 1
            return w

        # width keying, coarsened until the dictionary fits the budget
        keyings = [lambda o, n: n,
                   lambda o, n: min(_pow2(n), W),
                   lambda o, n: maxn[o]]
        for wf in keyings:
            nkeys = len({(off(s, d), wf(off(s, d), n))
                         for cnt in pair_rounds
                         for (s, d), n in cnt.items()})
            if shift_budget is None or nkeys <= shift_budget:
                break
        else:
            raise ValueError(
                f"shift_budget={shift_budget} is below one comm slot per "
                f"grid offset ({nkeys} offsets) — a slot's perm must stay "
                "single-offset to remain a permutation")

        slot_id: Dict[Tuple, int] = {}
        for t, cnt in enumerate(pair_rounds):
            for (s, d), n in cnt.items():
                key = (off(s, d), wf(off(s, d), n))
                si = slot_id.get(key)
                if si is None:
                    si = slot_id[key] = len(slot_pairs)
                    slot_shift_l.append(key[0])
                    slot_width_l.append(key[1])
                    slot_pairs.append(set())
                slot_pairs[si].add((s, d))
                active[t].add(si)
                if recv_slot[t, d] != -1:
                    raise ValueError(
                        f"round {t}: device {d} receives twice — the "
                        "overlapped round violates the ppermute "
                        "constraint")
                recv_slot[t, d] = si
        slot_perm = tuple(tuple(sorted(ps)) for ps in slot_pairs)
        # same-offset pairs are automatically bijective; keep the cheap
        # guard so a future keying change cannot ship a broken perm
        for perm in slot_perm:
            srcs = [s for s, _ in perm]
            dsts = [d for _, d in perm]
            if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
                raise ValueError(f"comm slot perm {perm} is not a "
                                 "permutation")
    else:
        # PR-5 flat-ring encoding: one full-ring slot per used shift,
        # always active (the stream shipped every stack on every shift
        # in every iteration — kept for A/B wire comparison)
        deltas = sorted({(d - s) % P
                         for cnt in pair_rounds for (s, d) in cnt})
        sidx = {dlt: i for i, dlt in enumerate(deltas)}
        slot_shift_l = [(dlt,) for dlt in deltas]
        slot_width_l = [W] * len(deltas)
        slot_perm = tuple(tuple((i, (i + dlt) % P) for i in range(P))
                          for dlt in deltas)
        for t in range(steps):
            active[t] = set(range(len(deltas)))
        for t, cnt in enumerate(pair_rounds):
            for (s, d) in cnt:
                if recv_slot[t, d] != -1:
                    raise ValueError(
                        f"round {t}: device {d} receives twice — the "
                        "overlapped round violates the ppermute "
                        "constraint")
                recv_slot[t, d] = sidx[(d - s) % P]

    S = len(slot_perm)
    slot_active = np.zeros((steps, S), bool)
    for t in range(steps):
        for si in active[t]:
            slot_active[t, si] = True

    gather = np.zeros((steps, P, W), np.int32)
    scatter = np.full((steps, P, W), trash, np.int32)
    addm = np.zeros((steps, P, W), np.float32)
    tmask = np.zeros((steps, P, W), bool)
    glh = np.zeros((steps, P, W), bool)
    lgather = np.zeros((steps, P, LW), np.int32)
    lscatter = np.full((steps, P, LW), trash, np.int32)
    ltmask = np.zeros((steps, P, LW), bool)
    lglh = np.zeros((steps, P, LW), bool)

    for t, rnd in enumerate(ov.rounds):
        for (s, d) in rnd.perm:
            # the ppermute constraint (unique sources / destinations per
            # round) is what makes the collapsed [round, device, lane]
            # layout lossless: one outgoing stack, one receive slot
            w = rnd.width
            gather[t, s, :w] = rnd.gather[s]
            glh[t, s, :w] = rnd.glh[s]
            scatter[t, d, :w] = rnd.scatter[d]
            addm[t, d, :w] = rnd.addm[d]
            tmask[t, d, :w] = rnd.tmask[d]
        if rnd.lwidth:
            lw = rnd.lwidth
            lgather[t, :, :lw] = rnd.lgather
            lscatter[t, :, :lw] = rnd.lscatter
            ltmask[t, :, :lw] = rnd.ltmask
            lglh[t, :, :lw] = rnd.lglh

    comp_kind = np.zeros((steps, max(C, 1)), np.int32)
    comp_level = np.zeros((steps, max(C, 1)), np.int32)
    for t, ops in enumerate(ov.compute_at):
        for j, op in enumerate(ops):
            comp_kind[t, j] = COMP_KIND_ID[op.kind]
            comp_level[t, j] = op.level

    # ---- level compute tables, padded to the widest level -------------
    nlev = len(ov.levels)
    nbr, nbc = ov.nbr, ov.nbc
    NK = max((len(lv.Ks) for lv in ov.levels), default=0)
    if nlev:
        # the shared partial/S regions are one address each across every
        # level (PR 3); the stream's static base offsets rely on it
        base_p = ov.levels[0].base_p
        base_s = ov.levels[0].base_s
        if any(lv.base_p != base_p or lv.base_s != base_s
               for lv in ov.levels):
            raise ValueError("overlapped levels disagree on the shared "
                             "partial/S region bases — the stream "
                             "lowering requires the PR-3 single-region "
                             "arena layout")
        if base_s - base_p != NK * nbr or trash - base_s != NK:
            raise ValueError(
                f"shared region extents (partial={base_s - base_p}, "
                f"S={trash - base_s}) do not match the widest level "
                f"(NK={NK}) — padded compute rows would escape them")
    else:
        base_p = base_s = ov.n_ainv

    u_gather = np.full((nlev, P, NK * nbc), trash, np.int32)
    cmask = np.zeros((nlev, ov.pc, NK, nbc))
    kcs = np.zeros((nlev, NK), np.int32)
    krs = np.zeros((nlev, NK), np.int32)
    col_write_row = np.zeros((nlev, ov.pr, NK, nbr))
    col_write_col = np.zeros((nlev, ov.pc, NK))
    diag_rowmask = np.zeros((nlev, ov.pr, NK))
    diag_root = np.full((nlev, NK), -1, np.int32)
    diag_slot = np.full((nlev, NK), trash, np.int32)
    for L, lv in enumerate(ov.levels):
        nk = len(lv.Ks)
        u_gather[L, :, :nk * nbc] = lv.u_gather
        cmask[L, :, :nk] = lv.cmask
        kcs[L, :nk] = lv.kcs
        krs[L, :nk] = lv.krs
        col_write_row[L, :, :nk] = lv.col_write_row
        col_write_col[L, :, :nk] = lv.col_write_col
        diag_rowmask[L, :, :nk] = lv.diag_rowmask
        diag_root[L, :nk] = lv.diag_root
        diag_slot[L, :nk] = lv.diag_slot

    return StreamTables(
        nb=ov.nb, pr=ov.pr, pc=ov.pc, n_ainv=ov.n_ainv,
        arena_blocks=ov.arena_blocks, trash=trash,
        base_p=base_p, base_s=base_s,
        nrounds=nrounds, steps=steps,
        axis_factored=axis_factored,
        slot_shift=tuple(slot_shift_l), slot_width=tuple(slot_width_l),
        slot_perm=slot_perm,
        W=W, LW=LW, C=C, NK=NK, window=ov.window,
        peak_blocks=peak_arena_blocks(ov),
        diag_set_root=ov.diag_set_root, diag_set_slot=ov.diag_set_slot,
        gather=gather, scatter=scatter, addm=addm, tmask=tmask, glh=glh,
        slot_active=slot_active, recv_slot=recv_slot,
        lgather=lgather, lscatter=lscatter, ltmask=ltmask, lglh=lglh,
        comp_kind=comp_kind, comp_level=comp_level,
        u_gather=u_gather, cmask=cmask, kcs=kcs, krs=krs,
        col_write_row=col_write_row, col_write_col=col_write_col,
        diag_rowmask=diag_rowmask, diag_root=diag_root,
        diag_slot=diag_slot,
        level_Ks=[np.asarray(lv.Ks) for lv in ov.levels],
        lane_edges=[list(rnd.edges) for rnd in ov.rounds],
        lmoves=[list(rnd.lmoves) for rnd in ov.rounds])


def decode_round_lanes(st: StreamTables, t: int
                       ) -> List[Tuple[int, int, int, int, float, bool,
                                       bool]]:
    """Reconstruct round ``t``'s *real* comm lanes from the device tables
    alone (no ``lane_edges`` metadata): one
    (src, dst, gather_slot, scatter_slot, addm, transpose, from_lh) tuple
    per lane whose receiver scatter slot is not the trash block: a
    receiver's one arrival comes from its receive slot's perm — the slot
    must be gated *active* this round, ship at least the lanes the
    receiver scatters, and name the receiver in its pair list. The
    replay property test compares this against the overlapped
    :class:`~.plan.GlobalRound` the round was lowered from."""
    P = st.pr * st.pc
    src_of = [dict((d, s) for (s, d) in perm) for perm in st.slot_perm]
    out = []
    for d in range(P):
        si = int(st.recv_slot[t, d])
        if si < 0:
            continue
        if not st.slot_active[t, si]:
            raise ValueError(
                f"round {t}: device {d} receives on slot {si}, which the "
                "gate table marks inactive — the arrival would be zeros")
        if d not in src_of[si]:
            raise ValueError(
                f"round {t}: device {d} receives on slot {si} but is not "
                "a destination of its perm")
        s = src_of[si][d]
        for j in range(st.W):
            ds = int(st.scatter[t, d, j])
            if ds == st.trash:
                continue
            if j >= st.slot_width[si]:
                raise ValueError(
                    f"round {t}: device {d} scatters lane {j} but its "
                    f"receive slot {si} ships only "
                    f"{st.slot_width[si]} lanes")
            out.append((s, d, int(st.gather[t, s, j]), ds,
                        float(st.addm[t, d, j]),
                        bool(st.tmask[t, d, j]),
                        bool(st.glh[t, s, j])))
    return out


# ---------------------------------------------------------------------------
# executed-wire accounting (physical permute traffic, not algorithmic lanes)
# ---------------------------------------------------------------------------

def stream_wire_blocks(st: StreamTables) -> int:
    """Blocks the gated stream physically ships per sweep: every round,
    each *active* comm slot moves ``len(slot_perm) × slot_width`` blocks
    (XLA's collective-permute ships every listed pair's full payload —
    union-perm sources that packed no lane this round ship padding, and
    so do lanes above a pair's real count; both are counted, exactly as
    they cross the wire). The flat-ring lowering prices out to the PR-5
    behavior (every shift, every step, full width) under the same
    formula."""
    counts = np.array([len(p) * w
                       for p, w in zip(st.slot_perm, st.slot_width)],
                      np.int64)
    if not len(counts):
        return 0
    return int((st.slot_active * counts[None, :]).sum())


def stream_wire_bytes(st: StreamTables, b: int) -> float:
    """Executed wire bytes per sweep of the gated stream
    (:func:`stream_wire_blocks` at block width ``b``, in the plan's
    per-element accounting unit)."""
    return float(stream_wire_blocks(st)) * b * b * BYTES_PER_ELT


def stream_shifts_per_round(st: StreamTables) -> float:
    """Mean number of gated permutes the stream executes per comm round
    — the per-round active-slot count (the flat-ring encoding executed
    ``len(shifts)`` every round unconditionally)."""
    if not st.nrounds or not st.nslots:
        return 0.0
    return float(st.slot_active[:st.nrounds].sum(axis=1).mean())


def overlap_wire_blocks(ov: OverlappedExec) -> int:
    """Blocks the *unrolled* overlapped executor physically ships per
    sweep: each round's single static permute moves
    ``len(perm) × width`` blocks (coalesced pairs below the round width
    ship padding lanes — counted, as they cross the wire). The yardstick
    the gated stream's :func:`stream_wire_blocks` is held to in the
    bench."""
    return sum(len(rnd.perm) * rnd.width for rnd in ov.rounds)


def decode_local_lanes(st: StreamTables, t: int
                       ) -> List[Tuple[int, int, int, bool, bool]]:
    """Round ``t``'s real owner-local lanes from the device tables:
    (device, gather_slot, scatter_slot, transpose, from_lh) per non-trash
    scatter."""
    P = st.pr * st.pc
    out = []
    for dev in range(P):
        for j in range(st.LW):
            ds = int(st.lscatter[t, dev, j])
            if ds == st.trash:
                continue
            out.append((dev, int(st.lgather[t, dev, j]), ds,
                        bool(st.ltmask[t, dev, j]),
                        bool(st.lglh[t, dev, j])))
    return out
