"""CommPlan — the single static IR behind every PSelInv schedule consumer.

One layering (host plan → device executor → simulator):

1. ``core/schedule.pselinv_events`` enumerates the *semantic* restricted
   collectives of Algorithm 1 (what must be communicated, by whom).
2. :func:`build_plan` lowers that enumeration ONCE into a
   :class:`CommPlan`: per collective a concrete :class:`~.trees.CommTree`
   (kind/tag-deterministic, in **global rank space**), per-edge byte
   counts, and the elimination-tree level of every supernode — supernodes
   at the same level are independent and get batched into shared rounds
   (the paper's asynchronous pipelining, §3).
3. Consumers:

   * ``core/simulator.volumes`` / ``simulate`` walk ``CommPlan.ops``
     directly — the bytes they account are the bytes of the very trees
     the executor runs, *by construction*;
   * ``core/pselinv_dist.make_sweep`` consumes the :class:`ExecPlan`
     produced by :func:`compile_exec`: dense per-device index tables
     (gather slot, scatter slot, receive mask, ppermute pairs) that
     replace per-pair ``jnp.where`` chains with O(1) table lookups;
   * ``comm/treecomm.batched_rounds`` delegates its round merging to
     :func:`merge_round_lists`.

Adding a new tree kind therefore means: extend ``core/trees.build_tree``
— every consumer (simulator, executor, reusable collectives) picks it up
through :func:`tree_for` with zero schedule drift.

Executor slot layout (uniform supernode width ``b``; ``nb`` padded so
``pr | nb`` and ``pc | nb``): global block (I, J) lives on device
``(I % pr, J % pc)`` at flat local slot ``(I//pr)*nbc + J//pc``; the
level-stacked Û buffer keys slot ``k*nbc + I//pc`` and the partial-product
buffer ``k*nbr + J//pr`` for the level's k-th supernode.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .schedule import (BYTES_PER_ELT, CommEvent, ComputeTask, Grid2D,
                       pselinv_events)
from .symbolic import BlockStructure
from .trees import CommTree, TreeKind, build_tree, cached_tree, stable_hash

__all__ = [
    "PlanOp", "CommPlan", "build_plan", "tree_for", "merge_round_lists",
    "pack_edges", "CommRound", "LocalRound", "LevelExec", "ExecPlan",
    "compile_exec", "exec_byte_counts", "etree_levels",
]


# ---------------------------------------------------------------------------
# tree construction (the one place a schedule becomes a concrete tree)
# ---------------------------------------------------------------------------

def tree_for(kind: TreeKind, root: int, participants: Sequence[int],
             tag: int) -> CommTree:
    """The canonical collective → tree lowering. FLAT/BINARY trees depend
    only on the participant set (memoized); SHIFTED/HYBRID decorrelate
    concurrent collectives through the tag-seeded rotation."""
    receivers = tuple(r for r in participants if r != root)
    if kind in (TreeKind.FLAT, TreeKind.BINARY):
        return cached_tree(kind.value, root, receivers, 0)
    return build_tree(kind, root, receivers, tag=tag)


def merge_round_lists(per_tree: Sequence[List[List[Tuple[int, int]]]],
                      op: str) -> List[List[Tuple[int, int]]]:
    """Merge several *disjoint-group* collectives' per-round (src, dst)
    edge lists into shared rounds: broadcasts left-aligned (roots fire
    first), reductions right-aligned (every root combines on the last
    round). Raises ``ValueError`` naming the colliding pairs if the trees
    are not disjoint within a round — a device may source/sink at most one
    transfer per ``ppermute``."""
    n = max((len(r) for r in per_tree), default=0)
    merged: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for rounds in per_tree:
        shift = 0 if op == "bcast" else n - len(rounds)
        for i, rnd in enumerate(rounds):
            merged[i + shift].extend(rnd)
    for i, rnd in enumerate(merged):
        srcs = [s for s, _ in rnd]
        dsts = [d for _, d in rnd]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            dup_s = sorted({s for s in srcs if srcs.count(s) > 1})
            dup_d = sorted({d for d in dsts if dsts.count(d) > 1})
            bad = [(s, d) for (s, d) in rnd
                   if s in dup_s or d in dup_d]
            raise ValueError(
                f"merged trees are not disjoint in round {i}: pairs {bad} "
                f"reuse sources {dup_s} / destinations {dup_d}")
    return merged


def etree_levels(bs: BlockStructure) -> np.ndarray:
    """Depth of every supernode in the block elimination tree (roots at
    level 0). Supernodes at equal depth are independent in the
    selected-inversion sweep: struct(K) ⊆ ancestors(K), all at strictly
    smaller depth."""
    nsuper = bs.nsuper
    level = np.full(nsuper, -1, dtype=np.int64)
    for K in range(nsuper - 1, -1, -1):
        p = int(bs.parent[K])
        level[K] = 0 if p < 0 else level[p] + 1
    # parent(K) > K, so a reverse scan sees parents first
    return level


# ---------------------------------------------------------------------------
# the IR
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PlanOp:
    """One restricted collective with its concrete tree.

    ``exec_only`` marks the symmetric-case bookkeeping transfers
    (``xfer-out`` transpose handoff, ``diag-reduce``) that the executable
    sweep performs but the paper's volume accounting (§4.1) does not
    report — ``volumes``/``simulate`` skip them."""
    kind: str
    supernode: int
    level: int
    root: int
    participants: Tuple[int, ...]
    nbytes: float
    tag: int
    tree: CommTree
    block: int = -1
    consumes: int = -1
    exec_only: bool = False


@dataclass
class CommPlan:
    """The static IR: every collective of one PSelInv pass, plus the
    elimination-tree level structure the executor pipelines over."""
    bs: BlockStructure
    grid: Grid2D
    kind: TreeKind
    nb: int                          # supernode count incl. grid padding
    ops: List[PlanOp]
    tasks: List[ComputeTask]
    level_of: np.ndarray             # (nsuper,)
    sweep_levels: List[List[int]]    # per level: supernodes with work
    diag_only: List[int]             # empty-struct supernodes (+ padding)

    def ops_by_supernode(self) -> Dict[int, List[PlanOp]]:
        out: Dict[int, List[PlanOp]] = defaultdict(list)
        for op in self.ops:
            out[op.supernode].append(op)
        return dict(out)


def build_plan(bs: BlockStructure, grid: Grid2D, kind: TreeKind,
               nb: int | None = None) -> CommPlan:
    """Lower the event enumeration into the CommPlan IR (trees built once,
    here, for every consumer)."""
    nsuper = bs.nsuper
    nb = nsuper if nb is None else int(nb)
    if nb < nsuper:
        raise ValueError(f"nb={nb} < nsuper={nsuper}")
    level = etree_levels(bs)
    w = bs.widths()
    pr, pc = grid.pr, grid.pc

    events, tasks = pselinv_events(bs, grid)
    ops: List[PlanOp] = []
    for ev in events:
        ops.append(PlanOp(
            kind=ev.kind, supernode=ev.supernode,
            level=int(level[ev.supernode]), root=ev.root,
            participants=ev.participants, nbytes=ev.nbytes, tag=ev.tag,
            tree=tree_for(kind, ev.root, ev.participants, ev.tag),
            block=ev.block, consumes=ev.consumes))

    # symmetric-case executor transfers (paper implementation detail:
    # A⁻¹(K,J) = A⁻¹(J,K)ᵀ is materialized by a transpose handoff, and the
    # diagonal correction Σ A⁻¹(K,I)·L̂(I,K) is reduced within row K%pr)
    for K in range(nsuper):
        C = [int(i) for i in bs.struct[K]]
        if not C:
            continue
        wk = float(w[K])
        krow, kcol = K % pr, K % pc
        for J in C:
            src = grid.owner(J, K)
            dst = grid.owner(K, J)
            if src == dst:
                continue
            parts = tuple(sorted({src, dst}))
            tag = (K << 20) ^ (J << 2) ^ 3
            ops.append(PlanOp(
                kind="xfer-out", supernode=K, level=int(level[K]),
                root=src, participants=parts,
                nbytes=float(w[J]) * wk * BYTES_PER_ELT, tag=tag,
                tree=tree_for(TreeKind.FLAT, src, parts, tag),
                block=J, exec_only=True))
        cols = sorted({I % pc for I in C} | {kcol})
        if len(cols) > 1:
            root = grid.owner(K, K)
            parts = tuple(sorted(krow * pc + c for c in cols))
            tag = stable_hash(K, 0xD)
            ops.append(PlanOp(
                kind="diag-reduce", supernode=K, level=int(level[K]),
                root=root, participants=parts,
                nbytes=wk * wk * BYTES_PER_ELT, tag=tag,
                tree=tree_for(kind, root, parts, tag),
                block=K, exec_only=True))

    nlev = int(level.max()) + 1 if nsuper else 0
    sweep_levels: List[List[int]] = [[] for _ in range(nlev)]
    diag_only: List[int] = []
    for K in range(nsuper):
        if len(bs.struct[K]):
            sweep_levels[int(level[K])].append(K)
        else:
            diag_only.append(K)
    diag_only.extend(range(nsuper, nb))
    # within a level, keep reverse elimination order (pure aesthetics —
    # same-level supernodes are independent)
    sweep_levels = [sorted(l, reverse=True) for l in sweep_levels if l]

    return CommPlan(bs=bs, grid=grid, kind=kind, nb=nb, ops=ops,
                    tasks=tasks, level_of=level,
                    sweep_levels=sweep_levels, diag_only=diag_only)


# ---------------------------------------------------------------------------
# executor compilation: ops -> packed rounds -> dense device tables
# ---------------------------------------------------------------------------

# an edge is (src_dev, dst_dev, src_slot, dst_slot, nbytes)
Edge = Tuple[int, int, int, int, float]


def pack_edges(edges: Sequence[Edge]) -> List[List[Edge]]:
    """Greedy-pack edges into ppermute rounds: per round each device
    sources at most one transfer and sinks at most one transfer."""
    rounds: List[List[Edge]] = []
    for e in edges:
        for rnd in rounds:
            if all(e[0] != q[0] and e[1] != q[1] for q in rnd):
                rnd.append(e)
                break
        else:
            rounds.append([e])
    return rounds


@dataclass
class CommRound:
    """One ppermute with per-device gather/scatter tables.

    ``slots[:, 0]`` is the flat gather index a sending device reads
    (don't-care 0 for non-senders — ppermute drops their payload);
    ``slots[:, 1]`` the flat scatter index a receiving device writes.
    Non-receivers point at the buffer's **trash slot** (index = buffer
    length): the executor allocates every writable buffer one block
    larger, so no receive mask and no read-modify-write select is needed
    — a write either lands or falls into the trash block."""
    perm: List[Tuple[int, int]]
    slots: np.ndarray         # (P, 2) int32 — [gather, scatter]
    edges: List[Edge] = field(default_factory=list)


@dataclass
class LocalRound:
    """Owner-local copy (src device == dst device): no communication,
    same gather/scatter table shape as :class:`CommRound`."""
    slots: np.ndarray         # (P, 2) int32


def _round_tables(edges: Sequence[Edge], P: int, trash: int) -> CommRound:
    slots = np.zeros((P, 2), np.int32)
    slots[:, 1] = trash
    perm = []
    for (s, d, ss, ds, _nb) in edges:
        perm.append((s, d))
        slots[s, 0] = ss
        slots[d, 1] = ds
    return CommRound(perm=perm, slots=slots, edges=list(edges))


def _local_rounds(ops: Sequence[Tuple[int, int, int]], P: int, trash: int
                  ) -> List[LocalRound]:
    """Pack (dev, src_slot, dst_slot) copies, one per device per round
    (an owner-local copy is an edge with src device == dst device)."""
    out = []
    for rnd in pack_edges([(dev, dev, ss, ds, 0.0)
                           for (dev, ss, ds) in ops]):
        slots = np.zeros((P, 2), np.int32)
        slots[:, 1] = trash
        for (dev, _d, ss, ds, _nb) in rnd:
            slots[dev, 0] = ss
            slots[dev, 1] = ds
        out.append(LocalRound(slots=slots))
    return out


def _schedule_tree_edges(per_op: Sequence[List[List[Edge]]], align: str,
                         P: int, trash: int) -> List[CommRound]:
    """Earliest-fire list scheduling of several collectives' tree edges
    into shared executable rounds (the asynchronous pipelining: an edge
    fires as soon as (1) its data dependency within its own tree is
    satisfied — for a broadcast the edge that delivered to its source,
    for a reduction every edge combining into its source — and (2) a
    ppermute slot is free, i.e. its source/destination device is not
    already used this round). Rounds are executed as barriers, so firing
    strictly after all dependencies is sufficient for correctness."""
    items: List[Tuple[Edge, List[int]]] = []
    for rounds in per_op:
        base = len(items)
        delivered: Dict[int, int] = {}     # node -> item index that fed it
        into: Dict[int, List[int]] = defaultdict(list)
        flat = [e for rnd in rounds for e in rnd]
        if align == "left":                # broadcast orientation
            for j, e in enumerate(flat):
                delivered[e[1]] = base + j
            for j, e in enumerate(flat):
                dep = delivered.get(e[0])
                items.append((e, [dep] if dep is not None else []))
        else:                              # reduce orientation
            for j, e in enumerate(flat):
                into[e[1]].append(base + j)
            for e in flat:
                items.append((e, list(into.get(e[0], ()))))

    fired = [None] * len(items)
    remaining = list(range(len(items)))
    out: List[CommRound] = []
    while remaining:
        used_s, used_d, this = set(), set(), []
        for i in remaining:
            e, deps = items[i]
            if any(fired[d] is None for d in deps):
                continue
            if e[0] in used_s or e[1] in used_d:
                continue
            this.append(i)
            used_s.add(e[0])
            used_d.add(e[1])
        if not this:
            raise ValueError("cyclic edge dependencies in tree schedule")
        for i in this:
            fired[i] = len(out)
        remaining = [i for i in remaining if fired[i] is None]
        out.append(_round_tables([items[i][0] for i in this], P, trash))
    return out


@dataclass
class LevelExec:
    """Dense tables driving one elimination-tree level of the sweep."""
    Ks: np.ndarray                   # (nk,) supernode ids
    xfer_in_local: List[LocalRound]  # Lh -> Uh (transpose), owner-local
    xfer_in: List[CommRound]         # Lh -> Uh (transpose), p2p
    bcast: List[CommRound]           # Uh -> Uh down grid columns
    cmask: np.ndarray                # (pc, nk, nbc) struct mask
    reduce: List[CommRound]          # partial -> partial along grid rows
    kcs: np.ndarray                  # (nk,) K // pc
    col_write_row: np.ndarray        # (pr, nk, nbr)
    col_write_col: np.ndarray        # (pc, nk)
    xfer_out_local: List[LocalRound]
    xfer_out: List[CommRound]        # Ainv -> Ainv (transpose), p2p
    krs: np.ndarray                  # (nk,) K // pr
    diag_rowmask: np.ndarray         # (pr, nk)
    diag_reduce: List[CommRound]     # S -> S within row K%pr
    diag_root: np.ndarray            # (nk,) owner(K,K) device id
    diag_slot: np.ndarray            # (nk,) flat Ainv slot of (K,K)


@dataclass
class ExecPlan:
    nb: int
    pr: int
    pc: int
    diag_set_root: np.ndarray        # (m,) device ids, empty-struct diag
    diag_set_slot: np.ndarray        # (m,) flat Ainv slots
    levels: List[LevelExec]

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc


def compile_exec(plan: CommPlan) -> ExecPlan:
    """Compile the IR into the level-pipelined executable form: every
    collective of a level shares rounds with its independent siblings."""
    grid, nb = plan.grid, plan.nb
    pr, pc, P = grid.pr, grid.pc, grid.size
    if nb % pr or nb % pc:
        raise ValueError(f"nb={nb} not divisible by grid {pr}x{pc}")
    nbr, nbc = nb // pr, nb // pc
    bs = plan.bs
    by_sn = plan.ops_by_supernode()

    droot = np.array([grid.owner(K, K) for K in plan.diag_only],
                     dtype=np.int32)
    dslot = np.array([(K // pr) * nbc + K // pc for K in plan.diag_only],
                     dtype=np.int32)

    levels: List[LevelExec] = []
    for Ks in plan.sweep_levels:
        nk = len(Ks)
        k_of = {K: k for k, K in enumerate(Ks)}
        xi_local: List[Tuple[int, int, int]] = []
        xi_edges: List[Edge] = []
        bcast_ops: List[List[List[Edge]]] = []
        red_ops: List[List[List[Edge]]] = []
        xo_local: List[Tuple[int, int, int]] = []
        xo_edges: List[Edge] = []
        dred_ops: List[List[List[Edge]]] = []
        cmask = np.zeros((pc, nk, nbc))
        cw_row = np.zeros((pr, nk, nbr))
        cw_col = np.zeros((pc, nk))
        d_rowmask = np.zeros((pr, nk))

        for K in Ks:
            k = k_of[K]
            C = [int(i) for i in bs.struct[K]]
            for I in C:
                cmask[I % pc, k, I // pc] = 1.0
                cw_row[I % pr, k, I // pr] = 1.0
                # owner-local transfers are layout copies, not comm ops
                if grid.owner(I, K) == grid.owner(K, I):
                    xi_local.append((grid.owner(I, K),
                                     (I // pr) * nbc + K // pc,
                                     k * nbc + I // pc))
                    xo_local.append((grid.owner(I, K),
                                     (I // pr) * nbc + K // pc,
                                     (K // pr) * nbc + I // pc))
            cw_col[K % pc, k] = 1.0
            d_rowmask[K % pr, k] = 1.0

            for op in by_sn.get(K, ()):
                if op.kind == "xfer":
                    I = op.block
                    dst = [r for r in op.participants if r != op.root][0]
                    xi_edges.append((op.root, dst,
                                     (I // pr) * nbc + K // pc,
                                     k * nbc + I // pc, op.nbytes))
                elif op.kind == "col-bcast":
                    I = op.block
                    slot = k * nbc + I // pc
                    bcast_ops.append(
                        [[(s, d, slot, slot, op.nbytes) for (s, d) in rnd]
                         for rnd in op.tree.bcast_rounds()])
                elif op.kind == "row-reduce":
                    J = op.block
                    slot = k * nbr + J // pr
                    red_ops.append(
                        [[(s, d, slot, slot, op.nbytes) for (s, d) in rnd]
                         for rnd in op.tree.reduce_rounds()])
                elif op.kind == "xfer-out":
                    J = op.block
                    dst = [r for r in op.participants if r != op.root][0]
                    xo_edges.append((op.root, dst,
                                     (J // pr) * nbc + K // pc,
                                     (K // pr) * nbc + J // pc, op.nbytes))
                elif op.kind == "diag-reduce":
                    dred_ops.append(
                        [[(s, d, k, k, op.nbytes) for (s, d) in rnd]
                         for rnd in op.tree.reduce_rounds()])
                elif op.kind == "diag-bcast":
                    pass   # loop-1 normalization is absorbed on the host
                           # (prepare_inputs ships L̂/D⁻¹ pre-normalized)
                else:
                    raise ValueError(
                        f"compile_exec cannot lower op kind {op.kind!r} — "
                        "teach it the new kind or the executed schedule "
                        "silently drifts from the simulated one")

        t_uh = nk * nbc           # trash slot of each writable buffer
        t_pf = nk * nbr
        t_ai = nbr * nbc
        levels.append(LevelExec(
            Ks=np.asarray(Ks, dtype=np.int64),
            xfer_in_local=_local_rounds(xi_local, P, t_uh),
            xfer_in=[_round_tables(r, P, t_uh)
                     for r in pack_edges(xi_edges)],
            bcast=_schedule_tree_edges(bcast_ops, "left", P, t_uh),
            cmask=cmask,
            reduce=_schedule_tree_edges(red_ops, "right", P, t_pf),
            kcs=np.array([K // pc for K in Ks], dtype=np.int32),
            col_write_row=cw_row, col_write_col=cw_col,
            xfer_out_local=_local_rounds(xo_local, P, t_ai),
            xfer_out=[_round_tables(r, P, t_ai)
                      for r in pack_edges(xo_edges)],
            krs=np.array([K // pr for K in Ks], dtype=np.int32),
            diag_rowmask=d_rowmask,
            diag_reduce=_schedule_tree_edges(dred_ops, "right", P, nk),
            diag_root=np.array([grid.owner(K, K) for K in Ks],
                               dtype=np.int32),
            diag_slot=np.array([(K // pr) * nbc + K // pc for K in Ks],
                               dtype=np.int32)))

    return ExecPlan(nb=nb, pr=pr, pc=pc, diag_set_root=droot,
                    diag_set_slot=dslot, levels=levels)


def exec_byte_counts(ex: ExecPlan
                     ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """Per-rank outgoing/incoming bytes by phase kind, summed over the
    *compiled* rounds — the bytes the device program actually moves. The
    equivalence test checks these against ``simulator.volumes`` (same
    plan, independent accounting path)."""
    P = ex.pr * ex.pc
    out: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    inc: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))

    def add(kind: str, rounds: List[CommRound]):
        for rnd in rounds:
            for (s, d, _ss, _ds, nb_) in rnd.edges:
                out[kind][s] += nb_
                inc[kind][d] += nb_

    for lv in ex.levels:
        add("xfer", lv.xfer_in)
        add("col-bcast", lv.bcast)
        add("row-reduce", lv.reduce)
        add("xfer-out", lv.xfer_out)
        add("diag-reduce", lv.diag_reduce)
    return dict(out), dict(inc)
