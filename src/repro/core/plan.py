"""CommPlan — the single static IR behind every PSelInv schedule consumer.

One layering (host plan → device executor → simulator):

1. ``core/schedule.pselinv_events`` enumerates the *semantic* restricted
   collectives of Algorithm 1 (what must be communicated, by whom).
2. :func:`build_plan` lowers that enumeration ONCE into a
   :class:`CommPlan`: per collective a concrete :class:`~.trees.CommTree`
   (kind/tag-deterministic, in **global rank space**), per-edge byte
   counts, and the elimination-tree level of every supernode — supernodes
   at the same level are independent and get batched into shared rounds
   (the paper's asynchronous pipelining, §3).
3. Consumers:

   * ``core/simulator.volumes`` / ``simulate`` walk ``CommPlan.ops``
     directly — the bytes they account are the bytes of the very trees
     the executor runs, *by construction*;
   * ``core/pselinv_dist.make_sweep`` consumes the :class:`ExecPlan`
     produced by :func:`compile_exec`: dense per-device index tables
     (gather slot, scatter slot, receive mask, ppermute pairs) that
     replace per-pair ``jnp.where`` chains with O(1) table lookups;
   * ``comm/treecomm.batched_rounds`` delegates its round merging to
     :func:`merge_round_lists`.

Adding a new tree kind therefore means: extend ``core/trees.build_tree``
— every consumer (simulator, executor, reusable collectives) picks it up
through :func:`tree_for` with zero schedule drift.

Executor slot layout (uniform supernode width ``b``; ``nb`` padded so
``pr | nb`` and ``pc | nb``): global block (I, J) lives on device
``(I % pr, J % pc)`` at flat local slot ``(I//pr)*nbc + J//pc``; the
level-stacked Û buffer keys slot ``k*nbc + I//pc`` and the partial-product
buffer ``k*nbr + J//pr`` for the level's k-th supernode.

**Overlapped round stream** (:func:`schedule_overlapped`): the level
batching above still barriers between elimination-tree levels, although
only the GEMM→reduce→write→diag chain is actually serialized by data —
a level's xfer-in and col-bcast traffic depends on nothing but the
static L̂ shard and its own tree edges. The overlapped lowering
therefore drops the level barrier entirely: every comm edge, local copy
and compute op of the whole sweep becomes a node of one dependence DAG
(:func:`_overlap_items` documents the exact edge set), which is
list-scheduled into a single global sequence of ppermute rounds over a
flat per-device block **arena** (A⁻¹ | L̂ | compact recycled Û slot
pool | one shared partial region | one shared S region | trash — a
level's stacks are live only between their first fill and their last
reader, so non-overlapping generations alias the same physical slots
and generation-keyed WAR anti-dependences serialize the reuse; see
:func:`_u_pool_layout` / :func:`_overlap_items`). Compute fires at
round boundaries; level L+1's xfer-in rides the same rounds as level
L's reduce and diagonal traffic — the paper's §3 asynchronous
pipelining *across* levels, not just within one.

**Coalescing rule**: within one round, a (src, dst) device pair may
carry up to ``coalesce_max`` blocks as extra lanes of the same permute
(one latency, unique non-trash scatter slots, per-lane accumulate /
transpose flags). Flat-tree roots and the xfer phases send many blocks
between the same pair, so the global round count drops well below the
level-serial path's — same bytes, fewer rounds
(:func:`overlapped_byte_counts` == ``simulator.volumes``, tested).

The level-barrier executor (:func:`compile_exec` + ``make_sweep``)
remains fully supported for A/B comparison — ``run_distributed(...,
overlap=False)`` and ``benchmarks/pselinv_bench.py`` drive it.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .schedule import (BYTES_PER_ELT, CommEvent, ComputeTask, Grid2D,
                       pselinv_events)
from .symbolic import BlockStructure
from .trees import (HYBRID_FLAT_MAX, CommTree, TreeKind, build_tree,
                    cached_tree, stable_hash)

__all__ = [
    "PlanOptions", "PlanOp", "CommPlan", "build_plan", "tree_for",
    "merge_round_lists",
    "pack_edges", "CommRound", "LocalRound", "LevelExec", "ExecPlan",
    "compile_exec", "exec_byte_counts", "etree_levels",
    "GlobalRound", "ComputeOp", "OverlapLevel", "OverlappedExec",
    "schedule_overlapped", "schedule_stream", "overlapped_byte_counts",
    "ppermute_round_count", "peak_arena_blocks",
]


@dataclass(frozen=True)
class PlanOptions:
    """The one knob bundle every schedule consumer reads.

    Collects what used to be scattered keyword arguments (``kind``,
    ``overlap``, ``coalesce_max``, ``window``) across ``build_program``,
    ``run_distributed``, :func:`schedule_overlapped` and the bench into a
    single hashable value — it is part of the
    :class:`~.engine.PSelInvEngine` structure-cache key, so two sessions
    with equal structure but different options compile independently.

    ``kind``: the tree family every restricted collective lowers through
    (:func:`tree_for`). ``overlap``: compile the cross-level overlapped
    round stream (the default executor) instead of the level-serial A/B
    baseline. ``coalesce_max``: max blocks one (src, dst) pair may carry
    as lanes of a single ppermute. ``window``: Û pool liveness window in
    adjacent elimination-tree levels (``None`` = whole sweep resident;
    see :func:`schedule_overlapped`). ``stream``: additionally lower the
    overlapped round stream into the uniform round-indexed device tables
    of ``core/stream.py`` and execute the whole sweep as one
    ``lax.fori_loop`` body (program size independent of the round count
    — the same rounds, replayed from tables instead of unrolled code;
    requires ``overlap=True``).

    ``axis_factored``: encode stream communication over the ``(pr, pc)``
    grid torus instead of the flat device ring — the packer groups
    equal-priority lanes by their grid offset ``(dr, dc)`` so lanes
    sharing an offset land in the same round, and the stream lowering
    emits per-(offset, width) comm *slots* gated by a per-round
    active-slot mask (``core/stream.py``); each round then pays only
    the wire bytes of the slots it actually uses, instead of shipping
    every device's payload on every ring shift of the whole sweep
    (the PR-5 flat-ring behavior, recovered with ``False``).
    ``shift_budget``: optional cap on the stream's comm-slot dictionary
    — exact-width slots are coarsened (power-of-two width classes, then
    one slot per grid offset) until the cap is met, trading wire bytes
    back for fewer gated permutes in the loop body. Requires
    ``axis_factored=True`` (the flat-ring lowering has exactly one slot
    per ring shift already).

    ``verify``: the PlanLint mode applied to every lowered artifact at
    ``build_program`` time (``core/verify.py``): ``"error"`` (default)
    raises :class:`~.verify.PlanVerificationError` on any ERROR-severity
    diagnostic, ``"warn"`` reduces the report to one ``warnings.warn``,
    ``"off"`` skips the static pass.

    ``verify_compiled``: the HloLint mode (``core/hlo_verify.py``)
    applied to the *compiled* layers — the traced jaxpr and lowered
    StableHLO of the program's own sweep, traced on an abstract mesh at
    ``build_program`` time (no devices needed; same three modes).
    Default ``"off"``: the pass re-traces and re-lowers the whole sweep
    (seconds, not microseconds), so it is opt-in per session —
    ``tools/hlo_lint.py``, ``tools/plan_lint.py --compiled`` and the
    tier-1 conformance tests run it over every shipped shape, and
    ``PSelInvEngine.lint_compiled`` adds the optimized-HLO layer from a
    real XLA compile."""
    kind: TreeKind = TreeKind.SHIFTED
    overlap: bool = True
    coalesce_max: int = 8
    window: int | None = None
    stream: bool = False
    axis_factored: bool = True
    shift_budget: int | None = None
    verify: str = "error"
    verify_compiled: str = "off"

    def __post_init__(self):
        if self.verify not in ("error", "warn", "off"):
            raise ValueError(
                f"PlanOptions(verify={self.verify!r}) — expected one of "
                "'error', 'warn', 'off'")
        if self.verify_compiled not in ("error", "warn", "off"):
            raise ValueError(
                f"PlanOptions(verify_compiled={self.verify_compiled!r}) "
                "— expected one of 'error', 'warn', 'off'")
        if self.stream and not self.overlap:
            raise ValueError(
                "PlanOptions(stream=True) lowers the *overlapped* round "
                "stream — it requires overlap=True (the level-serial "
                "executor has no global round stream to lower)")
        if self.shift_budget is not None:
            if not self.axis_factored:
                raise ValueError(
                    "PlanOptions(shift_budget=...) coarsens the "
                    "axis-factored slot dictionary — it requires "
                    "axis_factored=True (the flat-ring lowering has one "
                    "slot per ring shift already)")
            if self.shift_budget < 1:
                raise ValueError(
                    f"shift_budget must be >= 1, got {self.shift_budget}")


# ---------------------------------------------------------------------------
# tree construction (the one place a schedule becomes a concrete tree)
# ---------------------------------------------------------------------------

def tree_for(kind: TreeKind, root: int, participants: Sequence[int],
             tag: int) -> CommTree:
    """The canonical collective → tree lowering. FLAT/BINARY trees depend
    only on the participant set (memoized); SHIFTED/HYBRID decorrelate
    concurrent collectives through the tag-seeded rotation. HYBRID is the
    paper's §4.2 per-collective dispatch keyed on participant count: at
    or below :data:`~.trees.HYBRID_FLAT_MAX` participants the collective
    is a flat tree — tag-independent, so it routes through the memoized
    FLAT path instead of rebuilding per tag — and above it the tag-seeded
    shifted-binary tree."""
    receivers = tuple(r for r in participants if r != root)
    if kind is TreeKind.HYBRID and len(receivers) + 1 <= HYBRID_FLAT_MAX:
        kind = TreeKind.FLAT
    if kind in (TreeKind.FLAT, TreeKind.BINARY):
        return cached_tree(kind.value, root, receivers, 0)
    return build_tree(kind, root, receivers, tag=tag)


def merge_round_lists(per_tree: Sequence[List[List[Tuple[int, int]]]],
                      op: str) -> List[List[Tuple[int, int]]]:
    """Merge several *disjoint-group* collectives' per-round (src, dst)
    edge lists into shared rounds: broadcasts left-aligned (roots fire
    first), reductions right-aligned (every root combines on the last
    round). Raises ``ValueError`` naming the colliding pairs if the trees
    are not disjoint within a round — a device may source/sink at most one
    transfer per ``ppermute``."""
    n = max((len(r) for r in per_tree), default=0)
    merged: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for rounds in per_tree:
        shift = 0 if op == "bcast" else n - len(rounds)
        for i, rnd in enumerate(rounds):
            merged[i + shift].extend(rnd)
    for i, rnd in enumerate(merged):
        srcs = [s for s, _ in rnd]
        dsts = [d for _, d in rnd]
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            dup_s = sorted({s for s in srcs if srcs.count(s) > 1})
            dup_d = sorted({d for d in dsts if dsts.count(d) > 1})
            bad = [(s, d) for (s, d) in rnd
                   if s in dup_s or d in dup_d]
            raise ValueError(
                f"merged trees are not disjoint in round {i}: pairs {bad} "
                f"reuse sources {dup_s} / destinations {dup_d}")
    return merged


def etree_levels(bs: BlockStructure) -> np.ndarray:
    """Depth of every supernode in the block elimination tree (roots at
    level 0). Supernodes at equal depth are independent in the
    selected-inversion sweep: struct(K) ⊆ ancestors(K), all at strictly
    smaller depth."""
    nsuper = bs.nsuper
    level = np.full(nsuper, -1, dtype=np.int64)
    for K in range(nsuper - 1, -1, -1):
        p = int(bs.parent[K])
        level[K] = 0 if p < 0 else level[p] + 1
    # parent(K) > K, so a reverse scan sees parents first
    return level


# ---------------------------------------------------------------------------
# the IR
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PlanOp:
    """One restricted collective with its concrete tree.

    ``exec_only`` marks the symmetric-case bookkeeping transfers
    (``xfer-out`` transpose handoff, ``diag-reduce``) that the executable
    sweep performs but the paper's volume accounting (§4.1) does not
    report — ``volumes``/``simulate`` skip them."""
    kind: str
    supernode: int
    level: int
    root: int
    participants: Tuple[int, ...]
    nbytes: float
    tag: int
    tree: CommTree
    block: int = -1
    consumes: int = -1
    exec_only: bool = False


@dataclass
class CommPlan:
    """The static IR: every collective of one PSelInv pass, plus the
    elimination-tree level structure the executor pipelines over."""
    bs: BlockStructure
    grid: Grid2D
    kind: TreeKind
    nb: int                          # supernode count incl. grid padding
    ops: List[PlanOp]
    tasks: List[ComputeTask]
    level_of: np.ndarray             # (nsuper,)
    sweep_levels: List[List[int]]    # per level: supernodes with work
    diag_only: List[int]             # empty-struct supernodes (+ padding)

    def ops_by_supernode(self) -> Dict[int, List[PlanOp]]:
        out: Dict[int, List[PlanOp]] = defaultdict(list)
        for op in self.ops:
            out[op.supernode].append(op)
        return dict(out)


def build_plan(bs: BlockStructure, grid: Grid2D, kind: TreeKind,
               nb: int | None = None) -> CommPlan:
    """Lower the event enumeration into the CommPlan IR (trees built once,
    here, for every consumer)."""
    nsuper = bs.nsuper
    nb = nsuper if nb is None else int(nb)
    if nb < nsuper:
        raise ValueError(f"nb={nb} < nsuper={nsuper}")
    level = etree_levels(bs)
    w = bs.widths()
    pr, pc = grid.pr, grid.pc

    events, tasks = pselinv_events(bs, grid)
    ops: List[PlanOp] = []
    for ev in events:
        ops.append(PlanOp(
            kind=ev.kind, supernode=ev.supernode,
            level=int(level[ev.supernode]), root=ev.root,
            participants=ev.participants, nbytes=ev.nbytes, tag=ev.tag,
            tree=tree_for(kind, ev.root, ev.participants, ev.tag),
            block=ev.block, consumes=ev.consumes))

    # symmetric-case executor transfers (paper implementation detail:
    # A⁻¹(K,J) = A⁻¹(J,K)ᵀ is materialized by a transpose handoff, and the
    # diagonal correction Σ A⁻¹(K,I)·L̂(I,K) is reduced within row K%pr)
    for K in range(nsuper):
        C = [int(i) for i in bs.struct[K]]
        if not C:
            continue
        wk = float(w[K])
        krow, kcol = K % pr, K % pc
        for J in C:
            src = grid.owner(J, K)
            dst = grid.owner(K, J)
            if src == dst:
                continue
            parts = tuple(sorted({src, dst}))
            tag = (K << 20) ^ (J << 2) ^ 3
            ops.append(PlanOp(
                kind="xfer-out", supernode=K, level=int(level[K]),
                root=src, participants=parts,
                nbytes=float(w[J]) * wk * BYTES_PER_ELT, tag=tag,
                tree=tree_for(TreeKind.FLAT, src, parts, tag),
                block=J, exec_only=True))
        cols = sorted({I % pc for I in C} | {kcol})
        if len(cols) > 1:
            root = grid.owner(K, K)
            parts = tuple(sorted(krow * pc + c for c in cols))
            tag = stable_hash(K, 0xD)
            ops.append(PlanOp(
                kind="diag-reduce", supernode=K, level=int(level[K]),
                root=root, participants=parts,
                nbytes=wk * wk * BYTES_PER_ELT, tag=tag,
                tree=tree_for(kind, root, parts, tag),
                block=K, exec_only=True))

    nlev = int(level.max()) + 1 if nsuper else 0
    sweep_levels: List[List[int]] = [[] for _ in range(nlev)]
    diag_only: List[int] = []
    for K in range(nsuper):
        if len(bs.struct[K]):
            sweep_levels[int(level[K])].append(K)
        else:
            diag_only.append(K)
    diag_only.extend(range(nsuper, nb))
    # within a level, keep reverse elimination order (pure aesthetics —
    # same-level supernodes are independent)
    sweep_levels = [sorted(l, reverse=True) for l in sweep_levels if l]

    return CommPlan(bs=bs, grid=grid, kind=kind, nb=nb, ops=ops,
                    tasks=tasks, level_of=level,
                    sweep_levels=sweep_levels, diag_only=diag_only)


# ---------------------------------------------------------------------------
# executor compilation: ops -> packed rounds -> dense device tables
# ---------------------------------------------------------------------------

# an edge is (src_dev, dst_dev, src_slot, dst_slot, nbytes)
Edge = Tuple[int, int, int, int, float]


def pack_edges(edges: Sequence[Edge]) -> List[List[Edge]]:
    """Greedy-pack edges into ppermute rounds: per round each device
    sources at most one transfer and sinks at most one transfer."""
    rounds: List[List[Edge]] = []
    for e in edges:
        for rnd in rounds:
            if all(e[0] != q[0] and e[1] != q[1] for q in rnd):
                rnd.append(e)
                break
        else:
            rounds.append([e])
    return rounds


@dataclass
class CommRound:
    """One ppermute with per-device gather/scatter tables.

    ``slots[:, 0]`` is the flat gather index a sending device reads
    (don't-care 0 for non-senders — ppermute drops their payload);
    ``slots[:, 1]`` the flat scatter index a receiving device writes.
    Non-receivers point at the buffer's **trash slot** (index = buffer
    length): the executor allocates every writable buffer one block
    larger, so no receive mask and no read-modify-write select is needed
    — a write either lands or falls into the trash block."""
    perm: List[Tuple[int, int]]
    slots: np.ndarray         # (P, 2) int32 — [gather, scatter]
    edges: List[Edge] = field(default_factory=list)


@dataclass
class LocalRound:
    """Owner-local copy (src device == dst device): no communication,
    same gather/scatter table shape as :class:`CommRound`."""
    slots: np.ndarray         # (P, 2) int32


def _round_tables(edges: Sequence[Edge], P: int, trash: int) -> CommRound:
    slots = np.zeros((P, 2), np.int32)
    slots[:, 1] = trash
    perm = []
    for (s, d, ss, ds, _nb) in edges:
        perm.append((s, d))
        slots[s, 0] = ss
        slots[d, 1] = ds
    return CommRound(perm=perm, slots=slots, edges=list(edges))


def _local_rounds(ops: Sequence[Tuple[int, int, int]], P: int, trash: int
                  ) -> List[LocalRound]:
    """Pack (dev, src_slot, dst_slot) copies, one per device per round
    (an owner-local copy is an edge with src device == dst device)."""
    out = []
    for rnd in pack_edges([(dev, dev, ss, ds, 0.0)
                           for (dev, ss, ds) in ops]):
        slots = np.zeros((P, 2), np.int32)
        slots[:, 1] = trash
        for (dev, _d, ss, ds, _nb) in rnd:
            slots[dev, 0] = ss
            slots[dev, 1] = ds
        out.append(LocalRound(slots=slots))
    return out


def _schedule_tree_edges(per_op: Sequence[List[List[Edge]]], align: str,
                         P: int, trash: int) -> List[CommRound]:
    """Earliest-fire list scheduling of several collectives' tree edges
    into shared executable rounds (the asynchronous pipelining: an edge
    fires as soon as (1) its data dependency within its own tree is
    satisfied — for a broadcast the edge that delivered to its source,
    for a reduction every edge combining into its source — and (2) a
    ppermute slot is free, i.e. its source/destination device is not
    already used this round). Rounds are executed as barriers, so firing
    strictly after all dependencies is sufficient for correctness."""
    items: List[Tuple[Edge, List[int]]] = []
    for rounds in per_op:
        base = len(items)
        delivered: Dict[int, int] = {}     # node -> item index that fed it
        into: Dict[int, List[int]] = defaultdict(list)
        flat = [e for rnd in rounds for e in rnd]
        if align == "left":                # broadcast orientation
            for j, e in enumerate(flat):
                delivered[e[1]] = base + j
            for j, e in enumerate(flat):
                dep = delivered.get(e[0])
                items.append((e, [dep] if dep is not None else []))
        else:                              # reduce orientation
            for j, e in enumerate(flat):
                into[e[1]].append(base + j)
            for e in flat:
                items.append((e, list(into.get(e[0], ()))))

    fired = [None] * len(items)
    remaining = list(range(len(items)))
    out: List[CommRound] = []
    while remaining:
        used_s, used_d, this = set(), set(), []
        for i in remaining:
            e, deps = items[i]
            if any(fired[d] is None for d in deps):
                continue
            if e[0] in used_s or e[1] in used_d:
                continue
            this.append(i)
            used_s.add(e[0])
            used_d.add(e[1])
        if not this:
            raise ValueError("cyclic edge dependencies in tree schedule")
        for i in this:
            fired[i] = len(out)
        remaining = [i for i in remaining if fired[i] is None]
        out.append(_round_tables([items[i][0] for i in this], P, trash))
    return out


@dataclass
class LevelExec:
    """Dense tables driving one elimination-tree level of the sweep."""
    Ks: np.ndarray                   # (nk,) supernode ids
    xfer_in_local: List[LocalRound]  # Lh -> Uh (transpose), owner-local
    xfer_in: List[CommRound]         # Lh -> Uh (transpose), p2p
    bcast: List[CommRound]           # Uh -> Uh down grid columns
    cmask: np.ndarray                # (pc, nk, nbc) struct mask
    reduce: List[CommRound]          # partial -> partial along grid rows
    kcs: np.ndarray                  # (nk,) K // pc
    col_write_row: np.ndarray        # (pr, nk, nbr)
    col_write_col: np.ndarray        # (pc, nk)
    xfer_out_local: List[LocalRound]
    xfer_out: List[CommRound]        # Ainv -> Ainv (transpose), p2p
    krs: np.ndarray                  # (nk,) K // pr
    diag_rowmask: np.ndarray         # (pr, nk)
    diag_reduce: List[CommRound]     # S -> S within row K%pr
    diag_root: np.ndarray            # (nk,) owner(K,K) device id
    diag_slot: np.ndarray            # (nk,) flat Ainv slot of (K,K)


@dataclass
class ExecPlan:
    nb: int
    pr: int
    pc: int
    diag_set_root: np.ndarray        # (m,) device ids, empty-struct diag
    diag_set_slot: np.ndarray        # (m,) flat Ainv slots
    levels: List[LevelExec]

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc


def _level_tables(plan: CommPlan, Ks: Sequence[int]):
    """The per-level dense mask/index tables both executor lowerings
    share (one derivation — `compile_exec` and `_overlap_items` must
    never drift): cmask, col_write_row, col_write_col, diag_rowmask,
    kcs, krs, diag_root, diag_slot."""
    grid, nb = plan.grid, plan.nb
    pr, pc = grid.pr, grid.pc
    nbr, nbc = nb // pr, nb // pc
    nk = len(Ks)
    cmask = np.zeros((pc, nk, nbc))
    cw_row = np.zeros((pr, nk, nbr))
    cw_col = np.zeros((pc, nk))
    d_rowmask = np.zeros((pr, nk))
    for k, K in enumerate(Ks):
        for I in plan.bs.struct[K]:
            I = int(I)
            cmask[I % pc, k, I // pc] = 1.0
            cw_row[I % pr, k, I // pr] = 1.0
        cw_col[K % pc, k] = 1.0
        d_rowmask[K % pr, k] = 1.0
    return dict(
        cmask=cmask, col_write_row=cw_row, col_write_col=cw_col,
        diag_rowmask=d_rowmask,
        kcs=np.array([K // pc for K in Ks], np.int32),
        krs=np.array([K // pr for K in Ks], np.int32),
        diag_root=np.array([grid.owner(K, K) for K in Ks], np.int32),
        diag_slot=np.array([(K // pr) * nbc + K // pc for K in Ks],
                           np.int32))


def compile_exec(plan: CommPlan) -> ExecPlan:
    """Compile the IR into the level-pipelined executable form: every
    collective of a level shares rounds with its independent siblings."""
    grid, nb = plan.grid, plan.nb
    pr, pc, P = grid.pr, grid.pc, grid.size
    if nb % pr or nb % pc:
        raise ValueError(f"nb={nb} not divisible by grid {pr}x{pc}")
    nbr, nbc = nb // pr, nb // pc
    bs = plan.bs
    by_sn = plan.ops_by_supernode()

    droot = np.array([grid.owner(K, K) for K in plan.diag_only],
                     dtype=np.int32)
    dslot = np.array([(K // pr) * nbc + K // pc for K in plan.diag_only],
                     dtype=np.int32)

    levels: List[LevelExec] = []
    for Ks in plan.sweep_levels:
        nk = len(Ks)
        k_of = {K: k for k, K in enumerate(Ks)}
        xi_local: List[Tuple[int, int, int]] = []
        xi_edges: List[Edge] = []
        bcast_ops: List[List[List[Edge]]] = []
        red_ops: List[List[List[Edge]]] = []
        xo_local: List[Tuple[int, int, int]] = []
        xo_edges: List[Edge] = []
        dred_ops: List[List[List[Edge]]] = []
        tabs = _level_tables(plan, Ks)

        for K in Ks:
            k = k_of[K]
            C = [int(i) for i in bs.struct[K]]
            for I in C:
                # owner-local transfers are layout copies, not comm ops
                if grid.owner(I, K) == grid.owner(K, I):
                    xi_local.append((grid.owner(I, K),
                                     (I // pr) * nbc + K // pc,
                                     k * nbc + I // pc))
                    xo_local.append((grid.owner(I, K),
                                     (I // pr) * nbc + K // pc,
                                     (K // pr) * nbc + I // pc))

            for op in by_sn.get(K, ()):
                if op.kind == "xfer":
                    I = op.block
                    dst = [r for r in op.participants if r != op.root][0]
                    xi_edges.append((op.root, dst,
                                     (I // pr) * nbc + K // pc,
                                     k * nbc + I // pc, op.nbytes))
                elif op.kind == "col-bcast":
                    I = op.block
                    slot = k * nbc + I // pc
                    bcast_ops.append(
                        [[(s, d, slot, slot, op.nbytes) for (s, d) in rnd]
                         for rnd in op.tree.bcast_rounds()])
                elif op.kind == "row-reduce":
                    J = op.block
                    slot = k * nbr + J // pr
                    red_ops.append(
                        [[(s, d, slot, slot, op.nbytes) for (s, d) in rnd]
                         for rnd in op.tree.reduce_rounds()])
                elif op.kind == "xfer-out":
                    J = op.block
                    dst = [r for r in op.participants if r != op.root][0]
                    xo_edges.append((op.root, dst,
                                     (J // pr) * nbc + K // pc,
                                     (K // pr) * nbc + J // pc, op.nbytes))
                elif op.kind == "diag-reduce":
                    dred_ops.append(
                        [[(s, d, k, k, op.nbytes) for (s, d) in rnd]
                         for rnd in op.tree.reduce_rounds()])
                elif op.kind == "diag-bcast":
                    pass   # loop-1 normalization is absorbed on the host
                           # (prepare_inputs ships L̂/D⁻¹ pre-normalized)
                else:
                    raise ValueError(
                        f"compile_exec cannot lower op kind {op.kind!r} — "
                        "teach it the new kind or the executed schedule "
                        "silently drifts from the simulated one")

        t_uh = nk * nbc           # trash slot of each writable buffer
        t_pf = nk * nbr
        t_ai = nbr * nbc
        levels.append(LevelExec(
            Ks=np.asarray(Ks, dtype=np.int64),
            xfer_in_local=_local_rounds(xi_local, P, t_uh),
            xfer_in=[_round_tables(r, P, t_uh)
                     for r in pack_edges(xi_edges)],
            bcast=_schedule_tree_edges(bcast_ops, "left", P, t_uh),
            cmask=tabs["cmask"],
            reduce=_schedule_tree_edges(red_ops, "right", P, t_pf),
            kcs=tabs["kcs"],
            col_write_row=tabs["col_write_row"],
            col_write_col=tabs["col_write_col"],
            xfer_out_local=_local_rounds(xo_local, P, t_ai),
            xfer_out=[_round_tables(r, P, t_ai)
                      for r in pack_edges(xo_edges)],
            krs=tabs["krs"],
            diag_rowmask=tabs["diag_rowmask"],
            diag_reduce=_schedule_tree_edges(dred_ops, "right", P, nk),
            diag_root=tabs["diag_root"],
            diag_slot=tabs["diag_slot"]))

    return ExecPlan(nb=nb, pr=pr, pc=pc, diag_set_root=droot,
                    diag_set_slot=dslot, levels=levels)


# ---------------------------------------------------------------------------
# overlapped cross-level lowering: one global round stream + coalescing
# ---------------------------------------------------------------------------

#: phase ordering inside the packing priority (lower fires first when
#: competing for the same ppermute slot)
_PH_XI, _PH_BC, _PH_RED, _PH_XO, _PH_DRED = range(5)


@dataclass
class _Item:
    """One schedulable unit of the overlapped sweep: a comm edge, an
    owner-local copy, or a compute op. ``deps`` are item indices that must
    fire strictly earlier (edges/locals: an earlier round; compute: the
    same or an earlier round boundary)."""
    prio: Tuple[int, int, int]
    deps: List[int] = field(default_factory=list)
    src: int = -1
    dst: int = -1
    gslot: int = 0
    dslot: int = 0
    add: bool = False
    transpose: bool = False
    kind: str = ""                 # op kind for byte accounting
    level: int = -1
    nbytes: float = 0.0
    local: bool = False
    compute: str = ""              # "gemm" | "write" | "scomp" | "diagw"
    from_lh: bool = False          # gather from the input L̂ shard, not
                                   # the arena (xfer-in lanes only)


@dataclass
class GlobalRound:
    """One ppermute of the global overlapped stream. The payload is a
    stack of ``width`` (b, b) blocks: a (src, dst) pair that carries
    several coalesced blocks uses several lanes of the same permute;
    devices with fewer blocks pad (gather lane 0, scatter to trash).

    Per-device tables (all (P, width)): ``gather``/``scatter`` flat arena
    slots, ``addm`` 1.0 where the lane accumulates (reductions) instead of
    overwriting, ``tmask`` True where the receiver transposes the lane
    (the L̂→Û and A⁻¹ symmetric handoffs), ``glh`` True where the sender
    gathers from the resident input L̂ shard instead of the arena (the
    xfer-in lanes; the arena holds no L̂ copy — the lane's gather index
    is then a flat [0, N) L̂ slot). ``lgather``/``lscatter``/``ltmask``/
    ``lglh`` ((P, lwidth)) are owner-local copies executed before the
    permute. ``edges`` keeps (src, dst, kind, level, nbytes) per lane for
    byte accounting and the dependence-property tests."""
    perm: List[Tuple[int, int]]
    width: int
    gather: np.ndarray
    scatter: np.ndarray
    addm: np.ndarray
    tmask: np.ndarray
    edges: List[Tuple[int, int, str, int, float]]
    glh: np.ndarray | None = None
    lwidth: int = 0
    lgather: np.ndarray | None = None
    lscatter: np.ndarray | None = None
    ltmask: np.ndarray | None = None
    lglh: np.ndarray | None = None
    lmoves: List[Tuple[int, str, int]] = field(default_factory=list)


@dataclass(frozen=True)
class ComputeOp:
    """A compute step fired at a round boundary (before that round's
    comm): the level's masked GEMM, the A⁻¹(C,K) column write, the
    diagonal partial-sum S, or the diagonal write."""
    kind: str                      # "gemm" | "write" | "scomp" | "diagw"
    level: int                     # index into OverlappedExec.levels


@dataclass
class OverlapLevel:
    """Per-level compute metadata of the overlapped stream (the masks of
    :class:`LevelExec`) plus the level's arena addressing. ``u_gather``
    replaces the dense Û base offset: the level's Û blocks live in
    compact recycled pool slots (:func:`_u_pool_layout`), and the table
    maps the GEMM's dense (k, j) lane grid back onto them (trash where
    no struct entry exists — the struct mask zeroes those lanes).
    ``base_p``/``base_s`` point into the single *shared* partial / S
    regions every generation aliases; the scheduler's anti-dependences
    keep aliased occupancies disjoint in time."""
    Ks: np.ndarray
    u_gather: np.ndarray           # (P, nk*nbc) arena addresses of Û lanes
    base_p: int                    # partial stack offset (nk*nbr blocks)
    base_s: int                    # diagonal S stack offset (nk blocks)
    cmask: np.ndarray              # (pc, nk, nbc)
    kcs: np.ndarray
    col_write_row: np.ndarray
    col_write_col: np.ndarray
    krs: np.ndarray
    diag_rowmask: np.ndarray
    diag_root: np.ndarray
    diag_slot: np.ndarray


@dataclass
class OverlappedExec:
    """The overlapped compilation: a single global sequence of coalesced
    ppermute rounds spanning every elimination-tree level, plus the
    compute ops pinned to round boundaries (``compute_at[t]`` runs before
    round ``t``; the final entry after the last round). The arena is one
    flat per-device block buffer: [0, n_ainv) A⁻¹, then the compact
    recycled Û slot pool (:func:`_u_pool_layout`), then **one** shared
    partial region and one shared S region that every elimination-tree
    level aliases (their liveness never spans two levels), with the
    shared trash block last. The read-only input L̂ shard is **not**
    copied in: xfer-in lanes gather straight from it through the
    per-lane ``glh``/``lglh`` masks of :class:`GlobalRound`, which
    shaves ``n_ainv`` blocks off the footprint and puts the overlapped
    peak *below* the level-serial executor's. Generations that alias
    the same physical slots are separated in time by the scheduler's
    generation-keyed anti-dependences (see :func:`_overlap_items`), so
    the arena footprint no longer grows with the number of levels."""
    nb: int
    pr: int
    pc: int
    n_ainv: int
    arena_blocks: int              # trash included
    trash: int
    diag_set_root: np.ndarray
    diag_set_slot: np.ndarray
    levels: List[OverlapLevel]
    rounds: List[GlobalRound]
    compute_at: List[List[ComputeOp]]   # len == len(rounds) + 1
    window: int | None = None      # Û pool liveness window (None = whole
                                   # sweep resident, no Û recycling)

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc


def exec_byte_counts(ex: "ExecPlan | OverlappedExec"
                     ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """Per-rank outgoing/incoming bytes by phase kind, summed over the
    *compiled* rounds — the bytes the device program actually moves. The
    equivalence test checks these against ``simulator.volumes`` (same
    plan, independent accounting path). Accepts both the level-serial
    :class:`ExecPlan` and the cross-level :class:`OverlappedExec`."""
    if isinstance(ex, OverlappedExec):
        return overlapped_byte_counts(ex)
    P = ex.pr * ex.pc
    out: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    inc: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))

    def add(kind: str, rounds: List[CommRound]):
        for rnd in rounds:
            for (s, d, _ss, _ds, nb_) in rnd.edges:
                out[kind][s] += nb_
                inc[kind][d] += nb_

    for lv in ex.levels:
        add("xfer", lv.xfer_in)
        add("col-bcast", lv.bcast)
        add("row-reduce", lv.reduce)
        add("xfer-out", lv.xfer_out)
        add("diag-reduce", lv.diag_reduce)
    return dict(out), dict(inc)


def overlapped_byte_counts(ov: OverlappedExec
                           ) -> Tuple[Dict[str, np.ndarray],
                                      Dict[str, np.ndarray]]:
    """Per-rank outgoing/incoming bytes by op kind over the overlapped
    global rounds. Coalescing moves the same bytes in fewer rounds, so
    these must equal :func:`exec_byte_counts` of the level-serial path
    and ``simulator.volumes`` (tested)."""
    P = ov.pr * ov.pc
    out: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    inc: Dict[str, np.ndarray] = defaultdict(lambda: np.zeros(P))
    for rnd in ov.rounds:
        for (s, d, kind, _lv, nb_) in rnd.edges:
            out[kind][s] += nb_
            inc[kind][d] += nb_
    return dict(out), dict(inc)


def ppermute_round_count(ex: "ExecPlan | OverlappedExec") -> int:
    """Number of ``lax.ppermute`` rounds a compiled sweep issues (local
    copy rounds are free and not counted)."""
    if isinstance(ex, OverlappedExec):
        return sum(1 for r in ex.rounds if r.perm)
    return sum(len(lv.xfer_in) + len(lv.bcast) + len(lv.reduce)
               + len(lv.xfer_out) + len(lv.diag_reduce)
               for lv in ex.levels)


def peak_arena_blocks(ex: "ExecPlan | OverlappedExec") -> int:
    """Peak per-device working-buffer footprint of a compiled sweep, in
    (b, b) blocks — the memory axis of the scalability story (the
    symmetric-case PSelInv paper's per-process memory bound).

    Level-serial: A⁻¹ (N + 1 trash) + the input L̂ shard (N, read in
    place) + the largest level's transient Û/partial/S stacks (one
    trash block each, freed at the level barrier). Overlapped: the flat
    arena (A⁻¹ + the compact recycled Û pool + the shared partial/S
    regions + trash, :class:`OverlappedExec`) **plus** the resident
    input L̂ shard — xfer-in lanes gather straight from the input
    through the per-lane ``glh`` masks, so the arena holds no L̂ copy
    and only the input's N blocks count. The read-only D⁻¹ shard
    (N blocks) is input-resident in both paths and excluded, so the two
    numbers compare like for like; before slot recycling the overlapped
    arena dense-stacked *every* level's Û/partial/S and peaked at ~3×
    the serial path at nb=32, compaction brought it to ~1.2×, and
    dropping the arena L̂ copy lands it *below* the serial peak
    (~0.9×, asserted in the bench and tests)."""
    N = ex.nbr * ex.nbc
    if isinstance(ex, OverlappedExec):
        return ex.arena_blocks + N
    lvl = max((len(lv.Ks) * (ex.nbc + ex.nbr + 1) + 3 for lv in ex.levels),
              default=0)
    return 2 * N + 1 + lvl


def _u_pool_layout(plan: CommPlan, window: int | None
                   ) -> Tuple[List[Dict[Tuple[int, int], Tuple[int, int]]],
                              int]:
    """The overlapped arena's Û **slot allocator**: compact, per-column,
    liveness-window recycled.

    The level-serial executor's dense Û indexing (slot ``k*nbc + I//pc``)
    reserves ``nk*nbc`` blocks per level although only struct-present
    (K, I) pairs are ever filled; summed over every level of the sweep
    that dense layout is what blew the overlapped arena to ~3-4× the
    serial peak. Here each level's Û stack gets one compact slot per
    live (K, I) entry instead, allocated **per grid column** (a block
    Û(K, I) only exists on the devices of column ``I % pc``, so the two
    columns' allocators share the same address range — the same arena
    address holds different blocks on different columns, exactly like
    the dense layout's repeated slot numbers, and the dependence keys
    stay (device, slot, generation)).

    Liveness: a level's Û slots are written from its first xfer-in and
    last read by its ``scomp`` — so a slot is *dead* once its tenant
    level's scomp has fired. The allocator hands out fresh addresses
    while a column's pool is under its cap and otherwise **recycles the
    oldest freed slot** (FIFO by tenant level), recording the previous
    tenant's generation so the scheduler can key the WAR anti-dependence
    on that tenant's scomp. ``window=None`` (the default) sets the cap
    to the whole sweep — no Û recycling, which preserves the
    unthrottled prefetch schedule (round counts unchanged) while the
    compaction alone keeps the pool below one level's dense stack.
    ``window=w`` caps each column's pool at the largest total of ``w``
    consecutive levels, i.e. at most ~w adjacent generations live.

    Returns (per level: {(k, I) -> (address, previous-tenant level or
    -1)}, pool size in blocks). Addresses are relative to the pool
    base."""
    from collections import deque

    pc = plan.grid.pc
    bs = plan.bs
    nlev = len(plan.sweep_levels)
    entries: List[Dict[int, List[Tuple[int, int]]]] = []
    for Ks in plan.sweep_levels:
        per_c: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
        for k, K in enumerate(Ks):
            for I in bs.struct[K]:
                I = int(I)
                per_c[I % pc].append((k, I))
        entries.append({c: sorted(v) for c, v in per_c.items()})

    caps: Dict[int, int] = {}
    for c in range(pc):
        sizes = [len(entries[L].get(c, ())) for L in range(nlev)]
        if window is None:
            caps[c] = sum(sizes)
        else:
            w = max(1, int(window))
            caps[c] = max((sum(sizes[i:i + w])
                           for i in range(max(1, nlev - w + 1))), default=0)

    out: List[Dict[Tuple[int, int], Tuple[int, int]]] = []
    used = {c: 0 for c in range(pc)}
    free_q: Dict[int, deque] = {c: deque() for c in range(pc)}
    for L in range(nlev):
        amap: Dict[Tuple[int, int], Tuple[int, int]] = {}
        for c, ents in entries[L].items():
            for (k, I) in ents:
                if used[c] < caps[c] or not free_q[c]:
                    amap[(k, I)] = (used[c], -1)
                    used[c] += 1
                else:
                    addr, tenant = free_q[c].popleft()
                    amap[(k, I)] = (addr, tenant)
        for c, ents in entries[L].items():     # dead after scomp(L)
            for (k, I) in ents:
                free_q[c].append((amap[(k, I)][0], L))
        out.append(amap)
    return out, max(used.values(), default=0)


def _overlap_items(plan: CommPlan, window: int | None = None
                   ) -> Tuple[List[_Item], List[OverlapLevel],
                              int, int]:
    """Lower the CommPlan into the overlapped item DAG.

    Returns (items, level metadata, n_ainv, arena_blocks).
    Dependence model — RAW *and* WAR hazards on the arena are encoded as
    deps; reductions accumulate through dep-ordered adds:

      xfer-in(L)           — scomp(T) of the previous tenant T of its
                             recycled Û slot (WAR; no deps on fresh
                             slots — the payload only reads the static
                             L̂ shard)
      col-bcast(L) edge    — its in-tree parent edge; tree-root edges the
                             xfer-in item that filled the root's Û slot
                             (generation-keyed, see below)
      gemm(L)              — all xfer-in/col-bcast of L, plus every A⁻¹
                             write of level L-1 (write/xfer-out/diagw;
                             transitively all shallower levels), plus
                             write(L-1) (WAR: the shared partial region's
                             previous generation must be fully read)
      row-reduce(L) edge   — in-tree children edges + gemm(L)
      write(L)             — gemm(L) + all row-reduce(L)
      xfer-out(L)          — write(L)
      scomp(L)             — write(L) + all xfer-out(L) + diagw(L-1)
                             (WAR on the shared S region)
      diag-reduce(L) edge  — in-tree children edges + scomp(L)
      diagw(L)             — scomp(L) + all diag-reduce(L)

    Only the gemm→…→diagw chain serializes across levels; every
    xfer-in/col-bcast round of level L+1 is free to interleave with
    level L's GEMM-side rounds — the paper's §3 asynchronous pipelining
    across elimination-tree levels.

    **Liveness windows / slot recycling.** A level's Û slots are live
    from their fill to the level's scomp, the partial stack from gemm to
    write, the S stack from scomp to diagw. The partial and S stacks of
    different levels therefore *never* overlap in time — the compute
    chain itself separates the generations — so the arena keeps exactly
    **one** shared partial region and one shared S region (sized for the
    largest level), aliased by every generation at zero scheduling cost:
    the WAR deps ``write(L-1)`` / ``diagw(L-1)`` above are already
    implied by the RAW chain and encoded explicitly so the hazard model
    survives refactors. Û slots come from the compact recycled pool of
    :func:`_u_pool_layout`; a recycled slot's fill carries the previous
    tenant's ``scomp`` as an anti-dependence — ``scomp(T)`` dominates
    every reader of tenant T's slots (the broadcast forwards and the
    gemm all precede it by RAW deps), so one dep per slot suffices. The
    peak footprint drops from ~3× the level-serial executor's transient
    peak (O(Σ_L nk_L · nbc) dense-stacked blocks) to ~1.2×
    (:func:`peak_arena_blocks`, regression-guarded in the bench)."""
    grid, nb = plan.grid, plan.nb
    pr, pc = grid.pr, grid.pc
    if nb % pr or nb % pc:
        raise ValueError(f"nb={nb} not divisible by grid {pr}x{pc}")
    if window is not None and window < 1:
        raise ValueError(f"window={window} must be >= 1 (or None)")
    nbr, nbc = nb // pr, nb // pc
    bs = plan.bs
    by_sn = plan.ops_by_supernode()
    N = nbr * nbc

    # ---- arena layout: A⁻¹, then the compact recycled Û pool + one
    # shared partial region + one shared S region (single-generation
    # liveness). No L̂ region: xfer-in lanes gather from the resident
    # input shard directly (``from_lh`` → the executor's glh masks) ----
    u_pool, u_size = _u_pool_layout(plan, window)
    u_base = N
    base_p = u_base + u_size
    base_s = base_p + max((len(Ks) * nbr for Ks in plan.sweep_levels),
                          default=0)
    arena_blocks = base_s + max((len(Ks) for Ks in plan.sweep_levels),
                                default=0) + 1
    trash = arena_blocks - 1

    items: List[_Item] = []
    levels: List[OverlapLevel] = []
    prev_writers: List[int] = []       # A⁻¹-writing items of level L-1
    # last reader of each region per level (generation): recycling keys
    # the anti-dependence on the previous tenant's entry
    write_of: List[int] = []
    scomp_of: List[int] = []
    diagw_of: List[int] = []

    # (device, Û arena slot, generation) -> the xfer-in item that fills
    # it. The device is part of the key: the per-column allocators share
    # one address range, so equal slot numbers on *different* grid
    # columns hold different blocks, and a slot-only key would wire a
    # broadcast's root to the wrong fill. The *generation* (= level) is
    # part of the key because recycling makes slot numbers repeat across
    # levels: a (device, slot)-only lookup could resolve to the previous
    # tenant's fill and ship stale data into a broadcast
    u_filler: Dict[Tuple[int, int, int], int] = {}

    for L, Ks in enumerate(plan.sweep_levels):
        nk = len(Ks)
        k_of = {K: k for k, K in enumerate(Ks)}

        tabs = _level_tables(plan, Ks)

        # this level's Û slots: arena address + WAR dep (the previous
        # tenant's scomp) per (k, I) entry
        def u_slot(k: int, I: int) -> Tuple[int, List[int]]:
            addr, tenant = u_pool[L][(k, I)]
            return (u_base + addr,
                    [scomp_of[tenant]] if tenant >= 0 else [])

        # per-device gather table feeding the level GEMM / S einsum:
        # entry k*nbc + j holds the arena address of Û(K_k, j*pc + c) on
        # a column-c device, or the trash block where no struct entry
        # exists (the struct mask zeroes those lanes before use)
        u_gather = np.full((grid.size, nk * nbc), trash, np.int32)
        for (k, I), (addr, _tenant) in u_pool[L].items():
            for rho in range(pr):
                u_gather[rho * pc + I % pc, k * nbc + I // pc] = \
                    u_base + addr

        xi_bc_ids: List[int] = []
        red_ids: List[int] = []
        xo_ids: List[int] = []
        dred_ids: List[int] = []

        def _add(it: _Item) -> int:
            items.append(it)
            return len(items) - 1

        for K in Ks:
            k = k_of[K]
            C = [int(i) for i in bs.struct[K]]
            for I in C:
                if grid.owner(I, K) == grid.owner(K, I):
                    slot, war = u_slot(k, I)
                    i = _add(_Item(
                        prio=(L, _PH_XI, len(items)), deps=war,
                        local=True,
                        src=grid.owner(I, K), dst=grid.owner(I, K),
                        gslot=(I // pr) * nbc + K // pc, from_lh=True,
                        dslot=slot, transpose=True, kind="xfer-local",
                        level=L))
                    u_filler[(grid.owner(K, I), slot, L)] = i
                    xi_bc_ids.append(i)         # the owner-local fills
        for K in Ks:
            k = k_of[K]
            for op in by_sn.get(K, ()):
                if op.kind == "xfer":
                    I = op.block
                    dst = [r for r in op.participants if r != op.root][0]
                    slot, war = u_slot(k, I)
                    u_filler[(dst, slot, L)] = i = _add(_Item(
                        prio=(L, _PH_XI, len(items)), deps=war,
                        src=op.root, dst=dst,
                        gslot=(I // pr) * nbc + K // pc, from_lh=True,
                        dslot=slot, transpose=True, kind="xfer",
                        level=L, nbytes=op.nbytes))
                    xi_bc_ids.append(i)
                elif op.kind == "col-bcast":
                    I = op.block
                    slot, war = u_slot(k, I)
                    flat = [e for rnd in op.tree.bcast_rounds() for e in rnd]
                    delivered: Dict[int, int] = {}
                    for (s, d) in flat:
                        if s in delivered:
                            deps = [delivered[s]]
                        elif (s, slot, L) in u_filler:
                            deps = [u_filler[(s, slot, L)]]
                        else:
                            deps = list(war)
                        delivered[d] = _add(_Item(
                            prio=(L, _PH_BC, len(items)), deps=deps,
                            src=s, dst=d, gslot=slot, dslot=slot,
                            kind="col-bcast", level=L, nbytes=op.nbytes))
                        xi_bc_ids.append(delivered[d])
                elif op.kind in ("row-reduce", "diag-reduce",
                                 "xfer-out", "diag-bcast"):
                    pass      # lowered below / host-absorbed (diag-bcast)
                else:
                    raise ValueError(
                        f"schedule_overlapped cannot lower {op.kind!r} — "
                        "teach it the new kind or the executed schedule "
                        "silently drifts from the simulated one")

        # WAR on the shared partial region: write(L-1) is its previous
        # generation's last reader (transitively implied by the
        # gemm→write chain, but encoded explicitly so the hazard model
        # survives refactors)
        gemm_id = _add(_Item(prio=(L, _PH_BC, len(items)),
                             deps=xi_bc_ids + prev_writers
                             + ([write_of[L - 1]] if L else []),
                             compute="gemm", level=L))

        for K in Ks:
            k = k_of[K]
            for op in by_sn.get(K, ()):
                if op.kind != "row-reduce":
                    continue
                J = op.block
                slot = base_p + k * nbr + J // pr
                flat = [e for rnd in op.tree.reduce_rounds() for e in rnd]
                ids = [_add(_Item(prio=(L, _PH_RED, len(items)),
                                  src=s, dst=d, gslot=slot, dslot=slot,
                                  add=True, kind="row-reduce", level=L,
                                  nbytes=op.nbytes))
                       for (s, d) in flat]
                into: Dict[int, List[int]] = defaultdict(list)
                for i, (s, d) in zip(ids, flat):
                    into[d].append(i)
                for i, (s, d) in zip(ids, flat):
                    items[i].deps = into.get(s, []) + [gemm_id]
                red_ids.extend(ids)

        write_id = _add(_Item(prio=(L, _PH_RED, len(items)),
                              deps=[gemm_id] + red_ids,
                              compute="write", level=L))

        for K in Ks:
            k = k_of[K]
            C = [int(i) for i in bs.struct[K]]
            for I in C:
                if grid.owner(I, K) == grid.owner(K, I):
                    xo_ids.append(_add(_Item(
                        prio=(L, _PH_XO, len(items)), deps=[write_id],
                        local=True, src=grid.owner(I, K),
                        dst=grid.owner(I, K),
                        gslot=(I // pr) * nbc + K // pc,
                        dslot=(K // pr) * nbc + I // pc,
                        transpose=True, kind="xfer-out-local", level=L)))
            for op in by_sn.get(K, ()):
                if op.kind != "xfer-out":
                    continue
                J = op.block
                dst = [r for r in op.participants if r != op.root][0]
                xo_ids.append(_add(_Item(
                    prio=(L, _PH_XO, len(items)), deps=[write_id],
                    src=op.root, dst=dst,
                    gslot=(J // pr) * nbc + K // pc,
                    dslot=(K // pr) * nbc + J // pc,
                    transpose=True, kind="xfer-out", level=L,
                    nbytes=op.nbytes)))

        # WAR on the shared S region: diagw(L-1) is its previous
        # generation's last reader (also transitively implied; explicit
        # for the same reason)
        scomp_id = _add(_Item(prio=(L, _PH_XO, len(items)),
                              deps=[write_id] + xo_ids
                              + ([diagw_of[L - 1]] if L else []),
                              compute="scomp", level=L))

        for K in Ks:
            k = k_of[K]
            for op in by_sn.get(K, ()):
                if op.kind != "diag-reduce":
                    continue
                slot = base_s + k
                flat = [e for rnd in op.tree.reduce_rounds() for e in rnd]
                ids = [_add(_Item(prio=(L, _PH_DRED, len(items)),
                                  src=s, dst=d, gslot=slot, dslot=slot,
                                  add=True, kind="diag-reduce", level=L,
                                  nbytes=op.nbytes))
                       for (s, d) in flat]
                into = defaultdict(list)
                for i, (s, d) in zip(ids, flat):
                    into[d].append(i)
                for i, (s, d) in zip(ids, flat):
                    items[i].deps = into.get(s, []) + [scomp_id]
                dred_ids.extend(ids)

        diagw_id = _add(_Item(prio=(L, _PH_DRED, len(items)),
                              deps=[scomp_id] + dred_ids,
                              compute="diagw", level=L))

        prev_writers = [write_id, diagw_id] + xo_ids
        write_of.append(write_id)
        scomp_of.append(scomp_id)
        diagw_of.append(diagw_id)
        levels.append(OverlapLevel(
            Ks=np.asarray(Ks, dtype=np.int64),
            u_gather=u_gather, base_p=base_p, base_s=base_s, **tabs))

    return items, levels, N, arena_blocks


def schedule_overlapped(plan: CommPlan, coalesce_max: int = 8,
                        window: int | None = None, *,
                        axis_factored: bool = True,
                        options: PlanOptions | None = None
                        ) -> OverlappedExec:
    """Compile the IR into the cross-level overlapped executable form.
    ``options`` (a :class:`PlanOptions`) overrides the loose
    ``coalesce_max``/``window`` kwargs when given — the engine/session
    path passes the whole bundle through.

    List-schedules the item DAG of :func:`_overlap_items` into one global
    round sequence: an edge fires as soon as its dependences have fired
    in earlier rounds and a ppermute slot is free; compute ops fire at
    the earliest round boundary their inputs allow. Level L+1's xfer-in
    and col-bcast traffic therefore interleaves with level L's reduce /
    xfer-out / diagonal rounds instead of barriering on them.

    Coalescing: within one round a (src, dst) device pair may carry up to
    ``coalesce_max`` blocks as extra payload lanes of the same permute
    (flat trees and the xfer phases send many blocks between the same
    pair), so the global round count drops below the level-serial path's.
    Ready edges are packed lowest-(level, phase) first, which keeps the
    critical path as tight as the serial schedule while later levels'
    traffic fills the idle lanes.

    Arena memory: the partial and S stacks always live in one shared
    region per kind (their liveness never spans two levels), and the Û
    stacks come from the compact recycled slot pool of
    :func:`_u_pool_layout`. ``window`` caps how many adjacent levels' Û
    generations may be live at once — the anti-dependences of
    :func:`_overlap_items` serialize generations that alias a slot, so
    a tighter window trades prefetch depth (and, on this DAG shape,
    ppermute rounds: delayed fills contend with the critical-path tree
    traffic for permute slots) for arena blocks. The default ``None``
    keeps every level's compact Û slots resident, which preserves the
    unthrottled round count while compaction + partial/S recycling + the
    copy-free L̂ gathers hold the peak footprint *below* the
    level-serial executor's (~0.9×; :func:`peak_arena_blocks`, asserted
    ≤1.1× in the bench and strictly below serial in the tests).

    Shift-aware packing (``axis_factored``, the default): equal-priority
    ready edges are grouped by their grid-torus offset
    ``(dr, dc) = ((dst_r - src_r) mod pr, (dst_c - src_c) mod pc)``
    before packing, so lanes that share an offset land in the same round
    whenever the critical-path order allows it. The (level, phase)
    priority still dominates — the critical path is untouched — but the
    per-round *distinct-offset* count shrinks, which is what the
    gated stream lowering (``core/stream.py``) pays wire for."""
    if options is not None:
        coalesce_max, window = options.coalesce_max, options.window
        axis_factored = options.axis_factored
    grid = plan.grid
    P = grid.size
    items, levels, N, arena_blocks = _overlap_items(plan, window=window)
    trash = arena_blocks - 1

    droot = np.array([grid.owner(K, K) for K in plan.diag_only], np.int32)
    dslot = np.array([(K // grid.pr) * (plan.nb // grid.pc) + K // grid.pc
                      for K in plan.diag_only], np.int32)

    n = len(items)
    fired = [None] * n             # edges/locals: round; compute: boundary
    remaining = set(range(n))
    compute_order = [i for i in range(n) if items[i].compute]
    rounds: List[GlobalRound] = []
    compute_at: List[List[ComputeOp]] = [[]]

    def _deps_met(i: int, t: int) -> bool:
        for d in items[i].deps:
            if fired[d] is None:
                return False
            if not items[d].compute and fired[d] >= t:
                return False       # same-round edge: not yet visible
        return True

    t = 0
    while remaining:
        # fire every runnable compute op at boundary t (fixpoint: chained
        # ops like write→scomp may become runnable within one boundary)
        progress = True
        while progress:
            progress = False
            for i in compute_order:
                if i in remaining and _deps_met(i, t):
                    fired[i] = t
                    remaining.discard(i)
                    compute_at[t].append(
                        ComputeOp(items[i].compute, items[i].level))
                    progress = True
        if not remaining:
            break

        if axis_factored:
            # group equal-(level, phase) edges by grid-torus offset: the
            # insertion-order tiebreak moves *behind* the offset so lanes
            # sharing an offset pack into the same round — fewer distinct
            # offsets per round means fewer gated permutes (and fewer
            # executed wire bytes) in the stream lowering
            def _key(i):
                it = items[i]
                L, ph, order = it.prio
                if it.local:
                    return (L, ph, (-1, -1), order)
                dr = (it.dst // grid.pc - it.src // grid.pc) % grid.pr
                dc = (it.dst % grid.pc - it.src % grid.pc) % grid.pc
                return (L, ph, (dr, dc), order)
        else:
            def _key(i):
                return items[i].prio
        ready = sorted((i for i in remaining
                        if not items[i].compute and _deps_met(i, t)),
                       key=_key)
        pair_lanes: Dict[Tuple[int, int], List[int]] = {}
        used_src: set = set()
        used_dst: set = set()
        local_lanes: Dict[int, List[int]] = defaultdict(list)
        for i in ready:
            it = items[i]
            if it.local:
                if len(local_lanes[it.src]) < coalesce_max:
                    local_lanes[it.src].append(i)
                continue
            key = (it.src, it.dst)
            if key in pair_lanes:
                if len(pair_lanes[key]) < coalesce_max:
                    pair_lanes[key].append(i)
            elif it.src not in used_src and it.dst not in used_dst:
                pair_lanes[key] = [i]
                used_src.add(it.src)
                used_dst.add(it.dst)
        if not pair_lanes and not local_lanes:
            raise ValueError(
                f"overlapped scheduler stalled at round {t} with "
                f"{len(remaining)} items left — cyclic dependences")

        width = max((len(v) for v in pair_lanes.values()), default=0)
        gather = np.zeros((P, max(width, 1)), np.int32)
        scatter = np.full((P, max(width, 1)), trash, np.int32)
        addm = np.zeros((P, max(width, 1)), np.float32)
        tmask = np.zeros((P, max(width, 1)), bool)
        glh = np.zeros((P, max(width, 1)), bool)
        edges: List[Tuple[int, int, str, int, float]] = []
        perm = []
        for (s, d), lane_ids in pair_lanes.items():
            perm.append((s, d))
            for j, i in enumerate(lane_ids):
                it = items[i]
                gather[s, j] = it.gslot
                glh[s, j] = it.from_lh
                scatter[d, j] = it.dslot
                addm[d, j] = 1.0 if it.add else 0.0
                tmask[d, j] = it.transpose
                edges.append((s, d, it.kind, it.level, it.nbytes))
                fired[i] = t
                remaining.discard(i)

        lwidth = max((len(v) for v in local_lanes.values()), default=0)
        lg = ls = lt = llh = None
        lmoves: List[Tuple[int, str, int]] = []
        if lwidth:
            lg = np.zeros((P, lwidth), np.int32)
            ls = np.full((P, lwidth), trash, np.int32)
            lt = np.zeros((P, lwidth), bool)
            llh = np.zeros((P, lwidth), bool)
            for dev, lane_ids in local_lanes.items():
                for j, i in enumerate(lane_ids):
                    it = items[i]
                    lg[dev, j] = it.gslot
                    llh[dev, j] = it.from_lh
                    ls[dev, j] = it.dslot
                    lt[dev, j] = it.transpose
                    lmoves.append((dev, it.kind, it.level))
                    fired[i] = t
                    remaining.discard(i)

        # every non-trash write this round is unique per device. Across
        # rounds a slot may host several writers — reductions accumulate,
        # and recycled regions carry one generation per liveness window —
        # but within one round two lanes landing in the same (device,
        # slot) would silently drop a payload
        for dev in range(P):
            w = [x for x in scatter[dev] if x != trash]
            if lwidth:
                w += [x for x in ls[dev] if x != trash]
            if len(set(w)) != len(w):
                raise ValueError(
                    f"overlapped round {t}: device {dev} scatters twice "
                    f"into the same arena slot ({sorted(w)}) — the "
                    "one-writer-per-(device, slot, round) invariant is "
                    "broken")

        rounds.append(GlobalRound(
            perm=perm, width=width,
            gather=gather[:, :max(width, 1)],
            scatter=scatter[:, :max(width, 1)],
            addm=addm[:, :max(width, 1)], tmask=tmask[:, :max(width, 1)],
            glh=glh[:, :max(width, 1)],
            edges=edges, lwidth=lwidth, lgather=lg, lscatter=ls,
            ltmask=lt, lglh=llh, lmoves=lmoves))
        compute_at.append([])
        t += 1

    return OverlappedExec(
        nb=plan.nb, pr=grid.pr, pc=grid.pc, n_ainv=N,
        arena_blocks=arena_blocks, trash=trash,
        diag_set_root=droot, diag_set_slot=dslot,
        levels=levels, rounds=rounds, compute_at=compute_at, window=window)


def schedule_stream(plan: CommPlan, coalesce_max: int = 8,
                    window: int | None = None, *,
                    axis_factored: bool = True,
                    shift_budget: int | None = None,
                    options: PlanOptions | None = None):
    """Compile the IR into the **uniform round-stream** executable form:
    the overlapped lowering of :func:`schedule_overlapped`, lowered once
    more into round-indexed device tables (``core/stream.py``) that a
    single ``lax.fori_loop`` body replays — identical rounds, identical
    lane and accumulation order, program size independent of the round
    count. Returns ``(OverlappedExec, StreamTables)``: the overlapped
    object stays the source of truth for round counts, byte accounting
    and the arena footprint; the tables are what the device executes
    (``pselinv_dist.make_sweep_stream``). ``axis_factored`` /
    ``shift_budget`` select the grid-factored gated-slot comm encoding
    (see :class:`PlanOptions`); the ``options`` bundle overrides both."""
    from .stream import lower_stream
    if options is not None:
        axis_factored = options.axis_factored
        shift_budget = options.shift_budget
    ov = schedule_overlapped(plan, coalesce_max=coalesce_max,
                             window=window, axis_factored=axis_factored,
                             options=options)
    return ov, lower_stream(ov, axis_factored=axis_factored,
                            shift_budget=shift_budget)
