"""Distributed PSelInv on a JAX device mesh — the executable version of
the paper's algorithm with tree-based restricted collectives.

The selected-inversion sweep (Alg. 1, loop 2) runs as one SPMD program on
a flattened ``pr × pc`` grid ("xy" axis). Since this refactor the sweep
is driven end-to-end by the **CommPlan IR** (`core/plan.py`) — the same
plan object the simulator accounts, so the schedule that is *simulated*
is the schedule that *runs*:

  host plan   ``build_plan``      events → trees → per-edge bytes
  compile     ``compile_exec``    level batching → packed ppermute rounds
                                  → dense per-device index tables
  device      ``make_sweep``      table-driven gather / ppermute / scatter

Per elimination-tree **level** (all supernodes at equal etree depth are
independent — the paper's pipelining, executed rather than approximated):

    (a) xfer-in    L̂(I,K) → owner of Û(K,I)        [p2p rounds, batched]
    (b) col-bcast  Û(K,I) down its grid column      [trees, shared rounds]
    (1) one masked block-GEMM for the whole level   [kernels.ops]
    (c) row-reduce partials onto owner of A⁻¹(J,K)  [trees, shared rounds]
    (f) xfer-out   A⁻¹(J,K)ᵀ → A⁻¹(K,J) owner       [p2p, symmetric case]
    (2,3) diagonal update + restricted row reduce

Every comm round is one ``lax.ppermute`` of a single (b, b) block with
O(1) table lookups (``jnp.take`` + dynamic gather/scatter) — no per-pair
``jnp.where`` chains — so trace/compile time stays flat in the number of
concurrent collectives. The pre-IR per-supernode executor is kept as
``build_program_unrolled``/``make_sweep_unrolled`` for the compile-time
benchmark (``benchmarks/pselinv_bench.py``).

Symmetric matrices (as the paper's implementation): Û(K,I) = L̂(I,K)ᵀ and
A⁻¹(K,J) = A⁻¹(J,K)ᵀ — both identities hold blockwise for unpivoted LU.
Data is dense-blocked with uniform supernode width ``b`` and explicit
zeros for structurally-zero blocks: numerics are unaffected, while the
*communication* pattern is restricted to the true sparsity structure.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import shard_map
from ..kernels.ops import pselinv_level_gemm, pselinv_round_gemm
from .plan import (CommPlan, CommRound, ExecPlan, LocalRound,
                   OverlappedExec, PlanOptions, build_plan, compile_exec,
                   merge_round_lists, schedule_overlapped, schedule_stream)
from .stream import (COMP_DIAGW, COMP_GEMM, COMP_NOOP, COMP_SCOMP,
                     COMP_WRITE, StreamTables)
from .symbolic import BlockStructure, symbolic_factorize
from .supernodal_lu import factorize
from .selinv import normalize_factors
from .trees import CommTree, TreeKind, build_tree, stable_hash

__all__ = ["PSelInvProgram", "build_program", "build_program_unrolled",
           "make_sweep", "make_sweep_overlapped", "make_sweep_stream",
           "make_sweep_unrolled",
           "analyze_structure", "prepare_values", "prepare_values_many",
           "check_values_pattern", "prepare_inputs",
           "run_distributed", "gather_blocks"]


@dataclass
class PSelInvProgram:
    """A compiled sweep: grid geometry + (IR path) the CommPlan and its
    executable tables, or (legacy path) the per-supernode schedules."""
    nb: int
    b: int
    pr: int
    pc: int
    kind: TreeKind
    bs: BlockStructure
    plan: Optional[CommPlan] = None
    exec_plan: Optional[ExecPlan] = None
    overlap_plan: Optional[OverlappedExec] = None
    stream_tables: Optional[StreamTables] = None   # uniform round stream
    iters: Optional[list] = None        # legacy unrolled schedule

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc


# ---------------------------------------------------------------------------
# IR path: plan -> tables -> vectorized level-pipelined sweep
# ---------------------------------------------------------------------------

def build_program(bs: BlockStructure, nb: int, b: int, pr: int, pc: int,
                  kind: TreeKind = TreeKind.SHIFTED,
                  overlap: bool = False,
                  coalesce_max: int = 8,
                  window: int | None = None,
                  stream: bool = False, *,
                  options: PlanOptions | None = None,
                  verify: str = "error",
                  verify_compiled: str = "off") -> PSelInvProgram:
    """Build the CommPlan IR and compile it to executable tables.

    ``options`` (a :class:`~.plan.PlanOptions`) bundles and overrides
    the loose ``kind``/``overlap``/``coalesce_max``/``window``/``stream``
    kwargs — the engine/session API passes the whole bundle through so
    every consumer reads the same knobs.

    ``overlap=True`` compiles the cross-level overlapped round stream
    (`plan.schedule_overlapped`) consumed by
    :func:`make_sweep_overlapped`; ``overlap=False`` the level-serial
    :class:`ExecPlan` for :func:`make_sweep` (the A/B baseline). Only
    the requested lowering is compiled — an A/B caller builds one
    program per executor (as ``benchmarks/pselinv_bench.py`` does), or
    runs ``plan.compile_exec(prog.plan)`` on the shared CommPlan.
    ``window`` caps the overlapped arena's Û pool at that many live
    levels (None = whole sweep resident; see
    ``plan.schedule_overlapped``). ``stream=True`` (implies
    ``overlap=True``) additionally lowers the overlapped rounds into the
    uniform round-indexed tables of ``core/stream.py`` for
    :func:`make_sweep_stream` — the whole sweep as one ``lax.fori_loop``
    body.

    ``verify`` (overridden by ``options.verify`` when an options bundle
    is passed) runs the PlanLint static pass (``core/verify.py``) over
    every artifact just compiled: ``"error"`` raises
    :class:`~.verify.PlanVerificationError` on any ERROR-severity
    diagnostic, ``"warn"`` condenses the report into one
    ``warnings.warn``, ``"off"`` skips the pass.

    ``verify_compiled`` (overridden by ``options.verify_compiled``)
    additionally runs the HloLint compiled-artifact pass
    (``core/hlo_verify.py``): the program's own sweep is traced and
    lowered on an abstract mesh (no devices required) and the jaxpr /
    StableHLO layers are cross-checked against the tables just built —
    permute conformance, loop trip counts, wire-byte conservation,
    hot-path hygiene. Same three modes; default ``"off"`` because the
    pass costs a full re-trace + lowering of the sweep."""
    if options is not None:
        kind, overlap = options.kind, options.overlap
        coalesce_max, window = options.coalesce_max, options.window
        stream = options.stream
        verify = options.verify
        verify_compiled = options.verify_compiled
    if stream and not overlap:
        raise ValueError(
            "stream=True lowers the overlapped round stream — it "
            "requires overlap=True")
    if nb % pr or nb % pc:
        raise ValueError(f"nb={nb} not divisible by grid {pr}x{pc}")
    from ..obs.trace import TRACER
    from .schedule import Grid2D
    with TRACER.span("plan.build", nb=nb):
        plan = build_plan(bs, Grid2D(pr, pc), kind, nb=nb)
    ov = st = None
    with TRACER.span("plan.schedule", stream=stream, overlap=overlap):
        if stream:
            ov, st = schedule_stream(plan, coalesce_max=coalesce_max,
                                     window=window, options=options)
        elif overlap:
            ov = schedule_overlapped(plan, coalesce_max=coalesce_max,
                                     window=window, options=options)
        prog = PSelInvProgram(
            nb=nb, b=b, pr=pr, pc=pc, kind=kind, bs=bs, plan=plan,
            exec_plan=None if overlap else compile_exec(plan),
            overlap_plan=ov, stream_tables=st)
    if verify != "off":
        from .verify import enforce_verification, verify_program
        with TRACER.span("plan.verify", mode=verify):
            enforce_verification(
                verify_program(prog), mode=verify,
                where=f"build_program(nb={nb}, grid={pr}x{pc}, "
                      f"stream={stream}, overlap={overlap})")
    if verify_compiled != "off":
        from .hlo_verify import lint_program
        from .verify import enforce_verification
        with TRACER.span("plan.verify_compiled", mode=verify_compiled):
            enforce_verification(
                lint_program(prog), mode=verify_compiled,
                where=f"compiled sweep of build_program(nb={nb}, "
                      f"grid={pr}x{pc}, stream={stream}, "
                      f"overlap={overlap})")
    return prog


def _dyn(buf, i):
    return lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)


def _gi(buf, i):         # gather rows, bounds statically guaranteed
    return buf.at[i].get(mode="promise_in_bounds")


def _apply_comm_rounds(dst, rounds: Sequence[CommRound], idx, op: str,
                       src=None, transpose: bool = False):
    """Run packed single-block ppermute rounds: gather the sender's slot,
    permute, scatter at the receiver's slot. ``src=None`` gathers from the
    (updating) destination buffer — required for multi-round trees where
    internal nodes forward data received in earlier rounds.

    ``dst`` carries one extra trash block (see ``CommRound.slots``), so
    non-receivers need no mask: their write lands in the trash slot. Each
    round is a handful of lean ``lax.dynamic_*`` ops — trace size is flat
    in the number of concurrent collectives."""
    if not rounds:
        return dst
    # one fused (R, P, 2) table per phase — a single closed-over constant,
    # and a single dynamic lookup of this device's (R, 2) slot column
    tables = jnp.asarray(np.stack([r.slots for r in rounds]))
    slots = lax.dynamic_index_in_dim(tables, idx, 1, keepdims=False)
    for i, rnd in enumerate(rounds):
        buf = dst if src is None else src
        payload = _dyn(buf, slots[i, 0])
        moved = lax.ppermute(payload, "xy", rnd.perm)
        if transpose:
            moved = jnp.swapaxes(moved, -1, -2)
        if op != "set":
            moved = moved + _dyn(dst, slots[i, 1])
        dst = lax.dynamic_update_index_in_dim(dst, moved, slots[i, 1], 0)
    return dst


def _apply_local_rounds(dst, rounds: Sequence[LocalRound], idx,
                        src=None, transpose: bool = False):
    """Owner-local block copies (src owner == dst owner): same tables,
    no communication; non-participants copy into the trash slot."""
    if not rounds:
        return dst
    tables = jnp.asarray(np.stack([r.slots for r in rounds]))
    slots = lax.dynamic_index_in_dim(tables, idx, 1, keepdims=False)
    for i, rnd in enumerate(rounds):
        buf = dst if src is None else src
        blk = _dyn(buf, slots[i, 0])
        if transpose:
            blk = jnp.swapaxes(blk, -1, -2)
        dst = lax.dynamic_update_index_in_dim(dst, blk, slots[i, 1], 0)
    return dst


def _gather_lanes(arena, lh_f, g, lh_m, mixed: bool):
    """Per-lane select between the arena and the resident input L̂ shard
    (no arena copy of L̂ exists). ``mixed`` is the static whole-table
    check — streams/rounds without xfer-in lanes skip the second gather
    entirely; where lanes mix, indices are masked into the untaken
    buffer so both gathers stay in bounds. One definition shared by the
    overlapped and stream executors — the masking trick must never
    drift between them."""
    if not mixed:
        return _gi(arena, g)
    blks = _gi(arena, jnp.where(lh_m, 0, g))
    blks_l = _gi(lh_f, jnp.where(lh_m, g, 0))
    return jnp.where(lh_m[:, None, None], blks_l, blks)


def _wrap_sweep(body, batched: bool):
    """Lift a per-device sweep body into the shard_map calling
    convention. Single-matrix: per-device shards are (1, nbr, nbc, b, b)
    under ``in_specs=P("xy")``. Batched: shards are (B, 1, nbr, nbc, b,
    b) under ``in_specs=P(None, "xy")`` — the leading batch axis is
    vmapped through the *value* tensors only, while the closed-over
    index/mask tables (value-independent by construction) are shared
    across every lane, so a batch of B matrices with one structure costs
    one trace and one compile."""
    if batched:
        def sweep(Lh, Dinv):
            return jax.vmap(body)(Lh[:, 0], Dinv[:, 0])[:, None]
    else:
        def sweep(Lh, Dinv):
            return body(Lh[0], Dinv[0])[None]
    return sweep


def make_sweep(prog: PSelInvProgram, batched: bool = False):
    """Build the level-pipelined SPMD sweep from the compiled IR tables.
    Call inside shard_map over a 1-D mesh axis "xy" of size pr*pc, with
    per-device blocks Lh: (nbr, nbc, b, b), Dinv: (nbr, nbc, b, b).
    ``batched=True`` builds the multi-matrix variant (leading batch axis
    on the value tensors; see :func:`_wrap_sweep`)."""
    ex = prog.exec_plan
    if ex is None:
        raise ValueError("build_program() the IR path first")
    b, pr, pc = prog.b, prog.pr, prog.pc
    nbr, nbc = ex.nbr, ex.nbc

    def body(Lh, Dinv):
        idx = lax.axis_index("xy")
        r = idx // pc
        c = idx % pc
        dtype = Lh.dtype
        N = nbr * nbc
        Lh_f = Lh.reshape(N, b, b)
        Dinv_f = Dinv.reshape(N, b, b)
        # one extra trash block: non-receiving devices scatter into it
        Ainv_f = jnp.zeros((N + 1, b, b), dtype=dtype)

        # structless supernodes (leaves without fill + grid padding):
        # A⁻¹(K,K) = D(K)⁻¹ at the owner, one batched scatter
        if len(ex.diag_set_root):
            slots = jnp.asarray(ex.diag_set_slot)
            m = (jnp.asarray(ex.diag_set_root) == idx).astype(dtype)
            Ainv_f = Ainv_f.at[slots].add(
                m[:, None, None] * _gi(Dinv_f, slots),
                mode="promise_in_bounds")

        for lv in ex.levels:
            nk = len(lv.Ks)

            # ---- (a) xfer-in: build the level's stacked Û buffer -------
            Uh = jnp.zeros((nk * nbc + 1, b, b), dtype=dtype)
            Uh = _apply_local_rounds(Uh, lv.xfer_in_local, idx, src=Lh_f,
                                     transpose=True)
            Uh = _apply_comm_rounds(Uh, lv.xfer_in, idx, "set", src=Lh_f,
                                    transpose=True)

            # ---- (b) col-bcast down each grid column -------------------
            Uh = _apply_comm_rounds(Uh, lv.bcast, idx, "set")

            # ---- (1) one masked block-GEMM for the whole level ---------
            cm = jnp.take(jnp.asarray(lv.cmask, dtype=dtype), c, axis=0)
            Uh_m = Uh[:-1].reshape(nk, nbc, b, b) * cm[:, :, None, None]
            partial = pselinv_level_gemm(
                Ainv_f[:-1].reshape(nbr, nbc, b, b), Uh_m)  # (nk, nbr, b, b)

            # ---- (c) row-reduce onto the owners of A⁻¹(J,K) ------------
            pf = jnp.concatenate(
                [partial.reshape(nk * nbr, b, b),
                 jnp.zeros((1, b, b), dtype=dtype)])
            pf = _apply_comm_rounds(pf, lv.reduce, idx, "add")
            partial = pf[:-1].reshape(nk, nbr, b, b)

            # ---- write A⁻¹(C,K) for every K of the level ---------------
            kcs = jnp.asarray(lv.kcs)
            wr = jnp.take(jnp.asarray(lv.col_write_row, dtype=dtype), r,
                          axis=0)                          # (nk, nbr)
            wc = jnp.take(jnp.asarray(lv.col_write_col, dtype=dtype), c,
                          axis=0)                          # (nk,)
            w = jnp.transpose(wr * wc[:, None])            # (nbr, nk)
            Ainv = Ainv_f[:-1].reshape(nbr, nbc, b, b)
            old = Ainv.at[:, kcs].get(mode="promise_in_bounds")
            new = -jnp.swapaxes(partial, 0, 1)             # (nbr, nk, b, b)
            # masked delta + scatter-add: same-level K's write disjoint
            # (device, slot) pairs, so duplicate kcs entries add zeros
            Ainv = Ainv.at[:, kcs].add(w[:, :, None, None] * (new - old),
                                       mode="promise_in_bounds")
            Ainv_f = jnp.concatenate(
                [Ainv.reshape(N, b, b), Ainv_f[N:]])

            # ---- (f) xfer-out transposes A⁻¹(K,J) = A⁻¹(J,K)ᵀ ----------
            Ainv_f = _apply_local_rounds(Ainv_f, lv.xfer_out_local, idx,
                                         transpose=True)
            Ainv_f = _apply_comm_rounds(Ainv_f, lv.xfer_out, idx, "set",
                                        transpose=True)

            # ---- (2,3) diagonal:  A⁻¹(K,K) = D⁻¹ − (Σ A⁻¹(K,I)L̂(I,K))ᵀ
            krs = jnp.asarray(lv.krs)
            Arow = _gi(Ainv_f[:-1].reshape(nbr, nbc, b, b), krs)
            S = jnp.einsum("kjab,kjcb->kac",
                           Arow * cm[:, :, None, None], Uh_m)
            rm = jnp.take(jnp.asarray(lv.diag_rowmask, dtype=dtype), r,
                          axis=0)                          # (nk,)
            S = S * rm[:, None, None]
            S = jnp.concatenate([S, jnp.zeros((1, b, b), dtype=dtype)])
            S = _apply_comm_rounds(S, lv.diag_reduce, idx, "add")[:-1]
            slots = jnp.asarray(lv.diag_slot)
            m = (jnp.asarray(lv.diag_root) == idx).astype(dtype)
            newd = _gi(Dinv_f, slots) - jnp.swapaxes(S, -1, -2)
            Ainv_f = Ainv_f.at[slots].add(
                m[:, None, None] * (newd - _gi(Ainv_f, slots)),
                mode="promise_in_bounds")

        return Ainv_f[:-1].reshape(nbr, nbc, b, b)        # drop trash blk

    return _wrap_sweep(body, batched)


# ---------------------------------------------------------------------------
# overlapped path: one global cross-level round stream over a block arena
# ---------------------------------------------------------------------------


# The four arena compute phases of the overlapped sweep — ONE definition
# shared by the unrolled overlapped executor (per-level shapes, static
# tables) and the stream executor (NK-padded shapes, dynamically indexed
# tables): the delta-add and masking tricks below are the bit-identity
# contract between the two and must never drift. Each helper derives the
# supernode count from its table operands, so both shape regimes flow
# through the same code.

def _phase_gemm(arena, ut, cm, N, nbr, nbc, b, base_p):
    """Level GEMM: partial[k, i] = Σ_j A⁻¹[i, j] · Û_m[k, j]ᵀ into the
    shared partial region. ``ut`` are the (nk*nbc,) arena addresses of
    the Û lanes (trash where struct-absent — ``cm`` zeroes those)."""
    nk = ut.shape[0] // nbc
    U = _gi(arena, ut).reshape(nk, nbc, b, b)
    Ainv = lax.slice_in_dim(arena, 0, N).reshape(nbr, nbc, b, b)
    partial = pselinv_round_gemm(Ainv, U, cm)
    return lax.dynamic_update_slice(
        arena, partial.reshape(nk * nbr, b, b), (base_p, 0, 0))


def _phase_write(arena, kcs, wr, wc, N, nbr, nbc, b, base_p):
    """A⁻¹(C, K) column write for every K of the level: masked delta +
    scatter-add — same-level K's write disjoint (device, slot) pairs, so
    duplicate ``kcs`` entries add zeros."""
    nk = kcs.shape[0]
    partial = lax.slice_in_dim(
        arena, base_p, base_p + nk * nbr).reshape(nk, nbr, b, b)
    w = jnp.transpose(wr * wc[:, None])                # (nbr, nk)
    Ainv = lax.slice_in_dim(arena, 0, N).reshape(nbr, nbc, b, b)
    old = Ainv.at[:, kcs].get(mode="promise_in_bounds")
    new = -jnp.swapaxes(partial, 0, 1)                 # (nbr, nk, b, b)
    Ainv = Ainv.at[:, kcs].add(w[:, :, None, None] * (new - old),
                               mode="promise_in_bounds")
    return lax.dynamic_update_slice(
        arena, Ainv.reshape(N, b, b), (0, 0, 0))


def _phase_scomp(arena, ut, cm, krs, rm, N, nbr, nbc, b, base_s):
    """Diagonal partial sum S(K) = Σ_I A⁻¹(K, I) · L̂(I, K) into the
    shared S region (masked to row K%pr by ``rm``)."""
    nk = krs.shape[0]
    Uh_m = _gi(arena, ut).reshape(nk, nbc, b, b) * cm[:, :, None, None]
    Ainv = lax.slice_in_dim(arena, 0, N).reshape(nbr, nbc, b, b)
    Arow = _gi(Ainv, krs)
    S = jnp.einsum("kjab,kjcb->kac", Arow * cm[:, :, None, None], Uh_m)
    return lax.dynamic_update_slice(
        arena, S * rm[:, None, None], (base_s, 0, 0))


def _phase_diagw(arena, Dinv_f, slots, root, idx, N, base_s, dtype):
    """Diagonal write A⁻¹(K,K) = D⁻¹ − Sᵀ at the owner. ``slots`` may be
    padded with the trash block (stream path): those lanes carry a
    no-device root (mask 0) and the D⁻¹ gather clamps them in-bounds —
    an identity for the real, always-< N, slots."""
    nk = slots.shape[0]
    S = lax.slice_in_dim(arena, base_s, base_s + nk)
    m = (root == idx).astype(dtype)
    newd = _gi(Dinv_f, jnp.minimum(slots, N - 1)) - jnp.swapaxes(S, -1, -2)
    return arena.at[slots].add(
        m[:, None, None] * (newd - _gi(arena, slots)),
        mode="promise_in_bounds")

# The overlapped per-device body, factored into module-level pieces so
# the normal executor (`make_sweep_overlapped`) and the profiling replay
# (`make_sweep_segments`, driven by ``obs.rounds``) run the *same* code:
# the replay is the sweep cut at jit boundaries, not a re-implementation,
# so its per-round timings measure exactly what the fused sweep executes.

def _overlap_init(ov, b, Dinv_f, idx, dtype):
    """Fresh arena + structless-supernode diagonal seeds (leaves without
    fill + grid padding get A⁻¹(K,K) = D⁻¹ up front)."""
    arena = jnp.zeros((ov.arena_blocks, b, b), dtype=dtype)
    if len(ov.diag_set_root):
        slots = jnp.asarray(ov.diag_set_slot)
        m = (jnp.asarray(ov.diag_set_root) == idx).astype(dtype)
        arena = arena.at[slots].add(
            m[:, None, None] * _gi(Dinv_f, slots),
            mode="promise_in_bounds")
    return arena


def _overlap_compute(ov, op, arena, Dinv_f, idx, r, c, b, dtype):
    """One scheduled compute op at a round boundary. Numerics live in
    the shared ``_phase_*`` helpers (one definition with the stream
    executor); this just feeds them the level's static tables. The
    per-device Û gather table maps the dense (k, j) lane grid onto the
    compact recycled pool slots (trash lanes are struct-masked before
    use)."""
    N, nbr, nbc = ov.n_ainv, ov.nbr, ov.nbc
    lv = ov.levels[op.level]
    cm = jnp.take(jnp.asarray(lv.cmask, dtype=dtype), c, axis=0)
    if op.kind == "gemm":
        ut = jnp.take(jnp.asarray(lv.u_gather), idx, axis=0)
        return _phase_gemm(arena, ut, cm, N, nbr, nbc, b, lv.base_p)
    if op.kind == "write":
        wr = jnp.take(jnp.asarray(lv.col_write_row, dtype=dtype),
                      r, axis=0)                        # (nk, nbr)
        wc = jnp.take(jnp.asarray(lv.col_write_col, dtype=dtype),
                      c, axis=0)                        # (nk,)
        return _phase_write(arena, jnp.asarray(lv.kcs), wr, wc,
                            N, nbr, nbc, b, lv.base_p)
    if op.kind == "scomp":
        ut = jnp.take(jnp.asarray(lv.u_gather), idx, axis=0)
        rm = jnp.take(jnp.asarray(lv.diag_rowmask, dtype=dtype),
                      r, axis=0)                        # (nk,)
        return _phase_scomp(arena, ut, cm, jnp.asarray(lv.krs),
                            rm, N, nbr, nbc, b, lv.base_s)
    # "diagw":  A⁻¹(K,K) = D⁻¹ − (Σ A⁻¹(K,I)L̂(I,K))ᵀ
    return _phase_diagw(arena, Dinv_f, jnp.asarray(lv.diag_slot),
                        jnp.asarray(lv.diag_root), idx, N,
                        lv.base_s, dtype)


def _overlap_round(ov, t, arena, Lh_f, Dinv_f, idx, r, c, b, dtype):
    """One executed round: the boundary's pinned compute ops, the
    owner-local lane moves, then round ``t``'s coalesced multi-lane
    ppermute with per-lane gather/scatter/accumulate/transpose tables."""
    for op in ov.compute_at[t]:
        arena = _overlap_compute(ov, op, arena, Dinv_f, idx, r, c, b,
                                 dtype)
    rnd = ov.rounds[t]
    if rnd.lwidth:
        lg = jnp.take(jnp.asarray(rnd.lgather), idx, axis=0)
        ls = jnp.take(jnp.asarray(rnd.lscatter), idx, axis=0)
        lt = jnp.take(jnp.asarray(rnd.ltmask), idx, axis=0)
        llh = jnp.take(jnp.asarray(rnd.lglh), idx, axis=0)
        blks = _gather_lanes(arena, Lh_f, lg, llh, bool(rnd.lglh.any()))
        blks = jnp.where(lt[:, None, None],
                         jnp.swapaxes(blks, -1, -2), blks)
        # non-participating lanes land in the trash block
        arena = arena.at[ls].set(blks, mode="promise_in_bounds")
    if rnd.perm:
        g = jnp.take(jnp.asarray(rnd.gather), idx, axis=0)
        s_ = jnp.take(jnp.asarray(rnd.scatter), idx, axis=0)
        am = jnp.take(jnp.asarray(rnd.addm, dtype=dtype), idx, axis=0)
        tm = jnp.take(jnp.asarray(rnd.tmask), idx, axis=0)
        lh = jnp.take(jnp.asarray(rnd.glh), idx, axis=0)
        payload = _gather_lanes(arena, Lh_f, g, lh, bool(rnd.glh.any()))
        moved = lax.ppermute(payload, "xy", rnd.perm)
        moved = jnp.where(tm[:, None, None],
                          jnp.swapaxes(moved, -1, -2), moved)
        cur = _gi(arena, s_)
        arena = arena.at[s_].set(
            moved + am[:, None, None] * cur,
            mode="promise_in_bounds")
    return arena


def _overlap_finish(ov, arena, Dinv_f, idx, r, c, b, dtype):
    """Trailing boundary compute + A⁻¹ extraction from the arena."""
    for op in ov.compute_at[len(ov.rounds)]:
        arena = _overlap_compute(ov, op, arena, Dinv_f, idx, r, c, b,
                                 dtype)
    return lax.slice_in_dim(
        arena, 0, ov.n_ainv).reshape(ov.nbr, ov.nbc, b, b)


def make_sweep_overlapped(prog: PSelInvProgram, batched: bool = False):
    """Build the cross-level overlapped SPMD sweep from the compiled
    global round stream (`plan.schedule_overlapped`).

    One flat per-device **arena** of (b, b) blocks holds A⁻¹, the
    compact recycled Û slot pool, and the shared partial / S regions
    every level aliases (liveness windows + generation-keyed
    anti-dependences in the scheduler make the reuse safe — the executor
    just follows the tables). The read-only input L̂ shard is *not*
    copied into the arena: xfer-in lanes gather from it directly through
    the rounds' per-lane ``glh``/``lglh`` masks, shaving N blocks off
    the per-device footprint. The sweep is a single sequence of
    coalesced multi-lane ppermute rounds
    with per-lane gather/scatter/accumulate/transpose tables, and the
    masked level GEMMs (plus column/diagonal writes) fire at the round
    boundaries the dependence scheduler pinned them to — level L+1's
    xfer-in and col-bcast lanes ride the same rounds as level L's
    reduce / xfer-out / diag traffic instead of waiting for a level
    barrier. Call under shard_map exactly like :func:`make_sweep`;
    ``batched=True`` builds the multi-matrix variant (leading batch
    axis on the value tensors; see :func:`_wrap_sweep`)."""
    ov = prog.overlap_plan
    if ov is None:
        raise ValueError("build_program(..., overlap=True) first")
    b, pc = prog.b, prog.pc
    N = ov.n_ainv

    def body(Lh, Dinv):
        idx = lax.axis_index("xy")
        r = idx // pc
        c = idx % pc
        dtype = Lh.dtype
        Lh_f = Lh.reshape(N, b, b)
        Dinv_f = Dinv.reshape(N, b, b)
        # structless supernodes (leaves without fill + grid padding)
        arena = _overlap_init(ov, b, Dinv_f, idx, dtype)
        for t in range(len(ov.rounds)):
            arena = _overlap_round(ov, t, arena, Lh_f, Dinv_f, idx, r, c,
                                   b, dtype)
        return _overlap_finish(ov, arena, Dinv_f, idx, r, c, b, dtype)

    return _wrap_sweep(body, batched)


def make_sweep_segments(prog: PSelInvProgram,
                        boundaries: Optional[Sequence[int]] = None):
    """Profiling decomposition of the overlapped sweep: the same
    per-device body as :func:`make_sweep_overlapped`, cut at round
    boundaries so ``obs.rounds`` can jit, fence (``block_until_ready``)
    and time each executed round in isolation.

    Returns ``(init, steps, final)`` in the single-matrix shard_map
    calling convention (per-device value shards ``(1, nbr, nbc, b, b)``
    under ``in_specs=P("xy")``; the arena travels between segments as a
    per-device ``(1, arena_blocks, b, b)`` shard):

    * ``init(Lh, Dinv) -> arena`` — zeroed arena + structless-supernode
      diagonal seeds;
    * ``steps[i](arena, Lh, Dinv) -> arena`` — executed rounds
      ``boundaries[i] .. boundaries[i+1])`` (each = boundary compute ops
      + owner-local moves + the coalesced ppermute), one entry per
      consecutive boundary pair;
    * ``final(arena, Lh, Dinv) -> Ainv`` — the trailing boundary compute
      + A⁻¹ extraction.

    ``boundaries`` defaults to ``range(nrounds + 1)`` — one step per
    executed round; pass a coarser monotone cut list for level-chunk
    granularity. Running ``init``, every step in order, then ``final``
    reproduces the fused sweep bit-for-bit: the segments call the very
    same ``_overlap_round`` code, merely split at jit boundaries.
    Requires an overlapped schedule (stream programs carry one too —
    their gated tables are lowered from it)."""
    ov = prog.overlap_plan
    if ov is None:
        raise ValueError("build_program(..., overlap=True) first")
    b, pc = prog.b, prog.pc
    N = ov.n_ainv
    nrounds = len(ov.rounds)
    if boundaries is None:
        boundaries = list(range(nrounds + 1))
    else:
        boundaries = [int(x) for x in boundaries]
        if (not boundaries or boundaries[0] != 0
                or boundaries[-1] != nrounds
                or any(a >= b_ for a, b_ in zip(boundaries,
                                                boundaries[1:]))):
            raise ValueError(
                f"boundaries must be a strictly increasing cut list from "
                f"0 to {nrounds}, got {boundaries!r}")

    def _ctx(Lh, Dinv):
        idx = lax.axis_index("xy")
        return (idx, idx // pc, idx % pc, Lh[0].reshape(N, b, b),
                Dinv[0].reshape(N, b, b), Lh.dtype)

    def init(Lh, Dinv):
        idx, _, _, _, Dinv_f, dtype = _ctx(Lh, Dinv)
        return _overlap_init(ov, b, Dinv_f, idx, dtype)[None]

    def _make_step(lo: int, hi: int):
        def step(arena, Lh, Dinv):
            idx, r, c, Lh_f, Dinv_f, dtype = _ctx(Lh, Dinv)
            a = arena[0]
            for t in range(lo, hi):
                a = _overlap_round(ov, t, a, Lh_f, Dinv_f, idx, r, c, b,
                                   dtype)
            return a[None]
        return step

    steps = [_make_step(lo, hi)
             for lo, hi in zip(boundaries, boundaries[1:])]

    def final(arena, Lh, Dinv):
        idx, r, c, _, Dinv_f, dtype = _ctx(Lh, Dinv)
        return _overlap_finish(ov, arena[0], Dinv_f, idx, r, c, b,
                               dtype)[None]

    return init, steps, final


# ---------------------------------------------------------------------------
# stream path: the whole sweep as one lax.fori_loop over uniform tables
# ---------------------------------------------------------------------------

def make_sweep_stream(prog: PSelInvProgram, batched: bool = False):
    """Build the uniform round-stream SPMD sweep: the entire overlapped
    schedule as ONE ``lax.fori_loop`` body over the round-indexed device
    tables of ``core/stream.py`` (:class:`~.stream.StreamTables`).

    Each iteration ``t`` (a) dispatches the boundary's compute slots —
    level GEMM / column write / S-einsum / diagonal write behind
    per-round phase flags, one ``lax.switch`` per slot whose branches
    dynamic-index the level-stacked tables — (b) applies the owner-local
    copy lanes, and (c) runs the grid-factored comm-slot dictionary of
    ``core/stream.py``: one *static* ``ppermute`` per comm slot (a
    single grid-torus offset's pair union at one lane width), each gated
    by the round's ``slot_active`` mask through ``lax.cond`` so an
    inactive slot ships nothing, with per-round
    ``dynamic_slice``-selected gather/scatter/accumulate/transpose/
    L̂-gather lane tables (padded lanes scatter into the trash block,
    exactly like the unrolled executor's coalescing padding; a gated-off
    slot's zero arrival is never selected — no device receives on an
    inactive slot). The replayed round order, lane order and
    accumulation order are identical to :func:`make_sweep_overlapped`'s,
    so the f64 output is bit-identical — but jaxpr/HLO size no longer
    grows with the round count: the rounds are data (a few stacked
    tables), not code, and a round pays wire only for the slots it
    actually uses. Call under shard_map exactly like :func:`make_sweep`;
    ``batched=True`` builds the multi-matrix variant."""
    st = prog.stream_tables
    if st is None:
        raise ValueError(
            "build_program(..., options=PlanOptions(stream=True)) first")
    b = prog.b
    pr, pc = st.pr, st.pc
    P = pr * pc
    nbr, nbc = st.nbr, st.nbc
    N = st.n_ainv
    NK = st.NK
    S = st.nslots
    slot_perms = [[(int(s), int(d)) for (s, d) in perm]
                  for perm in st.slot_perm]
    slot_w = [int(w) for w in st.slot_width]
    # static whole-table checks: streams/locals that never carry an
    # L̂-gathering lane skip the second gather entirely
    comm_any_lh = bool(st.glh.any()) if S else False
    local_any_lh = bool(st.lglh.any()) if st.LW else False

    def body(Lh, Dinv):
        idx = lax.axis_index("xy")
        r = idx // pc
        c = idx % pc
        dtype = Lh.dtype
        Lh_f = Lh.reshape(N, b, b)
        Dinv_f = Dinv.reshape(N, b, b)
        arena = jnp.zeros((st.arena_blocks, b, b), dtype=dtype)

        # structless supernodes (leaves without fill + grid padding)
        if len(st.diag_set_root):
            slots = jnp.asarray(st.diag_set_slot)
            m = (jnp.asarray(st.diag_set_root) == idx).astype(dtype)
            arena = arena.at[slots].add(
                m[:, None, None] * _gi(Dinv_f, slots),
                mode="promise_in_bounds")

        # round-stacked device tables: one closed-over constant each,
        # sliced per round inside the loop body
        G = jnp.asarray(st.gather)
        SCT = jnp.asarray(st.scatter)
        AM = jnp.asarray(st.addm, dtype=dtype)
        TM = jnp.asarray(st.tmask)
        GLH = jnp.asarray(st.glh)
        RSL = jnp.asarray(st.recv_slot)
        ACT = jnp.asarray(st.slot_active)
        LG = jnp.asarray(st.lgather)
        LS = jnp.asarray(st.lscatter)
        LT = jnp.asarray(st.ltmask)
        LLH = jnp.asarray(st.lglh)
        CK = jnp.asarray(st.comp_kind)
        CL = jnp.asarray(st.comp_level)
        # level-stacked compute tables (padded to the widest level)
        UG = jnp.asarray(st.u_gather)
        CM = jnp.asarray(st.cmask, dtype=dtype)
        KCS = jnp.asarray(st.kcs)
        KRS = jnp.asarray(st.krs)
        CWR = jnp.asarray(st.col_write_row, dtype=dtype)
        CWC = jnp.asarray(st.col_write_col, dtype=dtype)
        DRM = jnp.asarray(st.diag_rowmask, dtype=dtype)
        DRT = jnp.asarray(st.diag_root)
        DSL = jnp.asarray(st.diag_slot)

        def at(tab, i):
            return lax.dynamic_index_in_dim(tab, i, 0, keepdims=False)

        # ---- the four compute phases, level selected dynamically ------
        # numerics live in the shared _phase_* helpers (one definition
        # with the unrolled overlapped executor); these branches only
        # dynamic-index the level-stacked tables, padded to NK: padded
        # rows carry zero struct masks (exact zeros into the shared
        # regions' tails) and trash diag slots — numerically inert
        def br_noop(L, arena):
            return arena

        def br_gemm(L, arena):
            ut = jnp.take(at(UG, L), idx, axis=0)        # (NK*nbc,)
            cm = jnp.take(at(CM, L), c, axis=0)          # (NK, nbc)
            return _phase_gemm(arena, ut, cm, N, nbr, nbc, b, st.base_p)

        def br_write(L, arena):
            wr = jnp.take(at(CWR, L), r, axis=0)         # (NK, nbr)
            wc = jnp.take(at(CWC, L), c, axis=0)         # (NK,)
            return _phase_write(arena, at(KCS, L), wr, wc,
                                N, nbr, nbc, b, st.base_p)

        def br_scomp(L, arena):
            ut = jnp.take(at(UG, L), idx, axis=0)
            cm = jnp.take(at(CM, L), c, axis=0)
            rm = jnp.take(at(DRM, L), r, axis=0)         # (NK,)
            return _phase_scomp(arena, ut, cm, at(KRS, L), rm,
                                N, nbr, nbc, b, st.base_s)

        def br_diagw(L, arena):
            return _phase_diagw(arena, Dinv_f, at(DSL, L), at(DRT, L),
                                idx, N, st.base_s, dtype)

        # branch order is the COMP_* id order — wired explicitly so the
        # phase-flag encoding can't drift from the dispatch table
        branches = [None] * 5
        branches[COMP_NOOP] = br_noop
        branches[COMP_GEMM] = br_gemm
        branches[COMP_WRITE] = br_write
        branches[COMP_SCOMP] = br_scomp
        branches[COMP_DIAGW] = br_diagw

        def round_body(t, arena):
            # (a) this boundary's compute slots, in dependence order
            if st.C:
                ck = at(CK, t)
                cl = at(CL, t)
                for j in range(st.C):
                    arena = lax.switch(ck[j], branches, cl[j], arena)
            # (b) owner-local copy lanes
            if st.LW:
                lg = jnp.take(at(LG, t), idx, axis=0)
                ls = jnp.take(at(LS, t), idx, axis=0)
                ltm = jnp.take(at(LT, t), idx, axis=0)
                llh = jnp.take(at(LLH, t), idx, axis=0)
                blks = _gather_lanes(arena, Lh_f, lg, llh, local_any_lh)
                blks = jnp.where(ltm[:, None, None],
                                 jnp.swapaxes(blks, -1, -2), blks)
                arena = arena.at[ls].set(blks, mode="promise_in_bounds")
            # (c) comm: the device's one outgoing lane stack is gathered
            # once; each comm slot — gated by the round's active mask —
            # ships the stack's leading slot_width lanes along its
            # static union perm, and each receiver keeps only the
            # arrival of its one receive slot and scatters it once —
            # identical snapshot semantics to the unrolled round's
            # single gather/permute/scatter. An inactive slot's cond
            # ships nothing (zeros branch); no device receives on an
            # inactive slot, so the zeros are never selected.
            if S:
                g = jnp.take(at(G, t), idx, axis=0)      # (W,)
                lh = jnp.take(at(GLH, t), idx, axis=0)
                payload = _gather_lanes(arena, Lh_f, g, lh, comm_any_lh)
                rsl = jnp.take(at(RSL, t), idx, axis=0)  # scalar
                act = at(ACT, t)                         # (S,) bool
                moved = jnp.zeros_like(payload)
                for si in range(S):
                    w = slot_w[si]
                    mv = lax.cond(
                        act[si],
                        lambda p, perm=slot_perms[si]:
                            lax.ppermute(p, "xy", perm),
                        lambda p: jnp.zeros_like(p),
                        lax.slice_in_dim(payload, 0, w))
                    moved = moved.at[:w].set(
                        jnp.where(rsl == si, mv, moved[:w]))
                tm = jnp.take(at(TM, t), idx, axis=0)
                moved = jnp.where(tm[:, None, None],
                                  jnp.swapaxes(moved, -1, -2), moved)
                s_ = jnp.take(at(SCT, t), idx, axis=0)
                am = jnp.take(at(AM, t), idx, axis=0)
                cur = _gi(arena, s_)
                arena = arena.at[s_].set(
                    moved + am[:, None, None] * cur,
                    mode="promise_in_bounds")
            return arena

        # steps = nrounds + 1: the final iteration's comm tables are
        # all-trash no-ops and only the last boundary's compute fires
        arena = lax.fori_loop(0, st.steps, round_body, arena)
        return lax.slice_in_dim(arena, 0, N).reshape(nbr, nbc, b, b)

    return _wrap_sweep(body, batched)


# ---------------------------------------------------------------------------
# legacy unrolled path (pre-IR executor, kept for the compile benchmark)
# ---------------------------------------------------------------------------

def _pack_rounds(pairs: List[Tuple[int, int, int]]):
    """Greedy-pack (src, dst, key) transfers into ppermute rounds with
    unique sources and destinations per round."""
    rounds: List[List[Tuple[int, int, int]]] = []
    for p in pairs:
        for rnd in rounds:
            if all(p[0] != q[0] and p[1] != q[1] for q in rnd):
                rnd.append(p)
                break
        else:
            rounds.append([p])
    return rounds


def _merge_tree_rounds(trees: Sequence[Tuple[CommTree, callable]], op: str):
    """Merge several disjoint-group trees into shared global-id rounds
    (``mapper`` translates tree coordinates to global device ids) —
    delegates the merge + disjointness check to the IR's
    :func:`~.plan.merge_round_lists`."""
    per_tree = []
    for tree, mapper in trees:
        rounds = tree.bcast_rounds() if op == "bcast" else tree.reduce_rounds()
        per_tree.append([[(mapper(s), mapper(d)) for (s, d) in rnd]
                         for rnd in rounds])
    return merge_round_lists(per_tree, op)


@dataclass
class _IterSchedule:
    K: int
    C: List[int]
    xfer_in_rounds: list          # rounds of (src, dst, I)
    xfer_in_local: List[int]      # I with owner(I,K) == owner(K,I)
    bcast_rounds: list            # merged global-id rounds
    reduce_rounds: list
    xfer_out_rounds: list         # rounds of (src, dst, J)
    xfer_out_local: List[int]
    diag_reduce_rounds: list
    col_mask: np.ndarray          # (NBc, pc) 1.0 where global col in C
    row_mask: np.ndarray          # (NBr, pr)


def build_program_unrolled(bs: BlockStructure, nb: int, b: int, pr: int,
                           pc: int, kind: TreeKind = TreeKind.SHIFTED
                           ) -> PSelInvProgram:
    """The pre-IR per-supernode schedule (one tree per mesh column/row per
    supernode, re-derived here rather than read from the CommPlan).
    Retained as the baseline of the compile-time benchmark."""
    if nb % pr or nb % pc:
        raise ValueError(f"nb={nb} not divisible by grid {pr}x{pc}")
    nbr, nbc = nb // pr, nb // pc

    def owner(I: int, J: int) -> int:
        return (I % pr) * pc + (J % pc)

    iters: List[_IterSchedule] = []
    for K in range(nb - 1, -1, -1):
        C = [int(i) for i in bs.struct[K]] if K < bs.nsuper else []
        krow, kcol = K % pr, K % pc

        # (a) xfer-in
        pairs, local = [], []
        for I in C:
            s, d = owner(I, K), owner(K, I)
            (local if s == d else pairs).append(
                I if s == d else (s, d, I))
        xfer_in_rounds = _pack_rounds([p for p in pairs])

        # (b) col-bcast: per mesh column, tree over participant rows
        rows = sorted({J % pr for J in C})
        recv_rows = [r for r in rows if r != krow]
        bcast_trees = []
        if recv_rows:
            for c in range(pc):
                tag = stable_hash(K, c, 0xB)
                tree = build_tree(kind, krow, recv_rows, tag=tag)
                bcast_trees.append(
                    (tree, (lambda cc: (lambda r: r * pc + cc))(c)))
        bcast_rounds = _merge_tree_rounds(bcast_trees, "bcast")

        # (c) row-reduce: per mesh row, tree over participant cols
        cols = sorted({I % pc for I in C} | {kcol})
        recv_cols = [c for c in cols if c != kcol]
        red_trees = []
        if recv_cols:
            for r in range(pr):
                tag = stable_hash(K, r, 0xC)
                tree = build_tree(kind, kcol, recv_cols, tag=tag)
                red_trees.append(
                    (tree, (lambda rr: (lambda c: rr * pc + c))(r)))
        reduce_rounds = _merge_tree_rounds(red_trees, "reduce")

        # (f) xfer-out (transpose to upper)
        pairs, localo = [], []
        for J in C:
            s, d = owner(J, K), owner(K, J)
            (localo if s == d else pairs).append(
                J if s == d else (s, d, J))
        xfer_out_rounds = _pack_rounds([p for p in pairs])

        # (g) diagonal reduce within mesh row krow
        diag_trees = []
        if recv_cols:
            tag = stable_hash(K, 0xD)
            tree = build_tree(kind, kcol, recv_cols, tag=tag)
            diag_trees.append((tree, lambda c: krow * pc + c))
        diag_reduce_rounds = _merge_tree_rounds(diag_trees, "reduce")

        mask = np.zeros(nb)
        for I in C:
            mask[I] = 1.0
        col_mask = mask.reshape(nbc, pc)
        row_mask = mask.reshape(nbr, pr)

        iters.append(_IterSchedule(
            K=K, C=C, xfer_in_rounds=xfer_in_rounds, xfer_in_local=local,
            bcast_rounds=bcast_rounds, reduce_rounds=reduce_rounds,
            xfer_out_rounds=xfer_out_rounds, xfer_out_local=localo,
            diag_reduce_rounds=diag_reduce_rounds,
            col_mask=col_mask, row_mask=row_mask))

    return PSelInvProgram(nb=nb, b=b, pr=pr, pc=pc, kind=kind, bs=bs,
                          iters=iters)


def _apply_rounds(x, rounds, axis, op):
    idx = lax.axis_index(axis)
    for rnd in rounds:
        perm = [(s, d) for (s, d) in rnd]
        moved = lax.ppermute(x, axis, perm)
        recv = jnp.zeros((), dtype=bool)
        for _, dst in perm:
            recv = recv | (idx == dst)
        if op == "bcast":
            x = jnp.where(recv, moved, x)
        else:
            x = jnp.where(recv, x + moved, x)
    return x


def make_sweep_unrolled(prog: PSelInvProgram):
    """The pre-IR sweep: per-supernode processing with per-pair
    ``jnp.where`` chains. O(nb × rounds × pairs) trace size — the
    benchmark baseline the IR executor is measured against."""
    if prog.iters is None:
        raise ValueError("use build_program_unrolled()")
    nb, b, pr, pc = prog.nb, prog.b, prog.pr, prog.pc
    nbr, nbc = prog.nbr, prog.nbc

    def sweep(Lh, Dinv):
        Lh = Lh[0]        # drop the size-1 sharded device axis
        Dinv = Dinv[0]
        idx = lax.axis_index("xy")
        r = idx // pc
        c = idx % pc
        dtype = Lh.dtype
        Ainv = jnp.zeros_like(Lh)

        for it in prog.iters:
            K = it.K
            krow, kcol = K % pr, K % pc
            kr, kc = K // pr, K // pc
            root_id = krow * pc + kcol

            if not it.C:
                Ainv = Ainv.at[kr, kc].set(
                    jnp.where(idx == root_id, Dinv[kr, kc], Ainv[kr, kc]))
                continue

            # ---- (a) xfer-in: build Û(K,·) buffer ----------------------
            Uh = jnp.zeros((nbc, b, b), dtype=dtype)
            for I in it.xfer_in_local:
                dev = (I % pr) * pc + (K % pc)
                Uh = Uh.at[I // pc].set(
                    jnp.where(idx == dev,
                              Lh[I // pr, kc].T, Uh[I // pc]))
            for rnd in it.xfer_in_rounds:
                payload = jnp.zeros((b, b), dtype=dtype)
                for (s, d, I) in rnd:
                    payload = jnp.where(idx == s, Lh[I // pr, kc], payload)
                moved = lax.ppermute(payload, "xy",
                                     [(s, d) for (s, d, _) in rnd])
                for (s, d, I) in rnd:
                    Uh = Uh.at[I // pc].set(
                        jnp.where(idx == d, moved.T, Uh[I // pc]))

            # ---- (b) col-bcast of Û down each grid column --------------
            Uh = _apply_rounds(Uh, it.bcast_rounds, "xy", "bcast")

            # ---- (1) local GEMM:  Σ_I A⁻¹(J,I)·L̂(I,K) ------------------
            cmask = jnp.take(jnp.asarray(it.col_mask, dtype=dtype), c,
                             axis=1)                       # (nbc,)
            Uh_m = Uh * cmask[:, None, None]
            # A⁻¹(J,I) @ L̂(I,K) = Ainv[i,j] @ Uh[j]ᵀ
            partial = jnp.einsum("ijab,jcb->iac", Ainv, Uh_m)

            # ---- (c) row-reduce onto column K%pc ------------------------
            partial = _apply_rounds(partial, it.reduce_rounds, "xy", "reduce")

            # ---- write A⁻¹(C,K) -----------------------------------------
            rmask = jnp.take(jnp.asarray(it.row_mask, dtype=dtype), r,
                             axis=1)                       # (nbr,)
            sel = (idx % pc == kcol) & True
            wr = (rmask[:, None, None] > 0) & sel
            Ainv = Ainv.at[:, kc].set(jnp.where(wr, -partial, Ainv[:, kc]))

            # ---- (f) xfer-out transposes A⁻¹(K,J) = A⁻¹(J,K)ᵀ -----------
            for J in it.xfer_out_local:
                dev = (J % pr) * pc + kcol
                Ainv = Ainv.at[kr, J // pc].set(
                    jnp.where(idx == dev, Ainv[J // pr, kc].T,
                              Ainv[kr, J // pc]))
            for rnd in it.xfer_out_rounds:
                payload = jnp.zeros((b, b), dtype=dtype)
                for (s, d, J) in rnd:
                    payload = jnp.where(idx == s, Ainv[J // pr, kc], payload)
                moved = lax.ppermute(payload, "xy",
                                     [(s, d) for (s, d, _) in rnd])
                for (s, d, J) in rnd:
                    Ainv = Ainv.at[kr, J // pc].set(
                        jnp.where(idx == d, moved.T, Ainv[kr, J // pc]))

            # ---- (2,3) diagonal:  A⁻¹(K,K) = Dinv − (Σ A⁻¹(K,I)L̂(I,K))ᵀ
            S = jnp.einsum("jab,jcb->ac", Ainv[kr] * cmask[:, None, None],
                           Uh_m)
            S = jnp.where(r == krow, S, jnp.zeros_like(S))
            S = _apply_rounds(S, it.diag_reduce_rounds, "xy", "reduce")
            Ainv = Ainv.at[kr, kc].set(
                jnp.where(idx == root_id, Dinv[kr, kc] - S.T, Ainv[kr, kc]))

        return Ainv[None]   # restore the sharded device axis

    return sweep


# ---------------------------------------------------------------------------
# host-side data preparation / gather
# ---------------------------------------------------------------------------

def validate_uniform_widths(bs: BlockStructure, b: int) -> None:
    """The dense-blocked layout requires every supernode at width b —
    one check shared by every structure entry point (matrix or ready
    :class:`BlockStructure`)."""
    if not np.all(bs.widths() == b):
        raise ValueError(
            f"structure has non-uniform supernode widths "
            f"{sorted(set(bs.widths().tolist()))} — the dense-blocked "
            f"layout requires every supernode to have width exactly "
            f"b={b}")


def pad_nb(nsuper: int, pr: int, pc: int) -> int:
    """Pad the supernode count so both grid dims divide it (the one
    padding rule — engine cache keys depend on it being identical for
    every entry point)."""
    nb = nsuper
    while nb % pr or nb % pc:
        nb += 1
    return nb


def analyze_structure(A, b: int, pr: int, pc: int
                      ) -> Tuple[BlockStructure, int]:
    """The value-independent half of :func:`prepare_inputs`: symbolic
    factorization + uniform-width validation + grid padding. Everything
    the engine caches hangs off this (bs, nb) pair."""
    import scipy.sparse as sp

    A = sp.csr_matrix(A)
    n = A.shape[0]
    # real input validation, not asserts: these guard user-provided
    # matrices and must survive ``python -O``
    if n % b:
        raise ValueError(
            f"matrix size n={n} is not a multiple of the supernode block "
            f"size b={b} — pad the matrix (or pick b dividing n)")
    bs = symbolic_factorize(A, max_supernode=b)
    validate_uniform_widths(bs, b)
    return bs, pad_nb(bs.nsuper, pr, pc)


def check_values_pattern(A, bs: BlockStructure, b: int):
    """Validate one matrix's *pattern* against an analyzed structure.

    The structured factorization only ever visits blocks in
    ``bs.struct``, so a matrix whose pattern escapes the analyzed
    structure would be silently truncated into the selected inverse of a
    *different* matrix — reject it instead (O(nnz) block-coordinate
    check against the symmetric filled pattern). Returns the matrix as
    CSR. Shared by :func:`prepare_values`, the batched
    :func:`prepare_values_many`, and the serving layer's per-request
    admission check (``repro.serve``) — a bad request must be rejectable
    *before* it joins a batch, so its neighbors still solve."""
    import scipy.sparse as sp

    A = sp.csr_matrix(A)
    n = A.shape[0]
    if n != int(bs.offsets[-1]):
        raise ValueError(
            f"matrix size n={n} does not match the analyzed structure "
            f"(expected n={int(bs.offsets[-1])}) — re-run analyze for a "
            "different-sized matrix")
    nb0 = bs.nsuper
    present = np.zeros((nb0, nb0), dtype=bool)
    np.fill_diagonal(present, True)
    for K in range(nb0):
        present[np.asarray(bs.struct[K], dtype=np.int64), K] = True
    coo = A.tocoo()
    hi = np.maximum(coo.row // b, coo.col // b)
    lo = np.minimum(coo.row // b, coo.col // b)
    bad = (coo.data != 0) & ~present[hi, lo]
    if bad.any():
        blocks = sorted({(int(i), int(j))
                         for i, j in zip(hi[bad], lo[bad])})[:8]
        raise ValueError(
            f"matrix has {int(bad.sum())} nonzero(s) outside the "
            f"analyzed block structure (e.g. blocks {blocks}) — its "
            "sparsity pattern differs from the analyzed matrix; re-run "
            "analyze for this structure")
    return A


def _shard_blocks(G: np.ndarray, nb: int, b: int, pr: int,
                  pc: int) -> np.ndarray:
    """Dense (…, nb, nb, b, b) block grid → (…, pr*pc, nbr, nbc, b, b)
    device shards for ``in_specs=P("xy")`` (cyclic over both grid dims).
    The one layout rule — :func:`prepare_values`,
    :func:`prepare_values_many` and :func:`gather_blocks` must agree."""
    nbr, nbc = nb // pr, nb // pc
    lead = G.shape[:-4]
    G = G.reshape(lead + (nbr, pr, nbc, pc, b, b))
    perm = tuple(range(len(lead)))
    off = len(lead)
    G = G.transpose(perm + (off + 1, off + 3, off, off + 2,
                            off + 4, off + 5))
    return G.reshape(lead + (pr * pc, nbr, nbc, b, b))


def prepare_values(A, bs: BlockStructure, nb: int, b: int, pr: int,
                   pc: int) -> Tuple[np.ndarray, np.ndarray]:
    """The numeric half of :func:`prepare_inputs`: factorize this
    matrix's *values* on the host against an already-analyzed structure,
    normalize, and lay out the dense-blocked shards.

    Returns (Lh, Dinv) with shape (pr*pc, nbr, nbc, b, b) for
    ``in_specs=P("xy")``. The caller guarantees ``A`` has the sparsity
    structure that produced ``bs`` — this is the engine's analyze-once /
    solve-many hot path, so no symbolic work happens here."""
    import scipy.linalg as sla

    A = check_values_pattern(A, bs, b)
    nb0 = bs.nsuper

    lu = factorize(A, bs=bs)
    Lhat, _ = normalize_factors(lu)

    Lh_g = np.zeros((nb, nb, b, b))
    Dinv_g = np.zeros((nb, nb, b, b))
    for (I, K), blk in Lhat.items():
        Lh_g[I, K] = np.asarray(blk)
    for K in range(nb0):
        linv = sla.solve_triangular(np.asarray(lu.Ldiag[K]), np.eye(b),
                                    lower=True, unit_diagonal=True)
        Dinv_g[K, K] = sla.solve_triangular(np.asarray(lu.Udiag[K]), linv,
                                            lower=False)
    for K in range(nb0, nb):       # padding supernodes: identity diag
        Dinv_g[K, K] = np.eye(b)

    return (_shard_blocks(Lh_g, nb, b, pr, pc),
            _shard_blocks(Dinv_g, nb, b, pr, pc))


def _batched_lu_nopivot(Akk: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Doolittle LU without pivoting over a (B, b, b) block stack —
    the batched twin of ``supernodal_lu.dense_lu_nopivot`` (same
    elimination order, so the factors agree to rounding)."""
    B, b = Akk.shape[0], Akk.shape[1]
    lu = Akk.copy()
    for k in range(b - 1):
        piv = lu[:, k, k]
        lu[:, k + 1:, k] /= piv[:, None]
        lu[:, k + 1:, k + 1:] -= (lu[:, k + 1:, k, None]
                                  * lu[:, None, k, k + 1:])
    L = np.tril(lu, -1) + np.eye(b)
    return L, np.triu(lu)


def prepare_values_many(mats: Sequence, bs: BlockStructure, nb: int,
                        b: int, pr: int, pc: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched host factorization: B same-structure matrices → stacked
    ``(B, pr*pc, nbr, nbc, b, b)`` shards in ONE structure-driven pass.

    Same math as B :func:`prepare_values` calls — right-looking
    supernodal LU over the filled structure, factor normalization,
    diagonal inverses — but the Python loop over supernodes runs once
    with every block stacked ``(B, b, b)``, so the interpreter overhead
    that dominates the single-matrix path (measured ~11 ms/matrix at
    nb=16) amortizes across the batch (~1.3 ms/matrix at B=16). This is
    the serving layer's host-side half of the batching win: without it a
    coalesced batch still pays B sequential GIL-bound factorizations.

    The dense (nb0, nb0) block workspace is the same asymptotic
    footprint as the device layout :func:`prepare_values` already
    emits. Numerics match the single-matrix scipy path to rounding
    (≤1e-12 asserted in tests; observed ~1e-18).

    Raises ``ValueError`` naming the offending batch *index* when any
    matrix's pattern escapes the analyzed structure — callers that need
    per-request isolation (the serving layer) validate each matrix with
    :func:`check_values_pattern` first."""
    if not len(mats):
        raise ValueError("prepare_values_many needs at least one matrix")
    csr = []
    for i, M in enumerate(mats):
        try:
            csr.append(check_values_pattern(M, bs, b))
        except ValueError as e:
            raise ValueError(f"matrix {i} of {len(mats)}: {e}") from e
    B, nb0 = len(csr), bs.nsuper
    eye = np.eye(b)

    # dense (B, nb0, nb0, b, b) block workspace holding the evolving
    # Schur complement; fill lands in blocks the symbolic structure
    # already owns, so reading only struct blocks below is exact
    W = np.stack([np.asarray(M.todense()) for M in csr])
    W = (W.reshape(B, nb0, b, nb0, b).transpose(0, 1, 3, 2, 4)
          .astype(np.float64, copy=True))
    Lh = np.zeros((B, nb, nb, b, b))
    Dinv = np.zeros((B, nb, nb, b, b))
    bidx = np.arange(B)
    for K in range(nb0):
        L, U = _batched_lu_nopivot(W[:, K, K])
        C = [int(i) for i in bs.struct[K]]
        if C:
            # L(C,K): X·U = A  ⇔  Uᵀ·Xᵀ = Aᵀ (batched, broadcast over C)
            LCK = np.linalg.solve(
                U.transpose(0, 2, 1)[:, None],
                W[:, C, K].transpose(0, 1, 3, 2)).transpose(0, 1, 3, 2)
            UKC = np.linalg.solve(L[:, None], W[:, K, C])   # L·X = A
            W[:, C, K] = LCK
            W[:, K, C] = UKC
            # Schur update over the whole struct(K) × struct(K) clique
            W[np.ix_(bidx, C, C)] -= np.einsum(
                'bikl,bjlm->bijkm', LCK, UKC)
            # L̂(C,K) = L(C,K)·L(K,K)⁻¹:  X·L = A  ⇔  Lᵀ·Xᵀ = Aᵀ
            Lh[:, C, K] = np.linalg.solve(
                L.transpose(0, 2, 1)[:, None],
                LCK.transpose(0, 1, 3, 2)).transpose(0, 1, 3, 2)
        linv = np.linalg.solve(L, np.broadcast_to(eye, (B, b, b)))
        Dinv[:, K, K] = np.linalg.solve(U, linv)   # (U_KK)⁻¹(L_KK)⁻¹
    Dinv[:, range(nb0, nb), range(nb0, nb)] = eye   # padding supernodes
    return (_shard_blocks(Lh, nb, b, pr, pc),
            _shard_blocks(Dinv, nb, b, pr, pc))


def prepare_inputs(A, b: int, pr: int, pc: int):
    """Factorize (host), normalize, and lay out dense-blocked shards.

    Returns (bs, nb, Lh_sharded_global, Dinv_sharded_global) where the
    arrays have shape (pr*pc, nbr, nbc, b, b) for in_specs P("xy").

    Back-compat composition of :func:`analyze_structure` (symbolic, the
    part the engine caches) and :func:`prepare_values` (numeric) — new
    code that solves many matrices of one structure should go through
    :class:`~.engine.PSelInvEngine` instead."""
    warnings.warn(
        "prepare_inputs is deprecated: use PSelInvEngine.analyze(...) + "
        "engine.prepare_values(...) (the analyze-once/solve-many split) "
        "or analyze_structure/prepare_values directly",
        DeprecationWarning, stacklevel=2)
    bs, nb = analyze_structure(A, b, pr, pc)
    Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
    return bs, nb, Lh_s, Dinv_s


def check_grid_devices(pr: int, pc: int) -> None:
    """Raise the canonical diagnostic when the process grid oversubscribes
    the available JAX devices (shared by the engine and the legacy
    entry point)."""
    avail = len(jax.devices())
    if pr * pc > avail:
        raise ValueError(
            f"process grid {pr}x{pc} needs {pr * pc} devices but only "
            f"{avail} JAX device(s) are available — shrink the grid or "
            "launch with more devices (e.g. XLA_FLAGS="
            f"--xla_force_host_platform_device_count={pr * pc})")


def run_distributed(A, b: int, pr: int, pc: int,
                    kind: TreeKind = TreeKind.SHIFTED, dtype=jnp.float32,
                    pipelined: bool = True, overlap: bool = True):
    """End-to-end distributed selected inversion on pr*pc devices.

    .. deprecated:: PR 4
       Thin back-compat shim over :class:`~.engine.PSelInvEngine` — one
       call per matrix re-enters the engine's structure cache, so
       repeated calls with one structure reuse the compiled sweep, but
       the numeric host factorization still runs per call. New code
       should ``PSelInvEngine.analyze(...)`` once and ``solve`` many
       times (with a batch axis for multi-matrix workloads).

    ``pipelined=True`` runs the IR executor — by default the cross-level
    *overlapped* round stream; ``overlap=False`` selects the level-serial
    executor (the A/B baseline). ``pipelined=False`` runs the legacy
    unrolled sweep (same numerics, larger HLO)."""
    from jax.sharding import Mesh, PartitionSpec as P

    warnings.warn(
        "run_distributed is deprecated: use PSelInvEngine.analyze(...) "
        "once and engine.solve(...) per matrix (batched solves via a "
        "leading batch axis / solve_many)",
        DeprecationWarning, stacklevel=2)
    check_grid_devices(pr, pc)
    if pipelined:
        from .engine import PSelInvEngine
        from .schedule import Grid2D
        engine = PSelInvEngine.analyze(
            A, b=b, grid=Grid2D(pr, pc),
            options=PlanOptions(kind=kind, overlap=overlap))
        out = engine.solve(A, dtype=dtype)
        return np.asarray(out), engine.program

    # composed directly (not through prepare_inputs) so one deprecated
    # call warns once, attributed to the caller
    bs, nb = analyze_structure(A, b, pr, pc)
    Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
    prog = build_program_unrolled(bs, nb, b, pr, pc, kind=kind)
    sweep = make_sweep_unrolled(prog)
    devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
    mesh = Mesh(devs, ("xy",))
    fn = jax.jit(shard_map(
        sweep, mesh=mesh, in_specs=(P("xy"), P("xy")), out_specs=P("xy")))
    out = fn(jnp.asarray(Lh_s, dtype=dtype), jnp.asarray(Dinv_s, dtype=dtype))
    return np.asarray(out), prog


def gather_blocks(out: np.ndarray, prog) -> np.ndarray:
    """Invert the shard layout back to a dense (nb, nb, b, b) block grid.
    Accepts the :class:`PSelInvProgram` or anything carrying one under
    ``.program`` (the engine) — the geometry is derived, not re-passed."""
    prog = getattr(prog, "program", prog)
    nb, b, pr, pc = prog.nb, prog.b, prog.pr, prog.pc
    nbr, nbc = nb // pr, nb // pc
    return (out.reshape(pr, pc, nbr, nbc, b, b)
               .transpose(2, 0, 3, 1, 4, 5)
               .reshape(nb, nb, b, b))
