"""Distributed PSelInv on a JAX device mesh — the executable version of
the paper's algorithm with tree-based restricted collectives.

The selected-inversion sweep (Alg. 1, loop 2) runs as one SPMD program on
a flattened ``pr × pc`` grid ("xy" axis), exactly mirroring the paper's
communication structure (§2.2, Fig. 2):

  per supernode K (reverse elimination order):
    (a) xfer-in    L̂(I,K) → owner of Û(K,I)        [p2p ppermute rounds]
    (b) col-bcast  Û(K,I) down its grid column      [tree, restricted]
    (1) local GEMM A⁻¹(J,I)·L̂(I,K)
    (c) row-reduce partials onto owner of A⁻¹(J,K)  [tree, restricted]
    (f) xfer-out   A⁻¹(J,K)ᵀ → A⁻¹(K,J) owner       [p2p, symmetric case]
    (2,3) diagonal update + restricted row reduce

Symmetric matrices (as the paper's implementation): Û(K,I) = L̂(I,K)ᵀ and
A⁻¹(K,J) = A⁻¹(J,K)ᵀ — both identities hold blockwise for unpivoted LU.

Data is dense-blocked with uniform supernode width ``b`` and explicit
zeros for structurally-zero blocks: numerics are unaffected (zero blocks
contribute zero), while the *communication* pattern is restricted to the
true sparsity structure — the trees only span the participating subset,
exactly like PSelInv.

Trees for concurrent column/row groups are batched into shared ppermute
rounds (several restricted collectives in flight per HLO collective-
permute — the executable analogue of the paper's asynchronous pipelining).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .symbolic import BlockStructure, symbolic_factorize
from .supernodal_lu import factorize
from .selinv import normalize_factors
from .trees import CommTree, TreeKind, build_tree, stable_hash

__all__ = ["PSelInvProgram", "build_program", "prepare_inputs",
           "run_distributed", "gather_blocks"]


# ---------------------------------------------------------------------------
# static schedule construction (host side)
# ---------------------------------------------------------------------------

def _pack_rounds(pairs: List[Tuple[int, int, int]]):
    """Greedy-pack (src, dst, key) transfers into ppermute rounds with
    unique sources and destinations per round."""
    rounds: List[List[Tuple[int, int, int]]] = []
    for p in pairs:
        for rnd in rounds:
            if all(p[0] != q[0] and p[1] != q[1] for q in rnd):
                rnd.append(p)
                break
        else:
            rounds.append([p])
    return rounds


def _merge_tree_rounds(trees: Sequence[Tuple[CommTree, callable]], op: str):
    """Merge several disjoint-group trees into shared global-id rounds.
    ``mapper`` translates tree coordinates to global device ids."""
    per_tree = []
    for tree, mapper in trees:
        rounds = tree.bcast_rounds() if op == "bcast" else tree.reduce_rounds()
        per_tree.append([[(mapper(s), mapper(d)) for (s, d) in rnd]
                         for rnd in rounds])
    n = max((len(r) for r in per_tree), default=0)
    merged: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for rounds in per_tree:
        shift = 0 if op == "bcast" else n - len(rounds)
        for i, rnd in enumerate(rounds):
            merged[i + shift].extend(rnd)
    for rnd in merged:
        srcs = [s for s, _ in rnd]
        dsts = [d for _, d in rnd]
        assert len(set(srcs)) == len(srcs) and len(set(dsts)) == len(dsts)
    return merged


@dataclass
class _IterSchedule:
    K: int
    C: List[int]
    xfer_in_rounds: list          # rounds of (src, dst, I)
    xfer_in_local: List[int]      # I with owner(I,K) == owner(K,I)
    bcast_rounds: list            # merged global-id rounds
    reduce_rounds: list
    xfer_out_rounds: list         # rounds of (src, dst, J)
    xfer_out_local: List[int]
    diag_reduce_rounds: list
    col_mask: np.ndarray          # (NBc, pc) 1.0 where global col in C
    row_mask: np.ndarray          # (NBr, pr)


@dataclass
class PSelInvProgram:
    nb: int
    b: int
    pr: int
    pc: int
    kind: TreeKind
    iters: List[_IterSchedule]
    bs: BlockStructure

    @property
    def nbr(self) -> int:
        return self.nb // self.pr

    @property
    def nbc(self) -> int:
        return self.nb // self.pc


def build_program(bs: BlockStructure, nb: int, b: int, pr: int, pc: int,
                  kind: TreeKind = TreeKind.SHIFTED) -> PSelInvProgram:
    """Precompute the full static communication schedule (trees, rounds,
    masks) for every supernode iteration."""
    assert nb % pr == 0 and nb % pc == 0
    nbr, nbc = nb // pr, nb // pc

    def owner(I: int, J: int) -> int:
        return (I % pr) * pc + (J % pc)

    iters: List[_IterSchedule] = []
    for K in range(nb - 1, -1, -1):
        C = [int(i) for i in bs.struct[K]] if K < bs.nsuper else []
        krow, kcol = K % pr, K % pc

        # (a) xfer-in
        pairs, local = [], []
        for I in C:
            s, d = owner(I, K), owner(K, I)
            (local if s == d else pairs).append(
                I if s == d else (s, d, I))
        xfer_in_rounds = _pack_rounds([p for p in pairs])

        # (b) col-bcast: per mesh column, tree over participant rows
        rows = sorted({J % pr for J in C})
        recv_rows = [r for r in rows if r != krow]
        bcast_trees = []
        if recv_rows:
            for c in range(pc):
                tag = stable_hash(K, c, 0xB)
                tree = build_tree(kind, krow, recv_rows, tag=tag)
                bcast_trees.append(
                    (tree, (lambda cc: (lambda r: r * pc + cc))(c)))
        bcast_rounds = _merge_tree_rounds(bcast_trees, "bcast")

        # (c) row-reduce: per mesh row, tree over participant cols
        cols = sorted({I % pc for I in C} | {kcol})
        recv_cols = [c for c in cols if c != kcol]
        red_trees = []
        if recv_cols:
            for r in range(pr):
                tag = stable_hash(K, r, 0xC)
                tree = build_tree(kind, kcol, recv_cols, tag=tag)
                red_trees.append(
                    (tree, (lambda rr: (lambda c: rr * pc + c))(r)))
        reduce_rounds = _merge_tree_rounds(red_trees, "reduce")

        # (f) xfer-out (transpose to upper)
        pairs, localo = [], []
        for J in C:
            s, d = owner(J, K), owner(K, J)
            (localo if s == d else pairs).append(
                J if s == d else (s, d, J))
        xfer_out_rounds = _pack_rounds([p for p in pairs])

        # (g) diagonal reduce within mesh row krow
        diag_trees = []
        if recv_cols:
            tag = stable_hash(K, 0xD)
            tree = build_tree(kind, kcol, recv_cols, tag=tag)
            diag_trees.append((tree, lambda c: krow * pc + c))
        diag_reduce_rounds = _merge_tree_rounds(diag_trees, "reduce")

        mask = np.zeros(nb)
        for I in C:
            mask[I] = 1.0
        col_mask = mask.reshape(nbc, pc)
        row_mask = mask.reshape(nbr, pr)

        iters.append(_IterSchedule(
            K=K, C=C, xfer_in_rounds=xfer_in_rounds, xfer_in_local=local,
            bcast_rounds=bcast_rounds, reduce_rounds=reduce_rounds,
            xfer_out_rounds=xfer_out_rounds, xfer_out_local=localo,
            diag_reduce_rounds=diag_reduce_rounds,
            col_mask=col_mask, row_mask=row_mask))

    return PSelInvProgram(nb=nb, b=b, pr=pr, pc=pc, kind=kind, iters=iters,
                          bs=bs)


# ---------------------------------------------------------------------------
# SPMD sweep (device side, inside shard_map over axis "xy")
# ---------------------------------------------------------------------------

def _apply_rounds(x, rounds, axis, op):
    idx = lax.axis_index(axis)
    for rnd in rounds:
        perm = [(s, d) for (s, d) in rnd]
        moved = lax.ppermute(x, axis, perm)
        recv = jnp.zeros((), dtype=bool)
        for _, dst in perm:
            recv = recv | (idx == dst)
        if op == "bcast":
            x = jnp.where(recv, moved, x)
        else:
            x = jnp.where(recv, x + moved, x)
    return x


def make_sweep(prog: PSelInvProgram):
    """Build the SPMD sweep callable. Call inside shard_map over a 1-D
    mesh axis "xy" of size pr*pc, with per-device blocks
    Lh: (nbr, nbc, b, b), Dinv: (nbr, nbc, b, b)."""
    nb, b, pr, pc = prog.nb, prog.b, prog.pr, prog.pc
    nbr, nbc = prog.nbr, prog.nbc

    def sweep(Lh, Dinv):
        Lh = Lh[0]        # drop the size-1 sharded device axis
        Dinv = Dinv[0]
        idx = lax.axis_index("xy")
        r = idx // pc
        c = idx % pc
        dtype = Lh.dtype
        Ainv = jnp.zeros_like(Lh)

        for it in prog.iters:
            K = it.K
            krow, kcol = K % pr, K % pc
            kr, kc = K // pr, K // pc
            root_id = krow * pc + kcol

            if not it.C:
                Ainv = Ainv.at[kr, kc].set(
                    jnp.where(idx == root_id, Dinv[kr, kc], Ainv[kr, kc]))
                continue

            # ---- (a) xfer-in: build Û(K,·) buffer ----------------------
            Uh = jnp.zeros((nbc, b, b), dtype=dtype)
            for I in it.xfer_in_local:
                dev = (I % pr) * pc + (K % pc)
                assert dev == (K % pr) * pc + (I % pc)
                Uh = Uh.at[I // pc].set(
                    jnp.where(idx == dev,
                              Lh[I // pr, kc].T, Uh[I // pc]))
            for rnd in it.xfer_in_rounds:
                payload = jnp.zeros((b, b), dtype=dtype)
                for (s, d, I) in rnd:
                    payload = jnp.where(idx == s, Lh[I // pr, kc], payload)
                moved = lax.ppermute(payload, "xy",
                                     [(s, d) for (s, d, _) in rnd])
                for (s, d, I) in rnd:
                    Uh = Uh.at[I // pc].set(
                        jnp.where(idx == d, moved.T, Uh[I // pc]))

            # ---- (b) col-bcast of Û down each grid column --------------
            Uh = _apply_rounds(Uh, it.bcast_rounds, "xy", "bcast")

            # ---- (1) local GEMM:  Σ_I A⁻¹(J,I)·L̂(I,K) ------------------
            cmask = jnp.take(jnp.asarray(it.col_mask, dtype=dtype), c,
                             axis=1)                       # (nbc,)
            Uh_m = Uh * cmask[:, None, None]
            # A⁻¹(J,I) @ L̂(I,K) = Ainv[i,j] @ Uh[j]ᵀ
            partial = jnp.einsum("ijab,jcb->iac", Ainv, Uh_m)

            # ---- (c) row-reduce onto column K%pc ------------------------
            partial = _apply_rounds(partial, it.reduce_rounds, "xy", "reduce")

            # ---- write A⁻¹(C,K) -----------------------------------------
            rmask = jnp.take(jnp.asarray(it.row_mask, dtype=dtype), r,
                             axis=1)                       # (nbr,)
            sel = (idx % pc == kcol) & True
            wr = (rmask[:, None, None] > 0) & sel
            Ainv = Ainv.at[:, kc].set(jnp.where(wr, -partial, Ainv[:, kc]))

            # ---- (f) xfer-out transposes A⁻¹(K,J) = A⁻¹(J,K)ᵀ -----------
            for J in it.xfer_out_local:
                dev = (J % pr) * pc + kcol
                Ainv = Ainv.at[kr, J // pc].set(
                    jnp.where(idx == dev, Ainv[J // pr, kc].T,
                              Ainv[kr, J // pc]))
            for rnd in it.xfer_out_rounds:
                payload = jnp.zeros((b, b), dtype=dtype)
                for (s, d, J) in rnd:
                    payload = jnp.where(idx == s, Ainv[J // pr, kc], payload)
                moved = lax.ppermute(payload, "xy",
                                     [(s, d) for (s, d, _) in rnd])
                for (s, d, J) in rnd:
                    Ainv = Ainv.at[kr, J // pc].set(
                        jnp.where(idx == d, moved.T, Ainv[kr, J // pc]))

            # ---- (2,3) diagonal:  A⁻¹(K,K) = Dinv − (Σ A⁻¹(K,I)L̂(I,K))ᵀ
            S = jnp.einsum("jab,jcb->ac", Ainv[kr] * cmask[:, None, None],
                           Uh_m)
            S = jnp.where(r == krow, S, jnp.zeros_like(S))
            S = _apply_rounds(S, it.diag_reduce_rounds, "xy", "reduce")
            Ainv = Ainv.at[kr, kc].set(
                jnp.where(idx == root_id, Dinv[kr, kc] - S.T, Ainv[kr, kc]))

        return Ainv[None]   # restore the sharded device axis

    return sweep


# ---------------------------------------------------------------------------
# host-side data preparation / gather
# ---------------------------------------------------------------------------

def prepare_inputs(A, b: int, pr: int, pc: int):
    """Factorize (host), normalize, and lay out dense-blocked shards.

    Returns (prog_builder_args, Lh_sharded_global, Dinv_sharded_global)
    where the arrays have shape (pr*pc, nbr, nbc, b, b) for in_specs
    P("xy")."""
    import scipy.sparse as sp
    import scipy.linalg as sla

    A = sp.csr_matrix(A)
    n = A.shape[0]
    assert n % b == 0, "pad the matrix to a multiple of the block size"
    bs = symbolic_factorize(A, max_supernode=b)
    assert np.all(bs.widths() == b), "uniform-width supernodes required"
    nb0 = bs.nsuper
    # pad supernode count so both grid dims divide it
    nb = nb0
    while nb % pr or nb % pc:
        nb += 1

    lu = factorize(A, bs=bs)
    Lhat, _ = normalize_factors(lu)

    Lh_g = np.zeros((nb, nb, b, b))
    Dinv_g = np.zeros((nb, nb, b, b))
    for (I, K), blk in Lhat.items():
        Lh_g[I, K] = np.asarray(blk)
    for K in range(nb0):
        linv = sla.solve_triangular(np.asarray(lu.Ldiag[K]), np.eye(b),
                                    lower=True, unit_diagonal=True)
        Dinv_g[K, K] = sla.solve_triangular(np.asarray(lu.Udiag[K]), linv,
                                            lower=False)
    for K in range(nb0, nb):       # padding supernodes: identity diag
        Dinv_g[K, K] = np.eye(b)

    def shard(G):
        nbr, nbc = nb // pr, nb // pc
        return (G.reshape(nbr, pr, nbc, pc, b, b)
                 .transpose(1, 3, 0, 2, 4, 5)
                 .reshape(pr * pc, nbr, nbc, b, b))

    return bs, nb, shard(Lh_g), shard(Dinv_g)


def run_distributed(A, b: int, pr: int, pc: int,
                    kind: TreeKind = TreeKind.SHIFTED, dtype=jnp.float32):
    """End-to-end distributed selected inversion on pr*pc devices."""
    from jax.sharding import Mesh, PartitionSpec as P

    bs, nb, Lh_s, Dinv_s = prepare_inputs(A, b, pr, pc)
    prog = build_program(bs, nb, b, pr, pc, kind=kind)
    sweep = make_sweep(prog)

    devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
    mesh = Mesh(devs, ("xy",))
    fn = jax.jit(jax.shard_map(
        sweep, mesh=mesh, in_specs=(P("xy"), P("xy")), out_specs=P("xy")))
    out = fn(jnp.asarray(Lh_s, dtype=dtype), jnp.asarray(Dinv_s, dtype=dtype))
    return np.asarray(out), prog


def gather_blocks(out: np.ndarray, prog: PSelInvProgram) -> np.ndarray:
    """Invert the shard layout back to a dense (nb, nb, b, b) block grid."""
    nb, b, pr, pc = prog.nb, prog.b, prog.pr, prog.pc
    nbr, nbc = nb // pr, nb // pc
    return (out.reshape(pr, pc, nbr, nbc, b, b)
               .transpose(2, 0, 3, 1, 4, 5)
               .reshape(nb, nb, b, b))
