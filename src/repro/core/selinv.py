"""Selected inversion (paper Algorithm 1), supernodal/blocked.

Given supernodal LU factors of ``A``, computes every block ``A⁻¹(I,J)``
on the *filled* block pattern (both triangles + diagonals) — a superset of
the paper's selected set Eq. (1), closed under the clique property that
Algorithm 1 requires (for I,J ∈ struct(K), block (I,J) is in the filled
pattern).

Two layers:

* :func:`selinv` — the production supernodal algorithm (numpy / jax /
  pallas backends; Python orchestration mirrors the per-supernode task
  graph that the distributed runtime executes),
* :func:`dense_selinv_oracle` — O(N³) dense oracle used by the tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import scipy.sparse as sp

from .supernodal_lu import LUFactors, factorize, get_backend
from .symbolic import BlockStructure, symbolic_factorize

__all__ = ["selinv", "selected_inverse", "dense_selinv_oracle",
           "normalize_factors"]

Key = Tuple[int, int]


def normalize_factors(lu: LUFactors):
    """Paper Alg. 1, first loop:  L̂(C,K) = L(C,K)·L(K,K)⁻¹,
    Û(K,C) = U(K,K)⁻¹·U(K,C).  (In PSelInv this pass has the simple
    column-group broadcast of the diagonal block.)"""
    be = get_backend(lu.backend)
    bs = lu.bs
    Lhat: Dict[Key, np.ndarray] = {}
    Uhat: Dict[Key, np.ndarray] = {}
    for K in range(bs.nsuper):
        ldiag = lu.Ldiag[K]
        udiag = lu.Udiag[K]
        for I in bs.struct[K]:
            I = int(I)
            # X L = B  with L unit-lower  <=>  Lᵀ Xᵀ = Bᵀ (unit-upper solve)
            lik = np.asarray(lu.L[(I, K)])
            ld = np.asarray(ldiag)
            import scipy.linalg as sla
            Lhat[(I, K)] = be.asarray(
                sla.solve_triangular(ld.T, lik.T, lower=False,
                                     unit_diagonal=True).T)
            # U X = B with U upper
            uki = np.asarray(lu.U[(K, I)])
            Uhat[(K, I)] = be.asarray(
                sla.solve_triangular(np.asarray(udiag), uki, lower=False))
    return Lhat, Uhat


def selinv(lu: LUFactors) -> Dict[Key, np.ndarray]:
    """Paper Algorithm 1, second loop, at supernode-block granularity."""
    be = get_backend(lu.backend)
    bs = lu.bs
    nb = bs.nsuper
    Lhat, Uhat = normalize_factors(lu)

    import scipy.linalg as sla

    def diag_inv(K: int) -> np.ndarray:
        # (U_KK)⁻¹ (L_KK)⁻¹
        n = bs.width(K)
        linv = sla.solve_triangular(np.asarray(lu.Ldiag[K]), np.eye(n),
                                    lower=True, unit_diagonal=True)
        return be.asarray(
            sla.solve_triangular(np.asarray(lu.Udiag[K]), linv, lower=False))

    Ainv: Dict[Key, np.ndarray] = {}
    w = bs.widths()

    for K in range(nb - 1, -1, -1):
        C = [int(i) for i in bs.struct[K]]
        if not C:
            Ainv[(K, K)] = diag_inv(K)
            continue
        sizes = [int(w[i]) for i in C]
        offs = np.concatenate([[0], np.cumsum(sizes)])
        m = int(offs[-1])
        wk = bs.width(K)

        # gather A⁻¹(C,C) — every (J,I) block exists (clique property)
        AinvCC = np.zeros((m, m))
        for a, J in enumerate(C):
            for b, I in enumerate(C):
                AinvCC[offs[a]:offs[a + 1], offs[b]:offs[b + 1]] = \
                    np.asarray(Ainv[(J, I)])
        AinvCC = be.asarray(AinvCC)

        LhatCK = be.asarray(np.concatenate(
            [np.asarray(Lhat[(I, K)]) for I in C], axis=0))
        UhatKC = be.asarray(np.concatenate(
            [np.asarray(Uhat[(K, I)]) for I in C], axis=1))

        # step 3:  A⁻¹(C,K) = −A⁻¹(C,C)·L̂(C,K)
        AinvCK = -be.matmul(AinvCC, LhatCK)
        # step 5:  A⁻¹(K,C) = −Û(K,C)·A⁻¹(C,C)
        AinvKC = -be.matmul(UhatKC, AinvCC)
        # step 4:  A⁻¹(K,K) = U⁻¹L⁻¹ − Û(K,C)·A⁻¹(C,K)
        AinvKK = be.gemm(diag_inv(K), UhatKC, AinvCK)

        AinvCK = np.asarray(AinvCK)
        AinvKC = np.asarray(AinvKC)
        for a, J in enumerate(C):
            Ainv[(J, K)] = AinvCK[offs[a]:offs[a + 1], :]
            Ainv[(K, J)] = AinvKC[:, offs[a]:offs[a + 1]]
        Ainv[(K, K)] = AinvKK

    return Ainv


def selected_inverse(A: sp.spmatrix, max_supernode: int = 32,
                     backend: str = "numpy") -> Tuple[Dict[Key, np.ndarray],
                                                      BlockStructure]:
    """End-to-end: symbolic → LU → selected inversion."""
    bs = symbolic_factorize(A, max_supernode=max_supernode)
    lu = factorize(A, bs=bs, backend=backend)
    return selinv(lu), bs


def dense_selinv_oracle(A: sp.spmatrix) -> np.ndarray:
    """O(N³) oracle: the full inverse."""
    return np.linalg.inv(np.asarray(sp.csr_matrix(A).todense()))


def compare_with_oracle(Ainv_blocks: Dict[Key, np.ndarray],
                        bs: BlockStructure, A: sp.spmatrix) -> float:
    """Max abs error of every computed block vs the dense inverse."""
    ref = dense_selinv_oracle(A)
    err = 0.0
    for (I, J), blk in Ainv_blocks.items():
        r0, r1 = bs.offsets[I], bs.offsets[I + 1]
        c0, c1 = bs.offsets[J], bs.offsets[J + 1]
        err = max(err, float(np.max(np.abs(np.asarray(blk) - ref[r0:r1, c0:c1]))))
    return err
