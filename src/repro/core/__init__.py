"""repro.core — the paper's contribution: supernodal selected inversion +
tree-based asynchronous restricted collectives."""
from .trees import (CommTree, TreeKind, build_tree, flat_tree, binary_tree,
                    shifted_binary_tree, stable_hash)
from .symbolic import BlockStructure, symbolic_factorize, partition_supernodes
from .supernodal_lu import LUFactors, factorize, dense_lu_nopivot
from .selinv import (selinv, selected_inverse, dense_selinv_oracle,
                     compare_with_oracle)
from .engine import Grid, PlanOptions, PSelInvEngine, SolveValues

__all__ = [
    "CommTree", "TreeKind", "build_tree", "flat_tree", "binary_tree",
    "shifted_binary_tree", "stable_hash",
    "BlockStructure", "symbolic_factorize", "partition_supernodes",
    "LUFactors", "factorize", "dense_lu_nopivot",
    "selinv", "selected_inverse", "dense_selinv_oracle", "compare_with_oracle",
    "Grid", "PlanOptions", "PSelInvEngine", "SolveValues",
]
