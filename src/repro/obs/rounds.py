"""Per-round profiling replay — ``engine.profile_rounds()``.

The α-per-round simulator *predicts* where the overlapped sweep spends
its time and PlanLint's overload heuristic *warns* from the tables; this
module *measures*.  It re-executes the session's sweep as per-round (or
per-level-chunk) jitted segments — the very same device code as the
fused executor, cut at round boundaries by
:func:`~repro.core.pselinv_dist.make_sweep_segments` — with
``block_until_ready`` fencing between segments, and joins the measured
walls against the plan's per-round wire tables and the α-β model:

* **residuals** — ``measured[t] − simulated[t]`` per executed round
  (:func:`~repro.core.simulator.simulated_round_times` applies the same
  round cut, so the join is like-for-like);
* **inbound skew** — per-rank inbound bytes / messages / attributed
  time: the paper's overload heuristic as a runtime dashboard,
  cross-checked against PlanLint's static ``load/imbalance`` WARN
  (same max/mean statistic, same :data:`~repro.core.verify.IMBALANCE_MAX`
  threshold);
* **α/β fit** — least-squares latency/bandwidth estimates from the
  pure-comm rounds, feeding the ROADMAP calibration item.

The replay's final A⁻¹ is returned so callers can assert bit-identity
against ``engine.solve`` (the segments are the sweep, not a model of
it); the conformance tests additionally pin the round count and the
per-round wire bytes to ``executed_wire_bytes``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.pselinv_dist import make_sweep_segments
from ..core.schedule import BYTES_PER_ELT
from ..core.simulator import NetworkModel, simulated_round_times
from ..core.verify import IMBALANCE_MAX

__all__ = ["RoundSample", "RoundProfile", "profile_rounds"]


@dataclass(frozen=True)
class RoundSample:
    """One measured segment of the replay (one executed round at
    ``chunk=1``; a consecutive round range otherwise)."""

    index: int                   #: segment position in the replay
    rounds: Tuple[int, ...]      #: plan round indices this segment ran
    wall_us: float               #: fenced wall time, best of ``reps``
    sim_us: float                #: α-β cost of the same rounds
    wire_bytes: float            #: physical permute payload (padding incl.)
    lane_bytes: float            #: algorithmic lane bytes (plan edges)
    msgs: int                    #: algorithmic lane count
    compute_ops: int             #: boundary compute ops fired
    pure_comm: bool              #: no compute at any covered boundary

    @property
    def residual_us(self) -> float:
        return self.wall_us - self.sim_us


@dataclass
class RoundProfile:
    """The measured per-round timeline of one profiled sweep, joined
    against the plan tables."""

    nrounds: int                     #: executed ppermute rounds in the plan
    nranks: int
    b: int
    chunk: int
    samples: List[RoundSample]
    init_us: float                   #: arena init + diagonal seeds segment
    final_us: float                  #: trailing compute + extraction segment
    final_sim_us: float
    inbound_bytes: np.ndarray        #: (P,) algorithmic inbound bytes
    inbound_msgs: np.ndarray         #: (P,) algorithmic inbound lanes
    inbound_time_us: np.ndarray      #: (P,) measured round walls attributed
    rank_bytes: np.ndarray = field(default=None, repr=False)
    """(nseg, P) inbound bytes per segment per rank — the exporter's
    per-rank lane payload."""
    ainv: Any = field(repr=False, default=None)  #: replay's A⁻¹ shards

    # -- joins ------------------------------------------------------------
    @property
    def wall_us(self) -> float:
        """Total fenced wall of the replay (init + rounds + final)."""
        return (self.init_us + self.final_us
                + sum(s.wall_us for s in self.samples))

    @property
    def sim_us(self) -> float:
        return self.final_sim_us + sum(s.sim_us for s in self.samples)

    def wire_bytes(self) -> float:
        """Physical permute bytes across the profiled rounds — equals
        ``executed_wire_bytes`` of an overlapped program (tested)."""
        return sum(s.wire_bytes for s in self.samples)

    def residuals_us(self) -> np.ndarray:
        """Measured − simulated per segment (the calibration signal)."""
        return np.array([s.residual_us for s in self.samples])

    def round_walls_us(self) -> np.ndarray:
        return np.array([s.wall_us for s in self.samples])

    def skew(self) -> Dict[str, Any]:
        """The paper's inbound-overload statistic, measured: per-rank
        inbound bytes/messages/attributed time plus the max/mean ratio
        PlanLint's static ``load/imbalance`` lint thresholds
        (``exceeds_static_warn`` mirrors :data:`IMBALANCE_MAX`)."""
        bts = self.inbound_bytes
        mean = float(bts.mean()) if bts.size else 0.0
        ratio = float(bts.max() / mean) if mean > 0 else 1.0
        return {
            "inbound_bytes": bts.tolist(),
            "inbound_msgs": self.inbound_msgs.tolist(),
            "inbound_time_us": [round(t, 3)
                                for t in self.inbound_time_us.tolist()],
            "skew_ratio": ratio,
            "static_warn_threshold": IMBALANCE_MAX,
            "exceeds_static_warn": ratio > IMBALANCE_MAX,
        }

    def fit_alpha_beta(self) -> Tuple[float, float]:
        """Least-squares (α seconds, β seconds/byte) over the measured
        rounds: ``wall ≈ α + β · max-pair-bytes``.  Pure-comm rounds
        (no boundary compute) are preferred; if they don't span two
        distinct payload sizes the fit falls back to every round.  β is
        clamped at 0 (a negative slope just means dispatch latency
        dominates at this scale — α then carries the whole cost)."""
        pool = [s for s in self.samples if s.pure_comm and s.wire_bytes > 0]
        if len({s.wire_bytes for s in pool}) < 2:
            pool = [s for s in self.samples if s.wire_bytes > 0] or \
                list(self.samples)
        x = np.array([s.wire_bytes / max(1, self.nranks) for s in pool])
        y = np.array([s.wall_us * 1e-6 for s in pool])
        if len(pool) == 0:
            return 0.0, 0.0
        if len({float(v) for v in x}) < 2:
            return float(y.mean()), 0.0
        A = np.stack([np.ones_like(x), x], axis=1)
        (alpha, beta), *_ = np.linalg.lstsq(A, y, rcond=None)
        if beta < 0:
            return float(y.mean()), 0.0
        return float(alpha), float(beta)

    # -- reporting --------------------------------------------------------
    def timeline(self) -> List[Dict[str, Any]]:
        """Flat rows (one per segment, cumulative start) for the
        Chrome-trace exporter and the CLI table."""
        rows: List[Dict[str, Any]] = []
        t = self.init_us
        for s in self.samples:
            rows.append({
                "index": s.index, "rounds": list(s.rounds),
                "start_us": t, "wall_us": s.wall_us, "sim_us": s.sim_us,
                "residual_us": s.residual_us, "wire_bytes": s.wire_bytes,
                "lane_bytes": s.lane_bytes, "msgs": s.msgs,
                "compute_ops": s.compute_ops, "pure_comm": s.pure_comm,
            })
            t += s.wall_us
        return rows

    def report(self) -> str:
        """Human-readable per-round table + the imbalance summary."""
        lines = [
            f"profiled {self.nrounds} executed rounds on {self.nranks} "
            f"ranks (chunk={self.chunk}):",
            f"{'seg':>4} {'rounds':>9} {'wall_us':>9} {'sim_us':>9} "
            f"{'resid_us':>9} {'wire_B':>10} {'msgs':>5} {'comp':>5}",
        ]
        for s in self.samples:
            rng = (f"{s.rounds[0]}" if len(s.rounds) == 1
                   else f"{s.rounds[0]}-{s.rounds[-1]}")
            lines.append(
                f"{s.index:>4} {rng:>9} {s.wall_us:>9.1f} "
                f"{s.sim_us:>9.1f} {s.residual_us:>9.1f} "
                f"{s.wire_bytes:>10.0f} {s.msgs:>5d} {s.compute_ops:>5d}")
        lines.append(f"init {self.init_us:.1f} us · final "
                     f"{self.final_us:.1f} us · total {self.wall_us:.1f} "
                     f"us (simulated {self.sim_us:.1f} us)")
        sk = self.skew()
        alpha, beta = self.fit_alpha_beta()
        lines.append("per-rank inbound bytes: "
                     + " ".join(f"{int(v)}" for v in sk["inbound_bytes"]))
        lines.append("per-rank inbound msgs:  "
                     + " ".join(f"{int(v)}" for v in sk["inbound_msgs"]))
        lines.append("per-rank time (us):     "
                     + " ".join(f"{v:.0f}" for v in sk["inbound_time_us"]))
        lines.append(
            f"inbound skew max/mean = {sk['skew_ratio']:.3f} "
            f"(static lint warns > {sk['static_warn_threshold']:.1f}: "
            f"{'EXCEEDED' if sk['exceeds_static_warn'] else 'ok'})")
        lines.append(f"fitted alpha = {alpha * 1e6:.1f} us, beta = "
                     f"{beta * 1e9:.3f} ns/byte")
        return "\n".join(lines)


def _chunk_boundaries(nrounds: int, chunk: int) -> List[int]:
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    cuts = list(range(0, nrounds, chunk)) + [nrounds]
    # range() already ends < nrounds, but a chunk dividing nrounds
    # exactly would duplicate the terminal cut
    if len(cuts) >= 2 and cuts[-2] == nrounds:
        cuts.pop()
    return cuts


def profile_rounds(engine, values, *, chunk: int = 1, reps: int = 3,
                   dtype=jnp.float32,
                   model: Optional[NetworkModel] = None) -> RoundProfile:
    """Measure one sweep per executed round.  ``engine`` is a
    :class:`~repro.core.engine.PSelInvEngine` with an overlapped
    schedule (stream sessions profile through the overlapped rounds
    their tables were lowered from); ``values`` is a matrix,
    :class:`SolveValues`, or an ``(Lh, Dinv)`` pair — single matrix
    only (rank 5).  Each segment is jitted under shard_map, warmed once
    (compile excluded from timing), then timed ``reps`` times with
    ``block_until_ready`` fencing, keeping the per-segment minimum.

    Prefer :meth:`PSelInvEngine.profile_rounds`, which forwards here."""
    prog = engine.program
    ov = prog.overlap_plan
    if ov is None:
        raise ValueError(
            "profile_rounds needs an overlapped schedule — analyze with "
            "PlanOptions(overlap=True) (default) or stream=True")
    if not (isinstance(values, (tuple, list)) and len(values) == 2):
        values = engine.prepare_values(values)   # a matrix, not shards
    Lh, Dinv = values
    Lh = jnp.asarray(Lh, dtype=dtype)
    Dinv = jnp.asarray(Dinv, dtype=dtype)
    if Lh.ndim != 5:
        raise ValueError(f"profile_rounds takes one matrix (rank-5 "
                         f"values), got shape {Lh.shape}")

    nrounds = len(ov.rounds)
    boundaries = _chunk_boundaries(nrounds, chunk)
    init, steps, final = make_sweep_segments(prog, boundaries)

    spec = P("xy")
    mesh = engine.mesh

    def _sm(fn, nin):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=(spec,) * nin,
                                 out_specs=spec))

    init_j = _sm(init, 2)
    steps_j = [_sm(s, 3) for s in steps]
    final_j = _sm(final, 3)

    # warm-up pass: compiles every segment and checks the plumbing
    arena = init_j(Lh, Dinv).block_until_ready()
    for sj in steps_j:
        arena = sj(arena, Lh, Dinv).block_until_ready()
    ainv = final_j(arena, Lh, Dinv).block_until_ready()

    nseg = len(steps_j)
    walls = np.full(nseg, np.inf)
    init_wall = np.inf
    final_wall = np.inf
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        arena = init_j(Lh, Dinv).block_until_ready()
        init_wall = min(init_wall, (time.perf_counter() - t0) * 1e6)
        for i, sj in enumerate(steps_j):
            t0 = time.perf_counter()
            arena = sj(arena, Lh, Dinv).block_until_ready()
            walls[i] = min(walls[i], (time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        ainv = final_j(arena, Lh, Dinv).block_until_ready()
        final_wall = min(final_wall, (time.perf_counter() - t0) * 1e6)

    # ---- join against the plan tables ---------------------------------
    P_ = ov.pr * ov.pc
    b = prog.b
    sim = simulated_round_times(prog, model) * 1e6   # (nrounds + 1,) us
    inbound_bytes = np.zeros(P_)
    inbound_msgs = np.zeros(P_, dtype=np.int64)
    inbound_time = np.zeros(P_)
    rank_bytes = np.zeros((len(boundaries) - 1, P_))
    samples: List[RoundSample] = []
    for i, (lo, hi) in enumerate(zip(boundaries, boundaries[1:])):
        wire = lane = 0.0
        msgs = 0
        comp = 0
        seg_in = np.zeros(P_)
        seg_msgs = np.zeros(P_, dtype=np.int64)
        for t in range(lo, hi):
            rnd = ov.rounds[t]
            wire += len(rnd.perm) * rnd.width * b * b * BYTES_PER_ELT
            for (_s, d, _kind, _lv, nb_) in rnd.edges:
                lane += nb_
                msgs += 1
                seg_in[d] += nb_
                seg_msgs[d] += 1
            comp += len(ov.compute_at[t])
        inbound_bytes += seg_in
        inbound_msgs += seg_msgs
        rank_bytes[i] = seg_in
        if seg_in.sum() > 0:
            # attribute the fenced wall to ranks by inbound share — a
            # dashboard statistic, not a per-rank measurement (the BSP
            # fence can't see inside a round)
            inbound_time += walls[i] * seg_in / seg_in.sum()
        samples.append(RoundSample(
            index=i, rounds=tuple(range(lo, hi)),
            wall_us=float(walls[i]), sim_us=float(sim[lo:hi].sum()),
            wire_bytes=wire, lane_bytes=lane, msgs=msgs,
            compute_ops=comp, pure_comm=(comp == 0)))

    return RoundProfile(
        nrounds=nrounds, nranks=P_, b=b, chunk=chunk, samples=samples,
        init_us=float(init_wall), final_us=float(final_wall),
        final_sim_us=float(sim[nrounds]),
        inbound_bytes=inbound_bytes, inbound_msgs=inbound_msgs,
        inbound_time_us=inbound_time, rank_bytes=rank_bytes, ainv=ainv)
