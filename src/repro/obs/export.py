"""Chrome-trace / Perfetto JSON export.

Serialises the three observability surfaces into one
``chrome://tracing`` / `ui.perfetto.dev` loadable file:

* **spans** (``obs.trace``) — pid 1, one lane per host thread;
* **round timeline** (``obs.rounds.RoundProfile``) — pid 2, an
  aggregate lane (tid 0) of the fenced per-round walls plus one lane
  per rank carrying that rank's inbound bytes/messages per round;
* **serve request lifecycles** — pid 3, one lane per structure queue,
  each request an ``X`` event from submission to completion with nested
  ``queued`` / ``batched`` phases when the batch timestamps are set.

Every event is a standard Trace-Event ``X`` (complete) or ``M``
(metadata) record with ``ph``/``name``/``ts``/``dur``/``pid``/``tid``/
``args`` — the fields the golden schema test pins.  Each source is
normalised to its own zero origin (spans use ``perf_counter``, serve
requests ``time.monotonic``; the epochs differ, so cross-source
alignment would be fiction — lanes within a source are exact).
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional

__all__ = ["chrome_trace", "write_trace"]

_PID_SPANS = 1
_PID_ROUNDS = 2
_PID_SERVE = 3


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": name}}]
    if tid is not None:
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": tname}})
    return out


def _span_events(spans) -> List[Dict[str, Any]]:
    spans = list(spans)
    if not spans:
        return []
    origin = min(s.t0_us for s in spans)
    events = _meta(_PID_SPANS, "host spans")
    tids: Dict[int, int] = {}
    for s in spans:
        tid = tids.get(s.tid)
        if tid is None:
            tid = tids[s.tid] = len(tids)
            events += _meta(_PID_SPANS, "host spans", tid,
                            f"thread {s.tid}")[1:]
        args = dict(s.attrs)
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        events.append({"ph": "X", "name": s.name, "cat": "span",
                       "ts": s.t0_us - origin, "dur": s.dur_us,
                       "pid": _PID_SPANS, "tid": tid, "args": args})
    return events


def _round_events(profile) -> List[Dict[str, Any]]:
    events = _meta(_PID_ROUNDS, "sweep rounds", 0, "all ranks")
    for rank in range(profile.nranks):
        events += _meta(_PID_ROUNDS, "sweep rounds", rank + 1,
                        f"rank {rank}")[1:]
    rank_bytes = profile.rank_bytes
    for row in profile.timeline():
        i = row["index"]
        name = (f"round {row['rounds'][0]}" if len(row["rounds"]) == 1
                else f"rounds {row['rounds'][0]}-{row['rounds'][-1]}")
        base = {"ph": "X", "cat": "round", "ts": row["start_us"],
                "dur": row["wall_us"], "pid": _PID_ROUNDS}
        events.append({**base, "name": name, "tid": 0, "args": {
            "sim_us": row["sim_us"], "residual_us": row["residual_us"],
            "wire_bytes": row["wire_bytes"],
            "lane_bytes": row["lane_bytes"], "msgs": row["msgs"],
            "compute_ops": row["compute_ops"],
            "pure_comm": row["pure_comm"]}})
        if rank_bytes is None:
            continue
        for rank in range(profile.nranks):
            nb = float(rank_bytes[i, rank])
            if nb <= 0:
                continue
            events.append({**base, "name": name, "tid": rank + 1,
                           "args": {"inbound_bytes": nb}})
    return events


def _serve_events(requests) -> List[Dict[str, Any]]:
    reqs = [r for r in requests if r.completed is not None]
    if not reqs:
        return []
    origin = min(r.submitted for r in reqs)
    events = _meta(_PID_SERVE, "serve requests")
    lanes: Dict[str, int] = {}
    for r in sorted(reqs, key=lambda r: r.submitted):
        tid = lanes.get(r.skey)
        if tid is None:
            tid = lanes[r.skey] = len(lanes)
            events += _meta(_PID_SERVE, "serve requests", tid,
                            f"queue {r.skey[:12]}")[1:]
        ts = (r.submitted - origin) * 1e6
        dur = (r.completed - r.submitted) * 1e6
        events.append({"ph": "X", "name": f"request {r.rid}",
                       "cat": "request", "ts": ts, "dur": dur,
                       "pid": _PID_SERVE, "tid": tid,
                       "args": {"rid": r.rid,
                                "status": r.status.value,
                                "latency_us": dur}})
        if r.batched_at is not None:
            cut = (r.batched_at - origin) * 1e6
            events.append({"ph": "X", "name": "queued", "cat": "request",
                           "ts": ts, "dur": max(0.0, cut - ts),
                           "pid": _PID_SERVE, "tid": tid,
                           "args": {"rid": r.rid}})
            events.append({"ph": "X", "name": "batched", "cat": "request",
                           "ts": cut, "dur": max(0.0, ts + dur - cut),
                           "pid": _PID_SERVE, "tid": tid,
                           "args": {"rid": r.rid}})
    return events


def chrome_trace(spans: Optional[Iterable] = None, profile=None,
                 requests: Optional[Iterable] = None) -> Dict[str, Any]:
    """Assemble the Trace-Event JSON dict from any subset of the three
    sources: an iterable of :class:`~repro.obs.trace.Span`, a
    :class:`~repro.obs.rounds.RoundProfile`, an iterable of
    :class:`~repro.serve.batcher.SolveRequest`."""
    events: List[Dict[str, Any]] = []
    if spans is not None:
        events += _span_events(spans)
    if profile is not None:
        events += _round_events(profile)
    if requests is not None:
        events += _serve_events(requests)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_trace(path: str, spans: Optional[Iterable] = None, profile=None,
                requests: Optional[Iterable] = None) -> str:
    """Write :func:`chrome_trace` to ``path`` (conventionally
    ``*.trace.json``); returns the path."""
    doc = chrome_trace(spans=spans, profile=profile, requests=requests)
    with open(path, "w") as f:
        json.dump(doc, f, indent=None, separators=(",", ":"),
                  default=float)
    return path
