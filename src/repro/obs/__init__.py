"""SweepScope — runtime observability for the selected-inversion stack.

The static layers (PlanLint, the α-per-round simulator, HloLint) reason
about what the schedule *should* do; this package measures what it
*does*:

* ``trace``    — nested span tracer with a thread-safe ring buffer and a
  near-zero-cost disabled path; the engine and serve layers emit spans
  through the module-level ``TRACER``.
* ``registry`` — unified metrics registry (counters / gauges /
  histograms with labels), one ``snapshot()`` and a prometheus-style
  text dump; ``engine.stats()`` and ``serve.metrics`` register into it.
* ``rounds``   — ``engine.profile_rounds()``: re-executes the overlapped
  sweep as per-round jitted segments with ``block_until_ready`` fencing
  and joins the measured timeline against the plan's wire tables
  (residuals, inbound-skew report, α/β fit).
* ``export``   — Chrome-trace / Perfetto JSON export of spans, round
  timelines and serve request lifecycles.

``rounds`` and ``export`` import the core/serve layers, so they are NOT
imported here — ``import repro.obs`` must stay cheap and cycle-free for
``core.engine`` (which imports ``obs.trace`` at module level).
"""
from . import registry, trace                                  # noqa: F401
from .registry import REGISTRY, MetricsRegistry                # noqa: F401
from .trace import TRACER, Span, Tracer                        # noqa: F401

__all__ = [
    "trace", "registry",
    "TRACER", "Tracer", "Span",
    "REGISTRY", "MetricsRegistry",
]
