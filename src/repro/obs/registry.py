"""Unified metrics registry — counters, gauges and histograms with
labels, one ``snapshot()``, and a prometheus-style text dump.

Before this existed the stack had three ad-hoc metric surfaces:
``engine.stats()`` (a dict rebuilt per call), ``ServeMetrics`` (its own
locks + two hand-rolled percentile paths), and the bench CSV.  The
registry is the single scrape surface they all write through:

* ``Counter``   — monotone ``inc``; labeled children via ``labels()``.
* ``Gauge``     — ``set`` / ``inc``; last value wins.
* ``Histogram`` — ``observe``; keeps exact ``count``/``sum`` plus a
  bounded sample reservoir (first ``max_samples`` observations, the same
  keep-the-head policy ``ServeMetrics`` used) for percentiles.  This is
  the *one* percentile implementation — serve latency and batch
  occupancy are thin wrappers over it.

Registration is idempotent: asking for an existing name returns the
existing metric (type and label names must match).  All mutation is
lock-guarded, so serve worker threads and the engine can share one
registry.  ``REGISTRY`` is the process-wide default; anything that wants
isolation (tests, per-server metrics) builds a private
:class:`MetricsRegistry`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY"]


def _format_labels(labelnames: Sequence[str],
                   labelvalues: Sequence[Any]) -> str:
    if not labelnames:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in zip(labelnames, labelvalues))
    return "{" + inner + "}"


class _Metric:
    """Shared plumbing: name/help/labels and the child table."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 lock: Optional[threading.Lock] = None) -> None:
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock if lock is not None else threading.Lock()
        self._children: "OrderedDict[Tuple[Any, ...], _Metric]" = OrderedDict()

    def labels(self, *values: Any, **kv: Any):
        """Child metric for one label-value combination."""
        if kv:
            if values:
                raise ValueError("pass label values positionally or by "
                                 "keyword, not both")
            values = tuple(kv[k] for k in self.labelnames)
        if len(values) != len(self.labelnames):
            raise ValueError(f"{self.name}: expected labels "
                             f"{self.labelnames}, got {values!r}")
        key = tuple(values)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child()
                self._children[key] = child
            return child

    def _make_child(self) -> "_Metric":
        raise NotImplementedError

    def children(self) -> List[Tuple[Tuple[Any, ...], "_Metric"]]:
        with self._lock:
            return list(self._children.items())

    def _require_plain(self) -> None:
        if self.labelnames:
            raise ValueError(f"{self.name} is labeled "
                             f"{self.labelnames}; call .labels(...) first")


class Counter(_Metric):
    kind = "counter"

    def __init__(self, *a, **kw) -> None:
        super().__init__(*a, **kw)
        self._value = 0.0

    def _make_child(self) -> "Counter":
        return Counter(self.name, self.help, (), lock=self._lock)

    def inc(self, by: float = 1.0) -> None:
        self._require_plain()
        if by < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += by

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, *a, **kw) -> None:
        super().__init__(*a, **kw)
        self._value = 0.0

    def _make_child(self) -> "Gauge":
        return Gauge(self.name, self.help, (), lock=self._lock)

    def set(self, value: float) -> None:
        self._require_plain()
        with self._lock:
            self._value = float(value)

    def inc(self, by: float = 1.0) -> None:
        self._require_plain()
        with self._lock:
            self._value += by

    def max(self, value: float) -> None:
        """High-water update: keep the larger of current and ``value``."""
        self._require_plain()
        with self._lock:
            if value > self._value:
                self._value = float(value)

    @property
    def value(self) -> float:
        return self._value


class Histogram(_Metric):
    """Count/sum plus a bounded reservoir of raw observations.

    The reservoir keeps the first ``max_samples`` observations and then
    stops growing (``count``/``sum`` stay exact) — the same bounded
    policy the serve latency reservoir shipped with, so percentiles are
    stable under long-running servers without unbounded memory.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 lock: Optional[threading.Lock] = None,
                 max_samples: int = 100_000) -> None:
        super().__init__(name, help, labelnames, lock=lock)
        self.max_samples = int(max_samples)
        self._count = 0
        self._sum = 0.0
        self._samples: List[float] = []

    def _make_child(self) -> "Histogram":
        return Histogram(self.name, self.help, (), lock=self._lock,
                         max_samples=self.max_samples)

    def observe(self, value: float) -> None:
        self._require_plain()
        v = float(value)
        with self._lock:
            self._count += 1
            self._sum += v
            if len(self._samples) < self.max_samples:
                self._samples.append(v)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> Optional[float]:
        return (self._sum / self._count) if self._count else None

    def percentile(self, q) -> Any:
        """``np.percentile`` over the reservoir; None when empty.

        Accepts a scalar or a sequence of q values (0–100), matching
        the shape ``np.percentile`` would return.
        """
        with self._lock:
            if not self._samples:
                return None
            return np.percentile(np.asarray(self._samples), q)

    def samples(self) -> List[float]:
        with self._lock:
            return list(self._samples)

    def summary(self, qs: Iterable[float] = (50, 95, 99)) -> Dict[str, Any]:
        qs = tuple(qs)
        ps = self.percentile(qs)
        out: Dict[str, Any] = {"count": self._count, "sum": self._sum,
                               "mean": self.mean}
        for q, p in zip(qs, ps if ps is not None else [None] * len(qs)):
            out[f"p{q:g}"] = float(p) if p is not None else None
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metric table with idempotent registration."""

    def __init__(self) -> None:
        self._metrics: "OrderedDict[str, _Metric]" = OrderedDict()
        self._lock = threading.Lock()

    # -- registration -----------------------------------------------------
    def _register(self, cls, name: str, help: str,
                  labelnames: Sequence[str], **kw) -> _Metric:
        with self._lock:
            got = self._metrics.get(name)
            if got is not None:
                if type(got) is not cls or got.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{got.kind}{got.labelnames} — cannot re-register "
                        f"as {cls.kind}{tuple(labelnames)}")
                return got
            m = cls(name, help, labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  max_samples: int = 100_000) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              max_samples=max_samples)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def unregister(self, name: str) -> None:
        with self._lock:
            self._metrics.pop(name, None)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    # -- scraping ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-able view: scalars for plain counters/gauges, a
        ``{label-string: value}`` dict for labeled ones, and a
        count/sum/mean/percentile summary per histogram."""
        out: Dict[str, Any] = {}
        for m in self.metrics():
            if m.labelnames:
                sub: Dict[str, Any] = {}
                for key, child in m.children():
                    label = ",".join(f"{k}={v}" for k, v
                                     in zip(m.labelnames, key))
                    sub[label] = (child.summary()
                                  if isinstance(child, Histogram)
                                  else child.value)
                out[m.name] = sub
            elif isinstance(m, Histogram):
                out[m.name] = m.summary()
            else:
                out[m.name] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus exposition-format dump (histograms as summaries)."""
        lines: List[str] = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} "
                         f"{'summary' if m.kind == 'histogram' else m.kind}")
            flat: List[Tuple[Tuple[Any, ...], _Metric]]
            flat = m.children() if m.labelnames else [((), m)]
            for key, child in flat:
                lbl = _format_labels(m.labelnames, key)
                if isinstance(child, Histogram):
                    base = lbl[1:-1] if lbl else ""
                    ps = child.percentile((50, 95, 99))
                    for q, p in zip((0.5, 0.95, 0.99),
                                    ps if ps is not None else [None] * 3):
                        if p is None:
                            continue
                        qlbl = (f'{{{base + "," if base else ""}'
                                f'quantile="{q}"}}')
                        lines.append(f"{m.name}{qlbl} {float(p):.9g}")
                    lines.append(f"{m.name}_count{lbl} {child.count}")
                    lines.append(f"{m.name}_sum{lbl} {child.sum:.9g}")
                else:
                    lines.append(f"{m.name}{lbl} {child.value:.9g}")
        return "\n".join(lines) + ("\n" if lines else "")


#: process-wide default registry — the one scrape surface.  The engine
#: publishes its gauges here; servers default to private registries but
#: can be pointed at this one.
REGISTRY = MetricsRegistry()
