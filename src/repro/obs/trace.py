"""Lightweight span tracer.

Spans are nested context managers carrying free-form attributes and
monotonic (``time.perf_counter_ns``) timestamps.  Finished spans land in
a bounded, thread-safe ring buffer on the owning :class:`Tracer`; the
Chrome-trace exporter (``obs.export``) serialises them one lane per
thread.

Design constraints, in order:

1. **Near-zero overhead when disabled.**  ``tracer.span(...)`` on a
   disabled tracer returns a shared ``_NullSpan`` singleton — no span
   object is allocated, no clock is read, nothing is buffered.  This is
   what lets the engine leave trace calls inline on the ``solve`` hot
   path (the bench asserts ≤2 % overhead even *enabled*).
2. **Thread safety.**  The span stack is thread-local (nesting never
   crosses threads — a serve worker's spans parent to that worker's
   stack); the ring buffer append is guarded by a lock shared with
   ``spans()`` snapshots.
3. **Bounded memory.**  The buffer is a ``deque(maxlen=capacity)``;
   overflow drops the *oldest* span and bumps ``tracer.dropped``.

Typical use::

    from repro.obs.trace import TRACER
    TRACER.enable()
    with TRACER.span("engine.solve", B=4) as sp:
        ...
        sp.set(bucket=8)
    events = TRACER.spans()
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Span", "Tracer", "TRACER"]


@dataclass(frozen=True)
class Span:
    """One *finished* span — an immutable record in the ring buffer."""

    name: str
    t0_us: float                 #: start, microseconds on the monotonic clock
    dur_us: float                #: wall duration, microseconds
    span_id: int
    parent_id: Optional[int]     #: enclosing span on the same thread, if any
    tid: int                     #: OS thread ident that ran the span
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def t1_us(self) -> float:
        return self.t0_us + self.dur_us


class _NullSpan:
    """Shared no-op span returned by disabled tracers.

    A single module-level instance serves every disabled ``span()``
    call, so the disabled path allocates nothing per call (tested).
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


_NULL = _NullSpan()


class _ActiveSpan:
    """A live span: context manager that records itself on exit."""

    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id",
                 "tid", "_t0_ns")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Dict[str, Any]) -> None:
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = next(tracer._ids)
        self.parent_id = None
        self.tid = 0
        self._t0_ns = 0

    def set(self, **attrs: Any) -> "_ActiveSpan":
        """Attach/overwrite attributes mid-span; chainable."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_ActiveSpan":
        stack = self._tracer._stack()
        self.parent_id = stack[-1].span_id if stack else None
        self.tid = threading.get_ident()
        stack.append(self)
        # read the clock last so setup cost is outside the measured window
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1_ns = time.perf_counter_ns()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        else:                               # unbalanced exit; don't corrupt
            try:
                stack.remove(self)
            except ValueError:
                pass
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._record(Span(
            name=self.name,
            t0_us=self._t0_ns / 1e3,
            dur_us=(t1_ns - self._t0_ns) / 1e3,
            span_id=self.span_id,
            parent_id=self.parent_id,
            tid=self.tid,
            attrs=self.attrs,
        ))
        return False


class Tracer:
    """Span collector with an enable switch and a bounded ring buffer."""

    def __init__(self, capacity: int = 65536, enabled: bool = False) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self.dropped = 0
        self._buf: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._ids = itertools.count(1)

    # -- control ----------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.dropped = 0

    # -- emission ---------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Open a span.  Disabled tracers return the shared null span."""
        if not self.enabled:
            return _NULL
        return _ActiveSpan(self, name, attrs)

    def instant(self, name: str, **attrs: Any) -> None:
        """Record a zero-duration marker (e.g. a flush decision)."""
        if not self.enabled:
            return
        now = time.perf_counter_ns() / 1e3
        stack = self._stack()
        self._record(Span(name=name, t0_us=now, dur_us=0.0,
                          span_id=next(self._ids),
                          parent_id=stack[-1].span_id if stack else None,
                          tid=threading.get_ident(), attrs=attrs))

    # -- inspection -------------------------------------------------------
    def spans(self) -> List[Span]:
        """Snapshot of the ring buffer, oldest first."""
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    # -- internals --------------------------------------------------------
    def _stack(self) -> List[_ActiveSpan]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self._buf) == self.capacity:
                self.dropped += 1
            self._buf.append(span)


#: process-wide default tracer used by the engine and serve layers;
#: disabled until something calls ``TRACER.enable()``.
TRACER = Tracer()
