"""Tiled GEMM Pallas kernel — the supernodal-GEMM hot spot of PSelInv
(step 3 of Alg. 1: A⁻¹(C,C)·L̂(C,K)), MXU-aligned 128×128×128 tiles with a
VMEM f32 accumulator across the K grid dimension."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["block_gemm_pallas"]


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_tiles: int,
                 alpha: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_tiles - 1)
    def _done():
        o_ref[...] = (alpha * acc_ref[...]).astype(o_ref.dtype)


def _pad_to(x, mult, axes):
    pads = [(0, 0)] * x.ndim
    needs = False
    for ax in axes:
        rem = (-x.shape[ax]) % mult
        if rem:
            pads[ax] = (0, rem)
            needs = True
    return jnp.pad(x, pads) if needs else x


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "alpha", "interpret"))
def block_gemm_pallas(a: jnp.ndarray, b: jnp.ndarray, bm: int = 128,
                      bn: int = 128, bk: int = 128, alpha: float = 1.0,
                      interpret: bool = True) -> jnp.ndarray:
    """alpha * (a @ b); shapes padded up to tile multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    ap = _pad_to(a, max(bm, bk), (0, 1))[: ((m + bm - 1) // bm) * bm,
                                         : ((k + bk - 1) // bk) * bk]
    bp = _pad_to(b, max(bk, bn), (0, 1))[: ((k + bk - 1) // bk) * bk,
                                         : ((n + bn - 1) // bn) * bn]
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, k_tiles=grid[2], alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
