"""Pallas TPU kernels for the compute hot-spots: the supernodal GEMM/TRSM
of selected inversion and the attention/norm hot paths of the LM stack.
`ops` holds the jit'd public wrappers (interpret-mode on CPU), `ref` the
pure-jnp oracles the tests compare against."""
