"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gemm_ref", "gemm_acc_ref", "flash_attention_ref", "rmsnorm_ref",
           "trsm_ref"]


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def gemm_acc_ref(acc, a, b, alpha=-1.0):
    return acc + alpha * jnp.dot(a, b,
                                 preferred_element_type=jnp.float32
                                 ).astype(acc.dtype)


def flash_attention_ref(q, k, v, causal: bool = True):
    """q/k/v: (B, S, H, hd) — standard softmax attention oracle."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(q.dtype), v)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)
            * scale.astype(x.dtype))


def trsm_ref(b, u):
    """Solve X·U = B with U upper triangular (right-side TRSM — the
    selected-inversion normalization  L̂ = L·U⁻¹)."""
    import jax.scipy.linalg as jla
    return jla.solve_triangular(u.T, b.T, lower=True).T.astype(b.dtype)
