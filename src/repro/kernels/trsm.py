"""Right-side upper-triangular solve Pallas kernel:  X·U = B.

This is the PSelInv normalization hot spot (L̂(I,K) = L(I,K)·U(K,K)⁻¹,
Alg. 1 loop 1). Row tiles of B stream through VMEM; the full U block
(supernode width ≤ 256) stays VMEM-resident; forward substitution runs
column-by-column with ``fori_loop`` over dynamic VMEM slices."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["trsm_pallas"]


def _trsm_kernel(b_ref, u_ref, o_ref, *, k: int):
    u = u_ref[...].astype(jnp.float32)      # (k, k) upper
    b = b_ref[...].astype(jnp.float32)      # (bm, k)

    def col(j, x):
        # x[:, j] = (b[:, j] - Σ_{i<j} x[:, i]·u[i, j]) / u[j, j]
        mask = jax.lax.broadcasted_iota(jnp.int32, (k,), 0) < j
        uj = jnp.where(mask, u[:, j], 0.0)
        s = x @ uj                           # (bm,)
        xj = (jax.lax.dynamic_slice_in_dim(b, j, 1, axis=1)[:, 0] - s) \
            / u[j, j]
        return jax.lax.dynamic_update_slice_in_dim(
            x, xj[:, None], j, axis=1)

    x = jax.lax.fori_loop(0, k, col, jnp.zeros_like(b))
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def trsm_pallas(b, u, bm: int = 128, interpret: bool = True):
    """Solve X·U = B; b: (m, k), u: (k, k) upper triangular."""
    m, k = b.shape
    assert u.shape == (k, k)
    bm = min(bm, m)
    pad = (-m) % bm
    bp = jnp.pad(b, ((0, pad), (0, 0))) if pad else b

    out = pl.pallas_call(
        functools.partial(_trsm_kernel, k=k),
        grid=(bp.shape[0] // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(bp.shape, b.dtype),
        interpret=interpret,
    )(bp, u)
    return out[:m]
