"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes in Python/XLA for correctness validation. On a real
TPU backend the same calls compile to Mosaic. ``REPRO_FORCE_INTERPRET=0``
overrides the auto-detection."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .block_gemm import block_gemm_pallas
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas
from .trsm import trsm_pallas

__all__ = ["block_gemm", "block_gemm_acc", "flash_attention", "rmsnorm",
           "trsm", "use_interpret", "pselinv_level_gemm",
           "pselinv_round_gemm"]


def use_interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() == "cpu"


def block_gemm(a, b):
    return block_gemm_pallas(a, b, interpret=use_interpret())


def block_gemm_acc(acc, a, b, alpha=-1.0):
    """acc + alpha·(a@b) — the Schur-update form used by supernodal LU."""
    return acc + block_gemm_pallas(a, b, alpha=alpha,
                                   interpret=use_interpret())


def pselinv_level_gemm(Ainv, Uh_m):
    """The sweep's masked block-GEMM for one elimination-tree level:
    ``partial[k, i] = Σ_j Ainv[i, j] @ Uh_m[k, j]ᵀ`` — all of a level's
    supernodes in one 2-D tiled matmul (MXU-shaped on TPU via the Pallas
    kernel; plain XLA dot as the CPU reference path).

    Ainv: (nbr, nbc, b, b) local A⁻¹ block grid; Uh_m: (nk, nbc, b, b)
    struct-masked Û stack. Returns (nk, nbr, b, b) partial products."""
    nbr, nbc, b, _ = Ainv.shape
    nk = Uh_m.shape[0]
    a2 = Ainv.transpose(0, 2, 1, 3).reshape(nbr * b, nbc * b)
    b2 = Uh_m.transpose(1, 3, 0, 2).reshape(nbc * b, nk * b)
    if jax.default_backend() == "cpu":
        p2 = jnp.dot(a2, b2)      # interpret-mode Pallas is trace-hostile
    else:
        p2 = block_gemm_pallas(a2, b2, interpret=use_interpret())
    return p2.reshape(nbr, b, nk, b).transpose(2, 0, 1, 3)


def pselinv_round_gemm(Ainv, Uh, cmask):
    """Masked sweep GEMM keyed by a *round* of the overlapped stream: the
    struct mask arrives per round boundary (whatever elimination-tree
    level fires there), not per Python-level loop iteration.

    Ainv: (nbr, nbc, b, b) local A⁻¹ grid; Uh: (nk, nbc, b, b) raw Û
    stack straight out of the comm arena; cmask: (nk, nbc) struct mask of
    the firing level. Returns (nk, nbr, b, b) partial products through
    the same tiled-matmul core as :func:`pselinv_level_gemm`."""
    return pselinv_level_gemm(Ainv, Uh * cmask[:, :, None, None])


def flash_attention(q, k, v, causal=True):
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=use_interpret())


def rmsnorm(x, scale, eps=1e-5):
    return rmsnorm_pallas(x, scale, eps=eps, interpret=use_interpret())


def trsm(b, u):
    return trsm_pallas(b, u, interpret=use_interpret())
