"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the
kernel body executes in Python/XLA for correctness validation. On a real
TPU backend the same calls compile to Mosaic. ``REPRO_FORCE_INTERPRET=0``
overrides the auto-detection."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .block_gemm import block_gemm_pallas
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas
from .trsm import trsm_pallas

__all__ = ["block_gemm", "block_gemm_acc", "flash_attention", "rmsnorm",
           "trsm", "use_interpret"]


def use_interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() == "cpu"


def block_gemm(a, b):
    return block_gemm_pallas(a, b, interpret=use_interpret())


def block_gemm_acc(acc, a, b, alpha=-1.0):
    """acc + alpha·(a@b) — the Schur-update form used by supernodal LU."""
    return acc + block_gemm_pallas(a, b, alpha=alpha,
                                   interpret=use_interpret())


def flash_attention(q, k, v, causal=True):
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=use_interpret())


def rmsnorm(x, scale, eps=1e-5):
    return rmsnorm_pallas(x, scale, eps=eps, interpret=use_interpret())


def trsm(b, u):
    return trsm_pallas(b, u, interpret=use_interpret())
