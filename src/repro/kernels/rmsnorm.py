"""Fused RMSNorm Pallas kernel: one pass over row tiles, f32 statistics
in VMEM, scale applied in the same tile visit (no second HBM read)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm_pallas(x, scale, eps: float = 1e-5, br: int = 256,
                   interpret: bool = True):
    """x: (rows, d); scale: (d,). Rows tiled; d stays whole in VMEM
    (d ≤ ~16k fits comfortably)."""
    rows, d = x.shape
    br = min(br, rows)
    pad = (-rows) % br
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xp.shape[0] // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, scale)
    return out[:rows]
