"""Flash-attention Pallas kernel (TPU target; interpret-mode validated).

Online-softmax over KV tiles with running (m, l, acc) VMEM scratch.
Grid: (batch·heads, q_tiles, kv_tiles) — the kv axis is the innermost
(sequential) dimension so the scratch carries across it. Causal masking
at element granularity inside the tile; fully-masked tiles are skipped
with ``pl.when`` (no MXU work issued)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_tiles: int, bq: int, bk: int, causal: bool,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _tile():
        q = q_ref[0]                                   # (bq, hd)
        k = k_ref[0]                                   # (bk, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == kv_tiles - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, bq: int = 128,
                           bk: int = 128, interpret: bool = True):
    """q/k/v: (B, S, H, hd) with same H (repeat GQA outside).
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    scale = hd ** -0.5

    # (B*H, S, hd) layout
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    grid = (B * H, S // bq, S // bk)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, kv_tiles=grid[2], bq=bq, bk=bk,
                          causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
