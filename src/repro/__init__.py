"""repro — tree-based asynchronous restricted collectives for parallel
selected inversion (PSelInv), as a multi-pod JAX framework."""
__version__ = "0.1.0"
