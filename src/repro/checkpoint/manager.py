"""Sharded checkpointing with async commit and elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json        tree structure, shapes, dtypes, step
            shard_<i>.npz        leaf arrays (grouped ~512 MB per shard)
            COMMITTED            written last (atomic rename) — a
                                 checkpoint without it is ignored

Elastic restore: leaves are stored as *global* arrays; on load they are
re-device_put with whatever sharding the (possibly different-size) mesh
prescribes — a checkpoint from N devices restores on M.
The writer runs on a background thread so the train loop never blocks on
disk (fault tolerance requirement: checkpoint cadence ≠ step cadence).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

SHARD_BYTES = 512 << 20


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        host_items, _ = _flatten(tree)
        host = [(k, np.asarray(v)) for k, v in host_items]

        def write():
            try:
                self._write(step, host)
            except BaseException as e:    # surfaced on next wait()
                self._error = e

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]]):
        path = os.path.join(self.dir, f"step_{step:09d}")
        tmp = path + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        shard: Dict[str, np.ndarray] = {}
        shard_bytes, shard_id = 0, 0

        def flush():
            nonlocal shard, shard_bytes, shard_id
            if shard:
                np.savez(os.path.join(tmp, f"shard_{shard_id}.npz"), **shard)
                shard, shard_bytes = {}, 0
                shard_id += 1

        for i, (key, arr) in enumerate(host):
            name = f"leaf_{i}"
            manifest["leaves"].append(
                {"key": key, "name": name, "shard": shard_id,
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
            shard[name] = arr
            shard_bytes += arr.nbytes
            if shard_bytes >= SHARD_BYTES:
                flush()
        flush()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(path, ignore_errors=True)
        os.replace(tmp, path)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- restore -------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "COMMITTED")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Restore into the structure of ``template``; if ``shardings``
        (a matching pytree of jax.sharding.Sharding) is given, leaves are
        device_put with it — elastic re-shard on load."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        shards: Dict[int, Any] = {}
        leaves = []
        for meta in manifest["leaves"]:
            sid = meta["shard"]
            if sid not in shards:
                shards[sid] = np.load(
                    os.path.join(path, f"shard_{sid}.npz"))
            leaves.append(shards[sid][meta["name"]])
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        assert len(flat_t) == len(leaves), "checkpoint/template mismatch"
        if shardings is not None:
            flat_s = treedef.flatten_up_to(shardings)
            leaves = [jax.device_put(l, s) for l, s in zip(leaves, flat_s)]
        else:
            leaves = [np.asarray(l) for l in leaves]
        return treedef.unflatten(leaves)
