"""Executable tree collectives: the paper's restricted broadcast/reduce
lowered to ``lax.ppermute`` rounds inside ``shard_map``.

XLA, like the MPI standard, has no *subset* collective on a mesh axis —
``psum``/``all_gather`` always involve every device on the axis. Exactly
as the paper does with ``MPI_Isend/Irecv``, we build restricted
collectives from point-to-point transfers: each :class:`CommTree` is
compiled to a static schedule of ppermute rounds (one (src, dst) set per
round; a device sources at most one transfer per round — the
collective-permute constraint, which is also the paper's one-message-at-
a-time sender model).

Multiple *concurrent* restricted collectives (the elimination-tree
pipelining of PSelInv, or per-layer gradient buckets in LM training) are
batched into the *same* rounds via :func:`batched_rounds` — the
executable analogue of several broadcasts being in flight at once, and
the reason the shifted tree's internal-node decorrelation matters.

All functions must be called inside ``shard_map`` with ``axis_name``
bound. Trees are expressed over *axis coordinates* [0, axis_size).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.trees import CommTree, TreeKind, build_tree

__all__ = ["tree_broadcast", "tree_reduce", "tree_allreduce",
           "subset_broadcast", "subset_reduce", "batched_rounds"]


def _member_mask(axis_name: str, members: Sequence[int]):
    """Scalar bool: is this device one of ``members``? One ``jnp.isin``
    against a constant member array — O(1) HLO ops instead of an
    O(|members|) chain of ``|(idx == r)`` compares (which dominated the
    lowered program for large subsets)."""
    if not len(members):
        return jnp.zeros((), dtype=bool)
    idx = lax.axis_index(axis_name)
    return jnp.isin(idx, jnp.asarray(sorted(members), dtype=idx.dtype))


def _recv_mask(idx, perm: List[Tuple[int, int]]):
    """Scalar bool: does this device receive in ``perm``? Same single
    ``jnp.isin``-against-a-constant shape as :func:`_member_mask`."""
    dsts = sorted({d for _, d in perm})
    if not dsts:
        return jnp.zeros((), dtype=bool)
    return jnp.isin(idx, jnp.asarray(dsts, dtype=idx.dtype))


def _apply_bcast_rounds(x, rounds: List[List[Tuple[int, int]]], axis_name: str):
    """Run broadcast rounds: destinations overwrite their buffer with the
    received value; everyone else keeps theirs."""
    idx = lax.axis_index(axis_name)
    for perm in rounds:
        moved = lax.ppermute(x, axis_name, perm)
        recv = _recv_mask(idx, perm)
        x = jax.tree_util.tree_map(
            lambda m, o: jnp.where(recv, m, o), moved, x)
    return x


def _apply_reduce_rounds(x, rounds: List[List[Tuple[int, int]]], axis_name: str):
    """Run reduction rounds: receivers accumulate the incoming partial."""
    idx = lax.axis_index(axis_name)
    for perm in rounds:
        moved = lax.ppermute(x, axis_name, perm)
        recv = _recv_mask(idx, perm)
        x = jax.tree_util.tree_map(
            lambda m, o: jnp.where(recv, o + m, o), moved, x)
    return x


def tree_broadcast(x, axis_name: str, tree: CommTree):
    """Broadcast the root's value to every participant of ``tree``.
    Non-participants keep their local value."""
    return _apply_bcast_rounds(x, tree.bcast_rounds(), axis_name)


def tree_reduce(x, axis_name: str, tree: CommTree):
    """Sum participants' values onto the root (non-participants are masked
    to zero before combining; their local buffer is left untouched in the
    result only at the root position semantics: the root ends with the
    participant sum, every other device's buffer is undefined-but-finite
    working state, as with MPI reduce scratch buffers)."""
    mask = _member_mask(axis_name, tree.ranks)
    xz = jax.tree_util.tree_map(
        lambda v: jnp.where(mask, v, jnp.zeros_like(v)), x)
    return _apply_reduce_rounds(xz, tree.reduce_rounds(), axis_name)


def tree_allreduce(x, axis_name: str, tree: CommTree):
    """Reduce onto the root then broadcast back down the same tree."""
    return tree_broadcast(tree_reduce(x, axis_name, tree), axis_name, tree)


def subset_broadcast(x, axis_name: str, root: int, members: Sequence[int],
                     kind: TreeKind = TreeKind.SHIFTED, tag: int = 0):
    """Restricted broadcast among ``members`` (axis coordinates) from
    ``root`` — the paper's Col-Bcast as a one-call API."""
    receivers = [m for m in members if m != root]
    tree = build_tree(kind, root, receivers, tag=tag)
    return tree_broadcast(x, axis_name, tree)


def subset_reduce(x, axis_name: str, root: int, members: Sequence[int],
                  kind: TreeKind = TreeKind.SHIFTED, tag: int = 0):
    """Restricted sum-reduction onto ``root`` — the paper's Row-Reduce."""
    receivers = [m for m in members if m != root]
    tree = build_tree(kind, root, receivers, tag=tag)
    return tree_reduce(x, axis_name, tree)


def batched_rounds(trees: Sequence[Tuple[CommTree, int]], op: str
                   ) -> List[List[Tuple[int, int]]]:
    """Merge the per-round edge lists of several *independent* collectives
    into shared rounds, offsetting each tree's coordinates into a global
    rank space (``coord + group * stride`` is the caller's job — here each
    entry is (tree, coordinate_offset)).

    This is how PSelInv keeps many restricted collectives in flight at
    once: trees over disjoint device groups (different mesh columns/rows)
    interleave their (src, dst) pairs in the same ppermute, so one HLO
    collective-permute round carries every concurrent collective's
    messages for that step.

    The merge itself (broadcasts left-aligned, reductions right-aligned
    so every root combines on the last round) and the disjointness check
    (ValueError naming the colliding pairs) are the CommPlan IR's
    :func:`repro.core.plan.merge_round_lists` — one implementation for
    the executor, the simulator, and these reusable collectives.
    """
    from repro.core.plan import merge_round_lists

    per_tree = []
    for tree, off in trees:
        rounds = tree.bcast_rounds() if op == "bcast" else tree.reduce_rounds()
        per_tree.append([[(s + off, d + off) for (s, d) in rnd]
                         for rnd in rounds])
    return merge_round_lists(per_tree, op)
