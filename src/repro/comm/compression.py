"""Gradient compression for the slow cross-pod stage.

int8 block quantization with error feedback: the quantization residual is
carried to the next step (standard EF-SGD construction), so compressed
cross-pod reduction stays unbiased in the long run. Only the *inter-pod*
stage compresses — intra-pod ICI is fast enough that compression would
cost more in compute than it saves in bytes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress", "ef_restore"]

_BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block int8 quantization. x is flattened; returns
    (q:int8 [n], scale:f32 [n/_BLOCK])."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def ef_compress(grad: jnp.ndarray, error: jnp.ndarray):
    """Error-feedback compression: quantize (grad + carried error), return
    (q, scale, new_error)."""
    target = grad + error
    q, scale = quantize_int8(target)
    approx = dequantize_int8(q, scale, grad.shape, grad.dtype)
    return q, scale, target - approx


def ef_restore(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype=jnp.float32):
    return dequantize_int8(q, scale, shape, dtype)
