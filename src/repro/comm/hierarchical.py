"""Hierarchical cross-pod collectives.

TPU pods have the same two-level network inhomogeneity the paper fights
on Cray XC30 (fast intra-pod ICI vs slow inter-pod DCN). Gradient
reduction is split:

    reduce-scatter (intra-pod, ICI)  →  tree all-reduce (inter-pod)
       →  all-gather (intra-pod, ICI)

so only ``1/pod_size`` of the gradient bytes cross the slow boundary.
The inter-pod stage uses the paper's trees; when several gradient buckets
reduce concurrently, each bucket gets a different shifted-tree rotation
(``tag=bucket``) so the forwarding role rotates across pods — the exact
load-balancing heuristic of the paper applied to cross-pod links.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.trees import TreeKind, build_tree
from .treecomm import tree_allreduce

__all__ = ["cross_pod_tree_allreduce", "hierarchical_allreduce"]


def cross_pod_tree_allreduce(x, pod_axis: str, npods: int,
                             kind: TreeKind = TreeKind.SHIFTED,
                             tag: int = 0, root: int = 0):
    """All-reduce across the pod axis via an explicit comm tree."""
    if npods == 1:
        return x
    receivers = [p for p in range(npods) if p != root]
    tree = build_tree(kind, root, receivers, tag=tag)
    return tree_allreduce(x, pod_axis, tree)


def hierarchical_allreduce(x, pod_axis: str, inner_axis: str, npods: int,
                           inner_size: int,
                           kind: TreeKind = TreeKind.SHIFTED,
                           tag: int = 0):
    """RS(intra) → tree-AR(inter) → AG(intra) over a 2-level mesh.

    ``x`` must have a leading dim divisible by ``inner_size`` (gradient
    buckets are flattened+padded by the optimizer wrapper). Must run
    inside shard_map with both axes bound.
    """
    # 1. reduce-scatter within the pod: each inner rank ends with one
    #    1/inner_size slice of the pod-local sum
    scat = lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    # 2. cross-pod tree all-reduce on the slice; rotate the tree root by
    #    (tag + inner rank) so concurrent buckets and different slice
    #    owners spread the forwarding load over pods
    root = (tag) % npods
    scat = cross_pod_tree_allreduce(scat, pod_axis, npods, kind=kind,
                                    tag=tag, root=root)
    # 3. all-gather within the pod
    return lax.all_gather(scat, inner_axis, axis=0, tiled=True)
