"""repro.comm — tree-based restricted collectives as an executable JAX
runtime feature (ppermute lowering of the paper's communication trees)."""
from .treecomm import (tree_broadcast, tree_reduce, tree_allreduce,
                       subset_broadcast, subset_reduce, batched_rounds)
from .hierarchical import hierarchical_allreduce, cross_pod_tree_allreduce

__all__ = [
    "tree_broadcast", "tree_reduce", "tree_allreduce",
    "subset_broadcast", "subset_reduce", "batched_rounds",
    "hierarchical_allreduce", "cross_pod_tree_allreduce",
]
