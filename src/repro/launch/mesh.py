"""Production mesh construction (a function, not a constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod adds a leading 2-pod axis
    (2×16×16 = 512). Axis semantics: pod = cross-DCN data/FSDP, data =
    intra-pod FSDP/DP, model = tensor parallel."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small host-device mesh for CI (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=prod(shape))."""
    return jax.make_mesh(shape, axes)
