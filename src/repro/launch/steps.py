"""Step builders shared by train.py, serve.py and dryrun.py: given a
(cfg, shape, mesh), produce the jitted step function + input specs +
shardings — the single source of truth for what runs and how it shards."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.data.pipeline import make_batch_specs
from repro.models import get_model
from repro.models.sharding_hooks import sharding_policy
from repro.models import transformer as tfm
from repro.optim import adamw_init, adamw_update, cosine_warmup
from repro.runtime.sharding import (act_policy, batch_specs, cache_pspec,
                                    param_specs)

__all__ = ["build_train_step", "build_prefill_step", "build_decode_step",
           "input_specs"]


def _named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    """ShapeDtypeStruct stand-ins for every input of the step that
    ``shape.mode`` selects (weak-type-correct, shardable, no allocation)."""
    api = get_model(cfg)
    if shape.mode in ("train", "prefill"):
        return {"batch": make_batch_specs(cfg, shape)}
    # decode: one token + KV/state cache at seq_len
    B, S = shape.global_batch, shape.seq_len
    spec = api.cache_spec(B, S)

    def to_sds(entry):
        if isinstance(entry, dict):
            return {k: jax.ShapeDtypeStruct(
                v, tfm.cache_dtype(k, cfg)) for k, v in entry.items()}
        return entry

    if isinstance(spec, tuple):
        cache = tuple(to_sds(e) for e in spec)
    else:   # enc-dec: KV caches are bf16 (compute dtype)
        cache = {k: jax.ShapeDtypeStruct(v, jnp.bfloat16)
                 for k, v in spec.items()}
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        "cache": cache,
    }


def _param_shardings(api, mesh):
    shapes = api.param_shapes()
    specs = param_specs(shapes, mesh)
    return shapes, specs, _named(specs, mesh)


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     peak_lr: float = 3e-4):
    """Returns (train_step_fn, arg_shapes, in_shardings, out_shardings).
    train_step(params, opt_state, batch, step) -> (params, opt, loss, mx)."""
    api = get_model(cfg)
    pshapes, pspecs, pshard = _param_shardings(api, mesh)
    state_dtype = (jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                   else jnp.float32)
    oshapes = jax.eval_shape(
        functools.partial(adamw_init, state_dtype=state_dtype), pshapes)
    ospecs = param_specs(oshapes, mesh)   # m/v mirror params; step scalar
    pol = act_policy(mesh)

    def train_step(params, opt_state, batch, step):
        with sharding_policy(pol):
            loss, grads = jax.value_and_grad(
                lambda p: api.loss(p, batch))(params)
        lr = cosine_warmup(step, peak_lr, warmup=2000, total=500_000)
        params, opt_state, mx = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, loss, mx

    bspecs = batch_specs(input_specs(cfg, shape, mesh)["batch"], mesh)
    in_shardings = (pshard, _named(ospecs, mesh), _named(bspecs, mesh),
                    NamedSharding(mesh, P()))
    out_shardings = (pshard, _named(ospecs, mesh),
                     NamedSharding(mesh, P()),
                     {"grad_norm": NamedSharding(mesh, P())})
    arg_shapes = (pshapes, oshapes,
                  input_specs(cfg, shape, mesh)["batch"],
                  jax.ShapeDtypeStruct((), jnp.int32))
    return train_step, arg_shapes, in_shardings, out_shardings


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    api = get_model(cfg)
    pshapes, pspecs, pshard = _param_shardings(api, mesh)
    pol = act_policy(mesh)

    def prefill_step(params, batch):
        with sharding_policy(pol):
            return api.prefill(params, batch)

    ins = input_specs(cfg, shape, mesh)
    bspecs = batch_specs(ins["batch"], mesh)
    in_shardings = (pshard, _named(bspecs, mesh))
    out_shardings = NamedSharding(mesh, P())
    return prefill_step, (pshapes, ins["batch"]), in_shardings, out_shardings


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    api = get_model(cfg)
    pshapes, pspecs, pshard = _param_shardings(api, mesh)
    pol = act_policy(mesh)

    def decode_step(params, token, pos, cache):
        with sharding_policy(pol):
            return api.decode_step(params, token, pos, cache)

    ins = input_specs(cfg, shape, mesh)
    cache_shardings = jax.tree_util.tree_map(
        lambda sds: NamedSharding(mesh, cache_pspec(sds.shape, mesh)),
        ins["cache"])
    tok_shard = NamedSharding(
        mesh, batch_specs({"t": ins["token"]}, mesh)["t"])
    in_shardings = (pshard, tok_shard, tok_shard, cache_shardings)
    out_shardings = (NamedSharding(mesh, P()), cache_shardings)
    args = (pshapes, ins["token"], ins["pos"], ins["cache"])
    return decode_step, args, in_shardings, out_shardings
