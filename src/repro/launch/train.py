"""Production training launcher: mesh + sharded step + fault-tolerant
loop.

    python -m repro.launch.train --arch granite-3-2b --shape train_4k \
        [--mesh 4x4] [--steps 100] [--ckpt DIR]

On a real TPU slice, omit --mesh to use the 16×16 production pod (or
--multi-pod for 2×16×16). On CPU, pass a small --mesh that matches
XLA_FLAGS=--xla_force_host_platform_device_count, and preferably a
reduced --scale so a step fits host memory.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import SHAPES, ShapeConfig, get_config, reduced_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_train_step
from repro.runtime.train_loop import TrainLoopConfig, run_train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--scale", default="full",
                    choices=["full", "reduced"],
                    help="reduced = CPU-sized model for smoke runs")
    ap.add_argument("--batch", type=int, default=0,
                    help="override global batch (reduced runs)")
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = reduced_config(cfg)
    shape = SHAPES[args.shape]
    if args.batch or args.seq:
        shape = ShapeConfig(shape.name, args.seq or shape.seq_len,
                            args.batch or shape.global_batch, shape.mode)

    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    step_fn, arg_shapes, in_sh, out_sh = build_train_step(cfg, shape, mesh)
    with mesh:
        jstep = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)

        # real parameter/optimizer initialization, sharded
        from repro.models import get_model
        from repro.optim import adamw_init
        api = get_model(cfg)
        params = jax.jit(api.init, out_shardings=in_sh[0])(
            jax.random.key(0))
        state_dtype = (jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                       else jnp.float32)
        opt = jax.jit(lambda p: adamw_init(p, state_dtype=state_dtype),
                      out_shardings=in_sh[1])(params)

        pipe = SyntheticTokens(
            vocab=cfg.vocab, seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            frontend_tokens=(cfg.n_frontend_tokens
                             if cfg.frontend == "vision" else
                             (shape.seq_len if cfg.enc_layers else 0)),
            d_model=cfg.d_model)

        def wrapped(params, opt_state, batch, step):
            b = {k: jax.device_put(v, s)
                 for (k, v), s in zip(batch.items(), in_sh[2].values())} \
                if isinstance(in_sh[2], dict) else batch
            return jstep(params, opt_state, b, jnp.asarray(step))

        out = run_train_loop(
            wrapped, params, opt, pipe,
            TrainLoopConfig(total_steps=args.steps,
                            ckpt_every=args.ckpt_every,
                            ckpt_dir=args.ckpt))
    print(f"[train] done: final step {out['final_step']}, "
          f"last loss {out['losses'][-1]:.4f}, "
          f"stragglers={out['stragglers']}, restarts={out['restarts']}")


if __name__ == "__main__":
    main()
