"""Roofline analysis from the dry-run artifacts.

Three terms per (arch × shape) cell on the single-pod mesh:

  compute    = FLOPs / (chips · 197e12)          [bf16 MXU peak, v5e]
  memory     = HBM bytes / (chips · 819e9)
  collective = collective bytes / (chips · 50e9) [per ICI link]

Methodology (CPU container, no wall clocks):

* FLOPs and HBM bytes come from an **analytic per-component model**
  (`flops_model`) — necessary because XLA's ``cost_analysis`` counts
  while-loop bodies exactly once (verified experimentally), so a
  scan-over-layers program under-reports by the trip count. The analytic
  model is cross-validated against ``cost_analysis`` on small *unrolled*
  configs in ``tests/test_roofline.py``.
* Collective bytes come from the compiled HLO of the dry-run with
  while-loop trip-count attribution (``dryrun.collective_bytes``) —
  measured, per device, from the real partitioned program.
* MODEL_FLOPS = 6·N·D (dense train) / 6·N_active·D (MoE train) /
  2·N·D (forward-only); the ratio MODEL_FLOPS / HLO_FLOPs exposes
  remat + causal-waste + GQA-repeat overheads.
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.config import SHAPES, ModelConfig, ShapeConfig, get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

__all__ = ["param_count", "model_flops", "flops_model", "roofline_row"]


# ---------------------------------------------------------------------------
# parameter counting
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> Tuple[float, float]:
    """(total, active-per-token) parameter counts, embeddings excluded
    from the *active* count's FFN scaling but included in totals."""
    D, F, H, KV, hd = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads,
                       cfg.hd)
    embed = cfg.vocab_padded * D * (1 if cfg.tie_embeddings else 2)

    def attn():
        return D * H * hd + 2 * D * KV * hd + H * hd * D

    def mlp():
        return (3 if cfg.act == "silu" else 2) * D * F

    def moe_total():
        return cfg.n_experts * 3 * D * F + D * cfg.n_experts

    def moe_active():
        return cfg.top_k * 3 * D * F + D * cfg.n_experts

    def mamba():
        di = cfg.mamba_expand * D
        return (D * 2 * di + cfg.mamba_d_conv * di
                + di * (2 * cfg.mamba_d_state + 1) + di * D)

    def mlstm():
        return 3 * D * H * hd + 2 * D * H + 2 * D * H * hd

    def slstm():
        return 4 * D * H * hd + H * hd * 4 * hd + H * hd * D

    total = active = embed
    from repro.models.transformer import layer_kinds
    if cfg.enc_layers:
        per = attn() + mlp()
        dec = 2 * attn() + mlp()
        total += cfg.enc_layers * per + cfg.n_layers * dec
        active = total
        return float(total), float(active)
    for kind in layer_kinds(cfg):
        if kind == "mlstm":
            total += mlstm(); active += mlstm(); continue
        if kind == "slstm":
            total += slstm(); active += slstm(); continue
        mixer, ffn = kind.split("+")
        m = attn() if mixer == "attn" else mamba()
        total += m; active += m
        if ffn == "moe":
            total += moe_total(); active += moe_active()
        else:
            total += mlp(); active += mlp()
    return float(total), float(active)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Textbook useful FLOPs: 6·N_active·D train, 2·N_active·D fwd."""
    total, active = param_count(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch      # decode: 1 token/seq


# ---------------------------------------------------------------------------
# analytic compiled-FLOPs / HBM-bytes model (matches the implementation,
# including its documented waste: non-causal chunk visits, GQA repeat)
# ---------------------------------------------------------------------------

def flops_model(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    D, F, H, KV, hd = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.n_kv_heads,
                       cfg.hd)
    B = shape.global_batch
    S = shape.seq_len
    mode = shape.mode
    T = B * (S if mode in ("train", "prefill") else 1)
    Vp = cfg.vocab_padded

    from repro.models.transformer import layer_kinds

    fl = 0.0
    by = 0.0
    p_dtype = 2 if cfg.param_dtype == "bfloat16" else 4
    a_dtype = 2  # bf16 activations

    def add_linear(t, d_in, d_out):
        nonlocal fl, by
        fl += 2.0 * t * d_in * d_out
        by += (d_in * d_out * p_dtype            # weights
               + t * (d_in + d_out) * a_dtype)   # act in/out

    def attn_layer(t):
        nonlocal fl, by
        add_linear(t, D, H * hd)
        add_linear(t, D, 2 * KV * hd)
        add_linear(t, H * hd, D)
        if mode == "decode":
            ctx = S
            fl_att = 2.0 * B * H * hd * ctx * 2          # qk + pv
            by_att = B * ctx * 2 * KV * hd * a_dtype      # read KV cache
        else:
            # chunked implementation visits ALL kv chunks (no causal
            # skip): full S per query — counted as implemented
            fl_att = 2.0 * B * H * S * S * hd * 2
            by_att = B * S * 2 * H * hd * a_dtype * 2     # repeated KV rw
        fl += fl_att
        by += by_att

    def mlp_layer(t):
        if cfg.act == "silu":
            add_linear(t, D, F); add_linear(t, D, F); add_linear(t, F, D)
        else:
            add_linear(t, D, F); add_linear(t, F, D)

    def moe_layer(t):
        add_linear(t, D, cfg.n_experts)                   # router
        te = t * cfg.top_k * cfg.capacity_factor
        add_linear(te, D, F); add_linear(te, D, F); add_linear(te, F, D)

    def mamba_layer(t):
        di = cfg.mamba_expand * D
        ds = cfg.mamba_d_state
        add_linear(t, D, 2 * di)
        add_linear(t, di, 2 * ds + 1)
        add_linear(t, di, D)
        nonlocal fl, by
        fl += t * di * (2 * cfg.mamba_d_conv + 6 * ds)    # conv + scan
        by += t * di * ds * 4 * (2 if mode != "decode" else 0.02)

    def mlstm_layer(t):
        nonlocal fl, by
        add_linear(t, D, 3 * H * hd)
        add_linear(t, D, 2 * H)
        add_linear(t, D, H * hd)
        add_linear(t, H * hd, D)
        L = min(cfg.xlstm_chunk, S if mode != "decode" else 1)
        fl += 2.0 * t * H * L * hd * 2           # intra-chunk attention
        fl += 2.0 * t * H * hd * hd * 2 / max(L, 1)  # chunk state update
        if mode == "decode":
            fl += 2.0 * B * H * hd * hd * 2

    def slstm_layer(t):
        add_linear(t, D, 4 * H * hd)
        add_linear(t, H * hd, D)
        nonlocal fl
        fl += 2.0 * t * H * hd * 4 * hd          # recurrent matmul

    kinds = (layer_kinds(cfg) if not cfg.enc_layers else [])
    if cfg.enc_layers:
        # encoder runs at full seq even for decode (cross memory given)
        t_enc = B * S if mode != "decode" else 0
        for _ in range(cfg.enc_layers):
            if t_enc:
                attn_layer(t_enc); mlp_layer(t_enc)
        for _ in range(cfg.n_layers):
            attn_layer(T)          # self
            attn_layer(T)          # cross (approx: same cost shape)
            mlp_layer(T)
    else:
        for kind in kinds:
            if kind == "mlstm":
                mlstm_layer(T); continue
            if kind == "slstm":
                slstm_layer(T); continue
            mixer, ffn = kind.split("+")
            (attn_layer if mixer == "attn" else mamba_layer)(T)
            (moe_layer if ffn == "moe" else mlp_layer)(T)

    add_linear(T, D, Vp)                          # logits
    by += T * 4                                   # tokens/labels

    if mode == "train":
        # backward 2×, remat recompute 1× of block fwd; optimizer reads
        # m, v + writes p, m, v (f32 math on p_dtype storage)
        total, _ = param_count(cfg)
        fwd_fl, fwd_by = fl, by
        fl = fwd_fl * (3.0 + (1.0 if cfg.remat == "block" else 0.0))
        by = fwd_by * 3.0 + total * p_dtype * 5.0
    return {"flops": fl, "hbm_bytes": by}


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def roofline_row(cell: Dict, chips: int = 256) -> Dict:
    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    anal = flops_model(cfg, shape)
    coll_dev = sum(cell.get("collective_bytes", {}).values())
    t_compute = anal["flops"] / (chips * PEAK_FLOPS)
    t_memory = anal["hbm_bytes"] / (chips * HBM_BW)
    t_coll = coll_dev / LINK_BW          # collective_bytes is per device
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    bound = max(terms.values())
    row = {
        "arch": cell["arch"], "shape": cell["shape"],
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / max(anal["flops"], 1.0),
        "roofline_fraction": (mf / (chips * PEAK_FLOPS)) / max(bound, 1e-30),
        "hbm_gb_per_dev": (cell.get("memory", {}).get(
            "argument_size_in_bytes", 0)
            + cell.get("memory", {}).get("temp_size_in_bytes", 0)) / 2**30,
    }
    return row


LEVERS = {
    "compute": "cut non-causal chunk visits / GQA repeat (kernel-level "
               "block-causal schedule) to close the useful-FLOPs gap",
    "memory": "fuse normalization+projection reads, bf16 optimizer "
              "states, larger tiles to raise arithmetic intensity",
    "collective": "reduce per-layer FSDP all-gathers (wider prefetch "
                  "bucketing), tree-scheduled cross-pod stage, "
                  "reduce-scatter gradients instead of all-reduce",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_results.json")
    ap.add_argument("--out", default="roofline.json")
    args = ap.parse_args()
    with open(args.dryrun) as f:
        cells = json.load(f)
    rows = []
    for cell in cells:
        if cell["status"] != "ok" or cell.get("multi_pod"):
            continue
        row = roofline_row(cell)
        row["lever"] = LEVERS[row["dominant"]]
        rows.append(row)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2%} {r['roofline_fraction']:7.2%}")


if __name__ == "__main__":
    main()
