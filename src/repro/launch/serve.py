"""Production serving launcher: mesh + sharded decode step + continuous
batching.

    python -m repro.launch.serve --arch granite-3-2b [--mesh 2x4] \
        [--scale reduced] [--requests 8]
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.config import SHAPES, get_config, reduced_config
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.runtime.serve_loop import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--scale", default="reduced",
                    choices=["full", "reduced"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = reduced_config(cfg)
    if cfg.enc_layers:
        raise SystemExit("enc-dec serving needs encoder inputs; use the "
                         "encdec decode path in tests/examples")

    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, axes)
    else:
        mesh = make_production_mesh()

    api = get_model(cfg)
    with mesh:
        params = api.init(jax.random.key(0))
        eng = ServeEngine(api, params, batch_slots=args.slots,
                          max_seq=args.max_seq)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab,
                                            rng.integers(2, 8)).tolist(),
                        max_new=args.max_new)
                for i in range(args.requests)]
        for r in reqs:
            eng.submit(r)
        eng.run()
    done = sum(r.done for r in reqs)
    print(f"[serve] completed {done}/{len(reqs)} requests, "
          f"{sum(len(r.out) for r in reqs)} tokens generated")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
