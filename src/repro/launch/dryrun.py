import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell on
the production mesh built from 512 placeholder host devices, and record
memory/cost/collective analysis for the roofline.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

The XLA_FLAGS assignment above MUST stay before any other import (jax
locks the device count at first init)."""

import argparse
import json
import time
import traceback
from typing import Dict

import jax

from repro.compat import cost_analysis_dict
from repro.config import SHAPES, get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (build_decode_step, build_prefill_step,
                                build_train_step)
# the HLO text parsing lives in core/hlo_ir.py (shared with HloLint,
# ``core/hlo_verify.py``) — re-exported here because the dryrun is the
# historical home of the collective byte pricing and its tests
from repro.core.hlo_ir import (collective_bytes, computation_multipliers,
                               split_computations)

_split_computations = split_computations
_computation_multipliers = computation_multipliers

__all__ = ["collective_bytes", "run_cell", "main"]


class CellTimeout(Exception):
    pass


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             timeout_s: int = 1500) -> Dict:
    import signal

    def _alarm(signum, frame):
        raise CellTimeout(f"{arch}×{shape_name} exceeded {timeout_s}s")

    signal.signal(signal.SIGALRM, _alarm)
    signal.alarm(timeout_s)
    try:
        return _run_cell(arch, shape_name, multi_pod)
    finally:
        signal.alarm(0)


def _make_mesh(multi_pod: bool):
    """Production mesh, or REPRO_DRYRUN_MESH="4x4" override for CI smoke
    runs on a small host-device count."""
    override = os.environ.get("REPRO_DRYRUN_MESH")
    if override:
        dims = tuple(int(d) for d in override.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=multi_pod)


def _run_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports_shape(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped", "reason": why}

    mesh = _make_mesh(multi_pod)
    builders = {"train": build_train_step, "prefill": build_prefill_step,
                "decode": build_decode_step}
    t0 = time.time()
    fn, arg_shapes, in_sh, out_sh = builders[shape.mode](cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "ndev": mesh.devices.size,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else -1.0,
        "collective_bytes": coll,
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "peak_memory_in_bytes",
                      "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
    }
    hbm = (result["memory"].get("argument_size_in_bytes", 0)
           + result["memory"].get("temp_size_in_bytes", 0))
    result["fits_16gb"] = bool(hbm < 16 * (1 << 30))
    print(f"[dryrun] {arch} × {shape_name} × "
          f"{'512(2pod)' if multi_pod else '256'}: OK "
          f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
          f"flops={result['flops']:.3e})", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_configs():
            for s in SHAPES:
                cells.append((a, s, args.multi_pod))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}

    for arch, shape, mp in cells:
        if (arch, shape, mp) in done:
            continue
        try:
            r = run_cell(arch, shape, mp)
        except Exception as e:
            traceback.print_exc()
            r = {"arch": arch, "shape": shape, "multi_pod": mp,
                 "status": "error", "error": repr(e)}
        results.append(r)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
