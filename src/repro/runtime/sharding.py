"""Sharding rules: 2-D FSDP×TP over mesh axes (data, model), with the
optional leading pod axis folded into the data (FSDP) dimension.

Every parameter is fully sharded over *both* axes (ZeRO-3-style: weights
FSDP-sharded on one dim, tensor-parallel on the other) — required for the
314B/398B archs to fit 16 GB chips on a 256-chip pod. Optimizer states
inherit param specs. Activations: batch→data, and (train/prefill)
sequence→model between blocks (Megatron-style sequence sharding keeps the
remat-saved residuals 1/16th size); attention/ffn internals re-shard to
heads/ffn TP automatically via GSPMD propagation from the weight specs.

Dims that don't divide the axis size fall back to replication — this is
what makes the *same* rules work for 14-head internvl2 and 64-head qwen3.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")   # folded FSDP axes when the pod axis exists


def _axes_of(mesh: Mesh) -> Tuple[Any, str]:
    names = mesh.axis_names
    if "pod" in names:
        return (("pod", "data"), "model")
    return ("data", "model")


def _size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    return axis is not None and dim % _size(mesh, axis) == 0


def _spec2d(mesh: Mesh, d0: int, d1: int, a0, a1) -> P:
    """Shard (d0, d1) over (a0, a1) with divisibility fallback."""
    s0 = a0 if _fits(d0, mesh, a0) else None
    s1 = a1 if _fits(d1, mesh, a1) else None
    return P(s0, s1)


_OUT_PARALLEL = ("wq", "wk", "wv", "up", "gate", "ogate", "wx", "in_proj",
                 "unembed")
_IN_PARALLEL = ("wo", "down", "out_proj")


def _leaf_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    dta, mdl = _axes_of(mesh)
    nd = len(shape)

    def pad(spec: P) -> P:
        return P(*([None] * (nd - len(spec)) + list(spec)))

    if nd <= 1:
        return P(*([None] * nd))
    t0, t1 = shape[-2], shape[-1]
    if "w_up" in path or "w_gate" in path:      # (E, D, F)
        E = shape[-3]
        if _fits(E, mesh, mdl):                 # expert parallel
            return pad(P(*([None] * (nd - 3)), mdl,
                          dta if _fits(t0, mesh, dta) else None, None))
        return pad(_spec2d(mesh, t0, t1, dta, mdl))
    if "w_down" in path:                        # (E, F, D)
        E = shape[-3]
        if _fits(E, mesh, mdl):
            return pad(P(*([None] * (nd - 3)), mdl, None,
                          dta if _fits(t1, mesh, dta) else None))
        return pad(_spec2d(mesh, t0, t1, mdl, dta))
    if "embed" in path and "unembed" not in path:   # (V, D)
        return pad(_spec2d(mesh, t0, t1, mdl, dta))
    if "router" in path:                        # (D, E)
        return pad(_spec2d(mesh, t0, t1, dta, None))
    if "x_proj" in path:                        # (di, 2ds+1)
        return pad(_spec2d(mesh, t0, t1, mdl, None))
    if "A_log" in path:
        return pad(_spec2d(mesh, t0, t1, mdl, None))
    if "conv_w" in path:                        # (dc, di)
        return pad(_spec2d(mesh, t0, t1, None, mdl))
    if "wr" in path:                            # (h, hd, 4hd)
        return pad(_spec2d(mesh, t0, t1, None, mdl))
    if any(k in path for k in _IN_PARALLEL):    # (F, D)
        return pad(_spec2d(mesh, t0, t1, mdl, dta))
    if any(k in path for k in _OUT_PARALLEL):   # (D, F)
        return pad(_spec2d(mesh, t0, t1, dta, mdl))
    return pad(_spec2d(mesh, t0, t1, dta, mdl))


def param_specs(shapes_tree, mesh: Mesh):
    """PartitionSpec tree matching a params (or optimizer-state) tree of
    ShapeDtypeStructs/arrays."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
    specs = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        specs.append(_leaf_spec(path, tuple(leaf.shape), mesh))
    return treedef.unflatten(specs)


def named(tree_specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


# -- batch / cache ------------------------------------------------------------

def batch_specs(batch_shapes: Dict, mesh: Mesh) -> Dict:
    dta, mdl = _axes_of(mesh)
    out = {}
    for k, v in batch_shapes.items():
        b = v.shape[0]
        s0 = dta if _fits(b, mesh, dta) else (
            "data" if _fits(b, mesh, "data") else None)
        if len(v.shape) >= 2 and v.shape[1] % _size(mesh, mdl) == 0 and \
                v.shape[1] > 1:
            out[k] = P(*([s0, mdl] + [None] * (len(v.shape) - 2)))
        else:
            out[k] = P(*([s0] + [None] * (len(v.shape) - 1)))
    return out


def cache_pspec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Decode-cache sharding: leading stack axis unsharded, batch→data,
    longest remaining (sequence/state) dim→model if divisible."""
    dta, mdl = _axes_of(mesh)
    spec = [None] * len(shape)
    if len(shape) >= 2:
        b = shape[1]
        if _fits(b, mesh, dta):
            spec[1] = dta
        elif _fits(b, mesh, "data"):
            spec[1] = "data"
    if len(shape) >= 3:
        # shard the largest trailing dim over model (KV seq, d_inner, …)
        rest = list(range(2, len(shape)))
        best = max(rest, key=lambda i: shape[i])
        if _fits(shape[best], mesh, mdl):
            spec[best] = mdl
    return P(*spec)


# -- activation constraint policy ---------------------------------------------

def act_policy(mesh: Mesh):
    dta, mdl = _axes_of(mesh)
    info = {"data_groups": _size(mesh, dta), "model_size": _size(mesh, mdl)}

    def policy(name: str, x) -> Optional[P]:
        shape = x.shape
        if name == "moe_dispatch" and len(shape) == 4:
            # (G, E, C, D): groups->data; experts->model when divisible
            G, E = shape[0], shape[1]
            sg = dta if _fits(G, mesh, dta) else (
                "data" if _fits(G, mesh, "data") else None)
            se = mdl if _fits(E, mesh, mdl) else None
            return P(sg, se, None, None)
        if name == "moe_ffn_act" and len(shape) == 4:
            # (G, E, C, F): experts->model, else ffn->model
            G, E, _, F = shape
            sg = dta if _fits(G, mesh, dta) else (
                "data" if _fits(G, mesh, "data") else None)
            if _fits(E, mesh, mdl):
                return P(sg, mdl, None, None)
            return P(sg, None, None, mdl if _fits(F, mesh, mdl) else None)
        if name == "attn_chunked_q" and len(shape) == 6:
            # (nq, B, H, G, qc, hd): batch->data, heads->model
            _, B, H = shape[:3]
            sb = dta if _fits(B, mesh, dta) else (
                "data" if _fits(B, mesh, "data") else None)
            sh = mdl if _fits(H, mesh, mdl) else None
            return P(None, sb, sh, None, None, None)
        if name == "attn_kv_full" and len(shape) == 4:
            # (B, S, KV, hd): batch->data, heads replicated (pre-repeat)
            B = shape[0]
            sb = dta if _fits(B, mesh, dta) else (
                "data" if _fits(B, mesh, "data") else None)
            return P(sb, None, None, None)
        if name == "attn_chunked_kv" and len(shape) == 5:
            _, B, H = shape[:3]
            sb = dta if _fits(B, mesh, dta) else (
                "data" if _fits(B, mesh, "data") else None)
            sh = mdl if _fits(H, mesh, mdl) else None
            return P(None, sb, sh, None, None)
        if name == "hidden" and len(shape) == 3:
            B, S, D = shape
            sb = dta if _fits(B, mesh, dta) else (
                "data" if _fits(B, mesh, "data") else None)
            ss = mdl if (S > 1 and _fits(S, mesh, mdl)) else None
            return P(sb, ss, None)
        if name == "pre_logits" and len(shape) == 3:
            B = shape[0]
            sb = dta if _fits(B, mesh, dta) else (
                "data" if _fits(B, mesh, "data") else None)
            return P(sb, None, None)
        if name == "logits":
            V = shape[-1]
            sv = mdl if _fits(V, mesh, mdl) else None
            B = shape[0]
            sb = dta if _fits(B, mesh, dta) else (
                "data" if _fits(B, mesh, "data") else None)
            return P(*([sb] + [None] * (len(shape) - 2) + [sv]))
        return None

    policy.info = info
    return policy
