from .sharding import (param_specs, batch_specs, cache_pspec, act_policy,
                       DATA_AXES)

__all__ = ["param_specs", "batch_specs", "cache_pspec", "act_policy",
           "DATA_AXES"]
