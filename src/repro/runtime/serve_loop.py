"""Batched serving loop: continuous batching over a request queue.

Slots hold independent requests; every engine step decodes one token for
every active slot (the whole batch shares one jitted decode_step). Free
slots are refilled from the queue each step — the standard continuous-
batching pattern, with per-slot positions so requests of different
lengths coexist in one KV cache."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, api, params, batch_slots: int, max_seq: int,
                 greedy: bool = True):
        self.api = api
        self.params = params
        self.B = batch_slots
        self.S = max_seq
        self.cache = api.init_cache(batch_slots, max_seq)
        self.pos = np.zeros(batch_slots, np.int32)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self.last_token = np.zeros(batch_slots, np.int32)
        self._step = jax.jit(api.decode_step)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # feed the prompt token by token (prefill-as-decode; a
                # production engine would run a fused prefill here)
                self.pos[i] = 0
                req._feed = list(req.prompt)
                self.last_token[i] = req._feed.pop(0)

    def step(self):
        """One engine iteration: decode one token for every active slot."""
        self._fill_slots()
        if all(s is None for s in self.slots):
            return False
        logits, self.cache = self._step(
            self.params, jnp.asarray(self.last_token),
            jnp.asarray(self.pos), self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            if getattr(req, "_feed", None):
                self.last_token[i] = req._feed.pop(0)   # still prefilling
                continue
            req.out.append(int(nxt[i]))
            self.last_token[i] = nxt[i]
            if len(req.out) >= req.max_new or self.pos[i] >= self.S - 1:
                req.done = True
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                break
