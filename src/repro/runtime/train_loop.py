"""Fault-tolerant training loop.

Scale features (designed for 1000+ nodes, exercised here on host devices):

* checkpoint/restart — async sharded checkpoints every ``ckpt_every``
  steps; ``resume=True`` picks up the latest COMMITTED step after a crash
  (data pipeline is counter-based, so resume is exact).
* failure handling — a step that dies with a device/runtime error is
  retried from the last checkpoint up to ``max_restarts`` times (the
  in-process analogue of a coordinator restarting a failed slice).
* straggler mitigation — per-step wall-time EWMA; steps slower than
  ``straggler_factor``× the EWMA are logged and counted. On a real
  cluster this signal feeds the scheduler; here it feeds metrics and the
  EXPERIMENTS log.
* elastic restore — checkpoints are global arrays; restoring onto a
  different mesh re-shards on load (see CheckpointManager.restore).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import numpy as np

import jax

from repro.checkpoint import CheckpointManager

__all__ = ["TrainLoopConfig", "run_train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    max_restarts: int = 3
    straggler_factor: float = 2.0
    resume: bool = True


def run_train_loop(step_fn: Callable, params, opt_state,
                   batches: Iterable, loop_cfg: TrainLoopConfig,
                   to_device: Callable = lambda b: b,
                   log: Callable = print) -> Dict[str, Any]:
    """Drive ``step_fn(params, opt_state, batch, step) -> (params,
    opt_state, loss, metrics)`` with checkpoint/restart + straggler
    accounting. Returns final state + run metrics."""
    mgr = CheckpointManager(loop_cfg.ckpt_dir)
    start = 0
    if loop_cfg.resume:
        latest = mgr.latest_step()
        if latest is not None:
            params, opt_state = mgr.restore(latest, (params, opt_state))
            start = latest
            log(f"[train] resumed from step {latest}")

    ewma = None
    stragglers = 0
    restarts = 0
    losses = []
    it = iter(batches)
    # fast-forward the deterministic pipeline on resume
    for _ in range(start):
        next(it)

    step = start
    while step < loop_cfg.total_steps:
        batch = to_device(next(it))
        t0 = time.time()
        try:
            params, opt_state, loss, metrics = step_fn(
                params, opt_state, batch, step)
            loss = float(loss)
        except (jax.errors.JaxRuntimeError, RuntimeError) as e:
            restarts += 1
            if restarts > loop_cfg.max_restarts:
                raise
            latest = mgr.latest_step()
            log(f"[train] step {step} failed ({e!r}); restart #{restarts} "
                f"from checkpoint {latest}")
            if latest is not None:
                params, opt_state = mgr.restore(latest, (params, opt_state))
                step = latest
                it = iter(batches)
                for _ in range(step):
                    next(it)
            continue

        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > loop_cfg.straggler_factor * ewma and step > start + 3:
            stragglers += 1
            log(f"[train] straggler step {step}: {dt:.2f}s vs EWMA "
                f"{ewma:.2f}s")
        losses.append(loss)
        step += 1
        if step % loop_cfg.log_every == 0:
            log(f"[train] step {step} loss {loss:.4f} "
                f"({dt * 1e3:.0f} ms/step)")
        if step % loop_cfg.ckpt_every == 0 or step == loop_cfg.total_steps:
            mgr.save(step, (params, opt_state))

    mgr.wait()
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "stragglers": stragglers, "restarts": restarts,
            "final_step": step}
