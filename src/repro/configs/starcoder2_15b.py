"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE [arXiv:2402.19173; hf]."""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, head_dim=128,
    rope=True, rope_theta=1e5, act="gelu",
))
