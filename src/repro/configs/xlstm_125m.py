"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304, sLSTM +
mLSTM blocks [arXiv:2405.04517; unverified]. Pattern m,m,s repeating
(mLSTM-dominant with periodic sLSTM, xLSTM[7:1]-style mix); block-internal
projections replace the FFN (d_ff=0)."""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=192,
    rope=False, xlstm_pattern=("m", "m", "s"), layer_group=3,
    tie_embeddings=True,
))
