"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655, InternViT + InternLM2 backbone [arXiv:2404.16821; hf].
The InternViT-300M vision tower is a STUB per assignment: input_specs()
provides 256 precomputed patch embeddings per image, prepended to the
text sequence."""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, head_dim=64,
    rope=True, rope_theta=1e6, frontend="vision", n_frontend_tokens=256,
    tie_embeddings=True,
))
