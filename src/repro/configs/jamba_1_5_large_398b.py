"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba+attention 1:7 interleave
[arXiv:2403.19887; hf]. Period-8 layer groups (1 attention + 7 Mamba),
MoE FFN on every other layer. bf16 params+opt states to fit 16 GB chips."""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    n_experts=16, top_k=2, moe_every=2,
    attn_every=8, layer_group=8,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope=False, param_dtype="bfloat16",
))
