"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm + GQA [hf:Qwen/Qwen3-8B; hf]. Qwen3 uses an
explicit head_dim=128 (n_heads*head_dim != d_model)."""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936, head_dim=128,
    qk_norm=True, rope=True, rope_theta=1e6,
    # §Perf iter 7: bf16 params+opt states (f32 update math) — f32
    # storage put train_4k 2% over the 16 GB budget
    param_dtype="bfloat16",
))
