"""seamless-m4t-large-v2 [audio] — enc-dec, 24L each side, d_model=1024
16H (kv=16) d_ff=8192 vocab=256206 [arXiv:2308.11596; hf]. The speech
frontend (w2v-BERT conformer feature extractor) is a STUB per assignment:
input_specs() provides precomputed frame embeddings."""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    rope=False, frontend="audio", n_frontend_tokens=0,  # = seq_len frames
    act="gelu",
))
