"""Assigned architecture configs (importing this package registers all)."""
from . import (internlm2_20b, starcoder2_15b, granite_3_2b, qwen3_32b,
               grok1_314b, dbrx_132b, seamless_m4t_large_v2, xlstm_125m,
               internvl2_1b, jamba_1_5_large_398b)

ALL_ARCHS = (
    "internlm2-20b", "starcoder2-15b", "granite-3-2b", "qwen3-32b",
    "grok-1-314b", "dbrx-132b", "seamless-m4t-large-v2", "xlstm-125m",
    "internvl2-1b", "jamba-1.5-large-398b",
)
