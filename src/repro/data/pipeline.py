"""Deterministic synthetic token pipeline.

Sequences are Zipf-ish ngram-correlated token streams, generated
per-(step, shard) from a counter-based RNG: any host can regenerate any
shard of any step independently — which is exactly what elastic restarts
and straggler re-dispatch need (no data state in checkpoints beyond the
step counter). Double-buffered prefetch keeps the host ahead of device
steps on real hardware.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0
    prefetch: int = 2

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Regenerable batch for a global step (host-independent)."""
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        # correlated stream: random walk over vocab with Zipf jumps
        base = rng.zipf(1.4, size=(B, S)).astype(np.int64)
        tokens = (np.cumsum(base, axis=1) % (self.vocab - 1)) + 1
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = 0
        mask = np.ones((B, S), np.float32)
        mask[:, -1] = 0.0
        out = {"tokens": tokens.astype(np.int32),
               "labels": labels.astype(np.int32),
               "loss_mask": mask}
        if self.frontend_tokens and self.d_model:
            out["frontend"] = rng.standard_normal(
                (B, self.frontend_tokens, self.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            step = 0
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_batch_specs(cfg, shape, dtype=jnp.int32):
    """ShapeDtypeStruct stand-ins for one training batch (dry-run)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    if cfg.frontend == "vision":
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio" or cfg.enc_layers:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.float32)
    return specs
