from .pipeline import SyntheticTokens, make_batch_specs

__all__ = ["SyntheticTokens", "make_batch_specs"]
