"""Synthetic mixed-structure traffic for the serving layer.

The acceptance harness behind ``tools/serve_bench.py``: a seeded
Poisson request stream over ≥2 distinct block structures, served
through :class:`~.server.SelInvServer`, then checked three ways —

- **throughput**: per-matrix wall time of coalesced serving vs the
  sequential baseline (``engine.solve`` per request, the exact same
  matrices) on warm programs;
- **compile conformance**: after the cold pass, every structure's
  ``trace_count`` equals the number of distinct batch buckets it
  served — exactly one compile per (structure, bucket), asserted off
  the engine trace counters before any single-matrix solve runs;
- **identity**: every served result equals its unbatched
  ``engine.solve`` to ≤``tol`` in f64 (run under
  ``JAX_ENABLE_X64=1`` for this to mean anything).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from ..core import sparse
from ..core.engine import (Grid, PlanOptions, PSelInvEngine,
                           bucket_size, stack_values)
from .batcher import BatchWindow
from .metrics import ServeMetrics
from .server import SelInvServer, ServeConfig

__all__ = ["mixed_structures", "make_trace", "run_traffic"]

#: 2-D Laplacian grid widths giving distinct block structures at b=8
_NX = (12, 16, 20, 24, 28, 32)


def mixed_structures(n_structures: int = 2, b: int = 8) -> List:
    """``n_structures`` distinct-sparsity base matrices (2-D Laplacians
    of growing width; each symbolic-factorizes to its own structure
    sha1 at supernode width ``b``)."""
    if not 1 <= n_structures <= len(_NX):
        raise ValueError(f"n_structures must be in [1, {len(_NX)}], "
                         f"got {n_structures}")
    return [sparse.laplacian_2d(nx, b) for nx in _NX[:n_structures]]


@dataclass(frozen=True)
class TraceItem:
    """One request of the synthetic stream: arrives ``gap_s`` after the
    previous one, targets structure ``sidx``, with values shifted by
    ``shift`` (A + shift·I — same pattern, fresh numbers)."""
    gap_s: float
    sidx: int
    shift: float


def make_trace(n_requests: int, n_structures: int,
               rate_hz: Optional[float], seed: int) -> List[TraceItem]:
    """A seeded Poisson stream: exponential inter-arrivals at
    ``rate_hz`` (``None`` → a burst, zero gaps), uniform structure
    choice, uniform value shifts."""
    rng = np.random.default_rng(seed)
    gaps = (rng.exponential(1.0 / rate_hz, n_requests)
            if rate_hz else np.zeros(n_requests))
    sidx = rng.integers(0, n_structures, n_requests)
    shifts = rng.uniform(0.1, 2.0, n_requests)
    return [TraceItem(float(g), int(s), float(c))
            for g, s, c in zip(gaps, sidx, shifts)]


def _materialize(trace: Sequence[TraceItem], bases: Sequence) -> List:
    import scipy.sparse as sp
    eye = [sp.identity(B.shape[0], format="csr") for B in bases]
    return [bases[t.sidx] + t.shift * eye[t.sidx] for t in trace]


def _serve_pass(server: SelInvServer, trace: Sequence[TraceItem],
                mats: Sequence, *, realtime: bool,
                timeout_s: float = 300.0) -> Tuple[float, List]:
    """Submit the whole trace (sleeping out the Poisson gaps when
    ``realtime``), drain, and return (wall seconds, per-request
    results in submit order)."""
    t0 = time.perf_counter()
    reqs = []
    for item, M in zip(trace, mats):
        if realtime and item.gap_s:
            time.sleep(item.gap_s)
        reqs.append(server.submit(M))
    server.drain(timeout=timeout_s)
    outs = [np.asarray(r.result(timeout=timeout_s)) for r in reqs]
    return time.perf_counter() - t0, outs


def run_traffic(n_requests: int = 120, n_structures: int = 2,
                rate_hz: Optional[float] = 4000.0, seed: int = 0, *,
                b: int = 8, grid: Grid = Grid(1, 1),
                options: PlanOptions = PlanOptions(),
                window: BatchWindow = BatchWindow(),
                dtype=jnp.float64, background: bool = True,
                check_identity: bool = True, tol: float = 1e-12,
                reps: int = 1, log=lambda s: None) -> Dict:
    """The full serve-bench: cold pass (compiles) → compile-conformance
    assert → warm timed pass → warm sequential baseline over the same
    matrices → identity check. Returns one flat dict of everything a
    bench row needs.

    ``reps`` repeats each *timed* pass (warm serve and sequential
    baseline) and keeps the best wall of each — same rationale as
    ``timed(best=True)`` in benchmarks/common.py: with simulated
    devices sharing the host, one descheduled pass would otherwise
    decide an asserted ratio."""
    if n_structures < 2:
        raise ValueError("the mixed-structure bench needs >= 2 "
                         "structures")
    bases = mixed_structures(n_structures, b)
    trace = make_trace(n_requests, n_structures, rate_hz, seed)
    mats = _materialize(trace, bases)

    PSelInvEngine.clear_cache()
    cfg = ServeConfig(b=b, grid=grid, options=options, window=window,
                      max_queue=max(256, 2 * n_requests), dtype=dtype)
    server = SelInvServer(cfg)
    if background:
        server.start()
    try:
        # ---- cold pass: burst the trace through once so every
        # (structure, bucket) the stream exercises gets its one compile
        log(f"cold pass: {n_requests} requests, {n_structures} "
            f"structures")
        _serve_pass(server, trace, mats, realtime=False)

        # ---- compile conformance, straight off the trace counters —
        # before any single-matrix solve adds its rank-5 trace
        st = server.stats()
        conformance = {k: (v["trace_count"], len(v["buckets_used"]))
                       for k, v in st["structures"].items()}
        for k, (traces, buckets) in conformance.items():
            assert traces == buckets, (
                f"structure {k}: {traces} compiles for {buckets} "
                f"buckets — expected exactly one per (structure, "
                f"bucket)")

        # ---- pre-warm every power-of-2 bucket the warm pass could
        # coalesce into (arrival timing decides the bucket census, so
        # the timed replay must never pay a stray compile)
        engines = [PSelInvEngine.analyze(B, b=b, grid=grid,
                                         options=options)
                   for B in bases]           # cache hits: the server's
        for eng, base in zip(engines, bases):
            v = eng.prepare_values(base)
            bkt = 1
            while bkt <= window.max_batch:
                np.asarray(eng.solve(stack_values([v] * bkt),
                                     dtype=dtype))
                bkt *= 2
            if bucket_size(window.max_batch) != window.max_batch:
                np.asarray(eng.solve(
                    stack_values([v] * bucket_size(window.max_batch)),
                    dtype=dtype))

        # ---- warm timed pass (same matrices, fresh metrics so the
        # percentiles reflect warm serving only); best-of-``reps``
        serve_wall, served, snap = None, None, None
        for rep in range(max(1, reps)):
            log(f"warm serve pass (timed, rep {rep + 1}/{reps})")
            server.metrics = ServeMetrics()
            wall, outs = _serve_pass(server, trace, mats,
                                     realtime=bool(rate_hz))
            if serve_wall is None or wall < serve_wall:
                serve_wall, served, snap = wall, outs, server.stats()
    finally:
        if background:
            server.stop()

    # ---- warm sequential baseline: the exact same matrices, one
    # full-path engine.solve each (host factorization + sweep)
    for eng, B in zip(engines, bases):       # pay the rank-5 compile
        np.asarray(eng.solve(B, dtype=dtype))
    base_wall, base_outs = None, None
    for rep in range(max(1, reps)):
        log(f"sequential baseline (timed, rep {rep + 1}/{reps})")
        t0 = time.perf_counter()
        outs = [np.asarray(engines[t.sidx].solve(M, dtype=dtype))
                for t, M in zip(trace, mats)]
        wall = time.perf_counter() - t0
        if base_wall is None or wall < base_wall:
            base_wall, base_outs = wall, outs

    identity_max = None
    if check_identity:
        identity_max = float(max(
            abs(o - bo).max() for o, bo in zip(served, base_outs)))
        assert identity_max <= tol, (
            f"served results deviate from unbatched solves by "
            f"{identity_max:g} > {tol:g}")

    return {
        "n_requests": n_requests,
        "n_structures": n_structures,
        "rate_hz": rate_hz,
        "serve_wall_s": serve_wall,
        "baseline_wall_s": base_wall,
        "speedup": base_wall / serve_wall,
        "serve_per_matrix_us": serve_wall / n_requests * 1e6,
        "baseline_per_matrix_us": base_wall / n_requests * 1e6,
        "serve_throughput_rps": n_requests / serve_wall,
        "serve_p50_us": snap["latency_p50_us"],
        "serve_p95_us": snap["latency_p95_us"],
        "serve_p99_us": snap["latency_p99_us"],
        "serve_batch_occupancy": snap["batch_occupancy_mean"],
        "batches": snap["batches"],
        "identity_max_abs": identity_max,
        "conformance": conformance,
        "stats": snap,
    }
