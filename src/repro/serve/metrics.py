"""Serving observability: latency percentiles, batch occupancy, queue
and rejection counters.

One :class:`ServeMetrics` instance per server, updated from the submit
path and the batch worker, read via :meth:`ServeMetrics.snapshot`
(exported through ``server.stats()`` and recorded by
``benchmarks/pselinv_bench.py``). Everything is guarded by one lock —
the counters are tiny and the snapshot is O(completed requests) for the
percentile sort, which a serving loop calls rarely.
"""
from __future__ import annotations

import threading
from collections import Counter
from typing import Dict, List

import numpy as np

__all__ = ["ServeMetrics"]

#: counter names every snapshot reports, even when still zero
COUNTERS = ("submitted", "solved", "failed", "timed_out", "rejected",
            "batches")


class ServeMetrics:
    """Thread-safe serving counters + reservoirs.

    - request lifecycle counters (``submitted``/``solved``/``failed``/
      ``timed_out``/``rejected``) and ``batches`` served;
    - per-request latency (submit → completion) reservoir, reported as
      p50/p95/p99 microseconds;
    - batch-occupancy histogram: per served batch, the real batch size
      and the padded power-of-2 bucket it rode — occupancy is
      real/bucket, the fraction of compiled lanes doing real work;
    - queue-depth gauge (current and high-water).
    """

    def __init__(self, max_latencies: int = 100_000):
        self._lock = threading.Lock()
        self._counts = Counter()
        self._lat_s: List[float] = []
        self._max_lat = max_latencies
        self._batch_real = Counter()     # real batch size -> count
        self._batch_bucket = Counter()   # padded bucket -> count
        self._occupancy: List[float] = []
        self.queue_depth = 0
        self.queue_depth_max = 0

    # ---- writers ------------------------------------------------------
    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self._counts[name] += by

    def observe_latency(self, seconds: float) -> None:
        with self._lock:
            if len(self._lat_s) < self._max_lat:
                self._lat_s.append(seconds)

    def observe_batch(self, real: int, bucket: int) -> None:
        with self._lock:
            self._counts["batches"] += 1
            self._batch_real[int(real)] += 1
            self._batch_bucket[int(bucket)] += 1
            self._occupancy.append(real / bucket if bucket else 0.0)

    def set_queue_depth(self, depth: int) -> None:
        with self._lock:
            self.queue_depth = depth
            self.queue_depth_max = max(self.queue_depth_max, depth)

    # ---- readers ------------------------------------------------------
    def snapshot(self) -> Dict:
        """One coherent dict of everything above; percentile keys are
        microseconds (``None`` before the first completion)."""
        with self._lock:
            lat = np.asarray(self._lat_s, dtype=np.float64)
            occ = np.asarray(self._occupancy, dtype=np.float64)
            out: Dict = {name: int(self._counts[name])
                         for name in COUNTERS}
            for name, count in self._counts.items():
                out.setdefault(name, int(count))
            if lat.size:
                p50, p95, p99 = np.percentile(lat, (50, 95, 99))
                out.update(latency_p50_us=float(p50 * 1e6),
                           latency_p95_us=float(p95 * 1e6),
                           latency_p99_us=float(p99 * 1e6),
                           latency_mean_us=float(lat.mean() * 1e6))
            else:
                out.update(latency_p50_us=None, latency_p95_us=None,
                           latency_p99_us=None, latency_mean_us=None)
            out["batch_occupancy_mean"] = (float(occ.mean())
                                           if occ.size else None)
            out["batch_size_hist"] = dict(sorted(self._batch_real.items()))
            out["batch_bucket_hist"] = dict(
                sorted(self._batch_bucket.items()))
            out["queue_depth"] = self.queue_depth
            out["queue_depth_max"] = self.queue_depth_max
            return out
