"""Serving observability: latency percentiles, batch occupancy, queue
and rejection counters — thin wrappers over the unified metrics
registry (``repro.obs.registry``).

One :class:`ServeMetrics` instance per server, updated from the submit
path and the batch worker, read via :meth:`ServeMetrics.snapshot`
(exported through ``server.stats()`` and recorded by
``benchmarks/pselinv_bench.py``). The hand-rolled latency reservoir and
occupancy list this module used to carry are gone: both percentile
paths now ride the registry's one :class:`~repro.obs.registry.Histogram`
implementation (same bounded keep-the-head reservoir, same
``np.percentile``), and every counter/gauge is a registry metric — so a
server is scrape-able in prometheus text via ``metrics.registry``
while ``snapshot()`` keeps its historical dict shape byte-for-byte
(tested).

By default each ``ServeMetrics`` owns a private
:class:`~repro.obs.registry.MetricsRegistry` (two servers don't mix
counts); pass ``registry=repro.obs.registry.REGISTRY`` to publish into
the process-wide scrape surface alongside the engine gauges.
"""
from __future__ import annotations

from typing import Dict, Optional

from ..obs.registry import MetricsRegistry

__all__ = ["ServeMetrics"]

#: counter names every snapshot reports, even when still zero
COUNTERS = ("submitted", "solved", "failed", "timed_out", "rejected",
            "batches")


class ServeMetrics:
    """Thread-safe serving counters + histograms over the registry.

    - request lifecycle counters (``submitted``/``solved``/``failed``/
      ``timed_out``/``rejected``) and ``batches`` served;
    - per-request latency (submit → completion) histogram, reported as
      p50/p95/p99 microseconds;
    - batch-occupancy histogram: per served batch, the real batch size
      and the padded power-of-2 bucket it rode — occupancy is
      real/bucket, the fraction of compiled lanes doing real work;
    - flush-cause counter: which window-policy leg released each batch
      (``full``/``window``/``pressure``/``force``);
    - queue-depth gauge (current and high-water).
    """

    def __init__(self, max_latencies: int = 100_000,
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        r = self.registry
        self._events = r.counter(
            "selinv_serve_events_total",
            "request lifecycle events by name", labelnames=("name",))
        self._latency = r.histogram(
            "selinv_serve_latency_seconds",
            "submit-to-completion request latency",
            max_samples=max_latencies)
        self._occupancy = r.histogram(
            "selinv_serve_batch_occupancy",
            "real batch size / padded bucket per served batch")
        self._batch_real = r.counter(
            "selinv_serve_batch_size_total",
            "served batches by real size", labelnames=("size",))
        self._batch_bucket = r.counter(
            "selinv_serve_batch_bucket_total",
            "served batches by padded bucket", labelnames=("bucket",))
        self._flush_cause = r.counter(
            "selinv_serve_batch_flush_total",
            "served batches by window flush cause",
            labelnames=("cause",))
        self._depth = r.gauge("selinv_serve_queue_depth",
                              "requests currently queued")
        self._depth_max = r.gauge("selinv_serve_queue_depth_max",
                                  "high-water queued requests")

    # ---- writers ------------------------------------------------------
    def inc(self, name: str, by: int = 1) -> None:
        self._events.labels(name).inc(by)

    def observe_latency(self, seconds: float) -> None:
        self._latency.observe(seconds)

    def observe_batch(self, real: int, bucket: int,
                      cause: Optional[str] = None) -> None:
        self._events.labels("batches").inc()
        self._batch_real.labels(int(real)).inc()
        self._batch_bucket.labels(int(bucket)).inc()
        self._occupancy.observe(real / bucket if bucket else 0.0)
        if cause is not None:
            self._flush_cause.labels(cause).inc()

    def set_queue_depth(self, depth: int) -> None:
        self._depth.set(depth)
        self._depth_max.max(depth)

    # ---- readers ------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return int(self._depth.value)

    @property
    def queue_depth_max(self) -> int:
        return int(self._depth_max.value)

    def flush_causes(self) -> Dict[str, int]:
        """Served-batch count per window flush cause."""
        return {k[0]: int(c.value) for k, c in
                self._flush_cause.children()}

    def snapshot(self) -> Dict:
        """One coherent dict of everything above; percentile keys are
        microseconds (``None`` before the first completion). The dict
        shape predates the registry and is frozen — serving dashboards
        and the bench parse it."""
        out: Dict = {name: 0 for name in COUNTERS}
        for key, child in self._events.children():
            out[key[0]] = int(child.value)
        ps = self._latency.percentile((50, 95, 99))
        if ps is not None:
            p50, p95, p99 = ps
            out.update(latency_p50_us=float(p50 * 1e6),
                       latency_p95_us=float(p95 * 1e6),
                       latency_p99_us=float(p99 * 1e6),
                       latency_mean_us=float(self._latency.mean * 1e6))
        else:
            out.update(latency_p50_us=None, latency_p95_us=None,
                       latency_p99_us=None, latency_mean_us=None)
        out["batch_occupancy_mean"] = self._occupancy.mean
        out["batch_size_hist"] = dict(sorted(
            (int(k[0]), int(c.value))
            for k, c in self._batch_real.children()))
        out["batch_bucket_hist"] = dict(sorted(
            (int(k[0]), int(c.value))
            for k, c in self._batch_bucket.children()))
        out["flush_causes"] = dict(sorted(self.flush_causes().items()))
        out["queue_depth"] = self.queue_depth
        out["queue_depth_max"] = self.queue_depth_max
        return out
