"""SelInvServer — the serving loop over :class:`PSelInvEngine`.

``submit(A)`` fingerprints the matrix's sparsity pattern (sha1 over the
CSR indptr/indices — cheap, no symbolic work on the hot path), maps it
to a warm engine (``PSelInvEngine.analyze`` runs once per new pattern
and after that every lookup is a dict hit), admission-checks the queue,
and hands back a :class:`~.batcher.SolveRequest` future. A worker —
either the background thread (``start()``/context manager) or the
caller via ``pump()``/``drain()`` — pops ready same-structure batches
from the :class:`~.batcher.StructureBatcher` and serves each one:

- per-request pattern check (``check_values_pattern``) so a request
  whose values escape its claimed structure fails *alone* — its batch
  neighbors still solve, bit-identical to their unbatched solves;
- batched host factorization (``prepare_values_many``) — the supernode
  loop runs once for the whole batch;
- one bucket-padded ``engine.solve`` call (odd batch lengths ride the
  power-of-2 programs), or the on-disk AOT program cache when
  configured;
- per-request result slicing + completion, latency and occupancy
  recorded in :class:`~.metrics.ServeMetrics`.

A failed batch marks only its own requests FAILED; the server and the
engine survive for the next window.
"""
from __future__ import annotations

import hashlib
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np

import jax.numpy as jnp

from ..core.engine import (Grid, PlanOptions, PSelInvEngine, SolveValues,
                           bucket_size, stack_values)
from ..core.pselinv_dist import check_values_pattern
from ..obs.trace import TRACER
from .batcher import (BatchWindow, RequestStatus, RequestTimedOut,
                      ServeError, ServerOverloaded, SolveRequest,
                      StructureBatcher)
from .metrics import ServeMetrics

__all__ = ["SelInvServer", "ServeConfig"]


def _pattern_fingerprint(A) -> str:
    """sha1 of the sparsity pattern (shape + CSR indptr/indices). Two
    matrices with one pattern share a fingerprint — and therefore a
    warm engine — without re-running symbolic analysis per request."""
    import scipy.sparse as sp
    C = sp.csr_matrix(A)
    h = hashlib.sha1()
    h.update(np.asarray(C.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(C.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(C.indices, dtype=np.int64).tobytes())
    return h.hexdigest()


@dataclass(frozen=True)
class ServeConfig:
    """Server knobs. ``b``/``grid``/``options`` are the engine session
    parameters every request is analyzed under; ``window`` is the
    dynamic batch window; ``max_queue`` the admission bound (requests
    beyond it are REJECTED, the paper's bound-the-absorbed-work lesson
    applied to the request queue); ``bucket`` pads batches to power-of-2
    buckets; ``batched_prep`` routes host factorization through the
    stacked pass; ``prog_cache`` (a
    :class:`~.progcache.ProgramDiskCache`) serves batches through
    persisted AOT executables instead of the engine's jitted sweep —
    off by default so ``engine.trace_count`` stays the compile-count
    ground truth."""
    b: int = 8
    grid: Grid = Grid(1, 1)
    options: PlanOptions = PlanOptions()
    window: BatchWindow = BatchWindow()
    max_queue: int = 256
    dtype: object = jnp.float32
    bucket: bool = True
    batched_prep: bool = True
    default_timeout_ms: Optional[float] = None
    prog_cache: Optional[object] = None


class SelInvServer:
    """Structure-keyed request coalescing + batched serving."""

    def __init__(self, config: ServeConfig = ServeConfig()):
        self.cfg = config
        self.metrics = ServeMetrics()
        self._batcher = StructureBatcher(config.window)
        self._cond = threading.Condition()
        self._engines: "OrderedDict[str, PSelInvEngine]" = OrderedDict()
        self._fp2skey: Dict[str, str] = {}
        self._buckets_used: Dict[str, Set[int]] = {}
        self._thread: Optional[threading.Thread] = None
        self._running = False
        # bounded lifecycle history for the Chrome-trace exporter
        self._history: "deque[SolveRequest]" = deque(maxlen=4096)

    # ---- engine lookup ------------------------------------------------
    def engine_for(self, A) -> PSelInvEngine:
        """The warm engine for A's sparsity pattern. First sight of a
        pattern runs symbolic analysis + compile via
        ``PSelInvEngine.analyze`` (itself structure-cached); every
        later submit of the pattern is a fingerprint dict hit."""
        fp = _pattern_fingerprint(A)
        skey = self._fp2skey.get(fp)
        if skey is not None:
            eng = self._engines.get(skey)
            if eng is not None:
                return eng
        eng = PSelInvEngine.analyze(A, b=self.cfg.b, grid=self.cfg.grid,
                                    options=self.cfg.options)
        skey = eng.key[0]
        self._fp2skey[fp] = skey
        self._engines[skey] = eng
        return eng

    # ---- submission ---------------------------------------------------
    def _admit(self, req: SolveRequest) -> SolveRequest:
        with TRACER.span("serve.admission", rid=req.rid,
                         skey=req.skey[:12]) as sp:
            with self._cond:
                if self._batcher.pending() >= self.cfg.max_queue:
                    self.metrics.inc("rejected")
                    sp.set(outcome="rejected")
                    req._finish(RequestStatus.REJECTED,
                                error=ServerOverloaded(
                                    f"queue at capacity "
                                    f"({self.cfg.max_queue} pending)"))
                    return req
                self._batcher.add(req)
                sp.set(outcome="queued",
                       queue_depth=self._batcher.pending())
                self.metrics.set_queue_depth(self._batcher.pending())
                self._cond.notify()
        return req

    def submit(self, A, timeout_ms: Optional[float] = None
               ) -> SolveRequest:
        """Enqueue one matrix; returns its :class:`SolveRequest` future
        immediately (possibly already REJECTED by admission control).
        ``timeout_ms`` (or the config default) bounds queue+solve time:
        a request still queued past its deadline completes TIMED_OUT."""
        self.metrics.inc("submitted")
        eng = self.engine_for(A)
        if timeout_ms is None:
            timeout_ms = self.cfg.default_timeout_ms
        deadline = (time.monotonic() + timeout_ms * 1e-3
                    if timeout_ms is not None else None)
        return self._admit(SolveRequest(skey=eng.key[0], matrix=A,
                                        deadline=deadline))

    def submit_values(self, eng: PSelInvEngine, values: SolveValues,
                      timeout_ms: Optional[float] = None
                      ) -> SolveRequest:
        """Enqueue pre-factorized rank-5 value shards for an engine the
        caller already holds (skips the host factorization AND the
        per-request pattern check — the caller vouches for layout)."""
        self.metrics.inc("submitted")
        self._engines.setdefault(eng.key[0], eng)
        if timeout_ms is None:
            timeout_ms = self.cfg.default_timeout_ms
        deadline = (time.monotonic() + timeout_ms * 1e-3
                    if timeout_ms is not None else None)
        return self._admit(SolveRequest(skey=eng.key[0], values=values,
                                        deadline=deadline))

    # ---- serving ------------------------------------------------------
    def _expire(self, expired: List[SolveRequest]) -> None:
        for r in expired:
            self.metrics.inc("timed_out")
            r._finish(RequestStatus.TIMED_OUT,
                      error=RequestTimedOut(
                          f"request {r.rid} missed its deadline "
                          f"while queued"))

    def _serve_batch(self, reqs: List[SolveRequest]) -> None:
        """Serve one same-structure batch end to end. Never raises:
        per-request pattern failures and whole-batch solve failures
        land on the affected requests as FAILED."""
        eng = self._engines[reqs[0].skey]
        cause = getattr(reqs, "cause", None)
        now = time.monotonic()
        for r in reqs:
            r.status = RequestStatus.BATCHED
            r.batched_at = now

        with TRACER.span("serve.batch", skey=reqs[0].skey[:12],
                         n=len(reqs), cause=cause or "?") as sp:
            # per-request admission of the *values* against the claimed
            # structure: a matrix whose pattern escapes it fails alone
            live: List[SolveRequest] = []
            for r in reqs:
                if r.matrix is not None:
                    try:
                        check_values_pattern(r.matrix, eng.bs, eng.b)
                    except ValueError as e:
                        self.metrics.inc("failed")
                        r._finish(RequestStatus.FAILED, error=ServeError(
                            f"request {r.rid}: {e}"))
                        continue
                live.append(r)
            self._remember(reqs)
            if not live:
                return

            try:
                vals = self._prepare(eng, live)
                B = vals.Lh.shape[0]
                bkt = bucket_size(B) if self.cfg.bucket else B
                sp.set(B=B, bucket=bkt)
                # one device→host gather for the whole batch: per-request
                # jax-array slicing would dispatch a gather op per request
                # (measured ~3 ms each — more than the solve itself)
                out = np.asarray(self._execute(eng, vals, B, bkt))
                self.metrics.observe_batch(B, bkt, cause=cause)
                self._buckets_used.setdefault(reqs[0].skey, set()).add(bkt)
                for i, r in enumerate(live):
                    self.metrics.inc("solved")
                    r._finish(RequestStatus.SOLVED, result=out[i])
                    self.metrics.observe_latency(r.latency_s)
            except Exception as e:               # noqa: BLE001 — isolate
                for r in live:
                    self.metrics.inc("failed")
                    r._finish(RequestStatus.FAILED, error=ServeError(
                        f"batch of {len(live)} failed: {e}"))

    def _prepare(self, eng: PSelInvEngine,
                 reqs: List[SolveRequest]) -> SolveValues:
        """Host numeric factorization for the batch: matrix-bearing
        requests go through the stacked pass, pre-factorized value
        requests slot in at their position."""
        mat_idx = [i for i, r in enumerate(reqs) if r.values is None]
        if len(mat_idx) == len(reqs):        # all-matrix batch (the
            mats = [r.matrix for r in reqs]  # common path): the stacked
            if self.cfg.batched_prep and len(mats) > 1:  # prep already
                return eng.prepare_values_many(mats)     # IS the batch
            return stack_values([eng.prepare_values(M) for M in mats])
        per: List[Optional[SolveValues]] = [
            r.values if r.values is not None else None for r in reqs]
        if mat_idx:
            mats = [reqs[i].matrix for i in mat_idx]
            if self.cfg.batched_prep and len(mats) > 1:
                mv = eng.prepare_values_many(mats)
            else:
                mv = stack_values([eng.prepare_values(M) for M in mats])
            for j, i in enumerate(mat_idx):
                per[i] = SolveValues(mv.Lh[j], mv.Dinv[j])
        return stack_values(per)

    def _execute(self, eng: PSelInvEngine, vals: SolveValues,
                 B: int, bkt: int):
        """One device-side sweep for the batch: the engine's counted
        jitted program (the default — ``trace_count`` stays the
        one-compile-per-(structure, bucket) ground truth) or a persisted
        AOT executable from the program cache."""
        if self.cfg.prog_cache is not None:
            comp = self.cfg.prog_cache.get(eng, bkt, self.cfg.dtype)
            Lh = jnp.asarray(vals.Lh, dtype=self.cfg.dtype)
            Dv = jnp.asarray(vals.Dinv, dtype=self.cfg.dtype)
            if bkt != B:
                pad = ((0, bkt - B),) + ((0, 0),) * (Lh.ndim - 1)
                Lh, Dv = jnp.pad(Lh, pad), jnp.pad(Dv, pad)
            return comp(Lh, Dv)[:B]
        return eng.solve(vals, dtype=self.cfg.dtype,
                         bucket=self.cfg.bucket)

    # ---- synchronous driving ------------------------------------------
    def pump(self, *, force: bool = False) -> int:
        """Serve every currently-ready batch (and expire overdue
        requests) on the caller's thread; returns the number of batches
        served. ``force=True`` flushes partial windows immediately."""
        with self._cond:
            batches, expired = self._batcher.pop_ready(force=force)
            self.metrics.set_queue_depth(self._batcher.pending())
        self._expire(expired)
        for batch in batches:
            self._serve_batch(batch)
        return len(batches)

    def drain(self, timeout: Optional[float] = None) -> None:
        """Flush and serve everything pending (on this thread when no
        worker is running, else wait for the worker to empty the
        queue)."""
        if self._thread is None:
            while self._batcher.pending():
                self.pump(force=True)
            return
        deadline = (time.monotonic() + timeout
                    if timeout is not None else None)
        with self._cond:
            self._drain_asap = True
            self._cond.notify()
            while self._batcher.pending():
                left = (None if deadline is None
                        else max(0.0, deadline - time.monotonic()))
                if left == 0.0:
                    raise TimeoutError("drain timed out")
                self._cond.wait(timeout=0.01 if left is None
                                else min(0.01, left))
        self._drain_asap = False

    # ---- the background worker ----------------------------------------
    def start(self) -> "SelInvServer":
        if self._thread is not None:
            return self
        self._running = True
        self._drain_asap = False
        self._thread = threading.Thread(target=self._worker,
                                        name="selinv-serve",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        with self._cond:
            self._running = False
            self._cond.notify_all()
        self._thread.join()
        self._thread = None
        # whatever is still queued at shutdown completes FAILED rather
        # than leaving callers blocked forever
        batches, expired = self._batcher.pop_ready(force=True)
        self._expire(expired)
        for batch in batches:
            for r in batch:
                self.metrics.inc("failed")
                r._finish(RequestStatus.FAILED,
                          error=ServeError("server stopped"))

    def __enter__(self) -> "SelInvServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _worker(self) -> None:
        while True:
            with self._cond:
                while self._running and self._batcher.pending() == 0:
                    self._cond.wait()
                if not self._running:
                    return
                now = time.monotonic()
                force = getattr(self, "_drain_asap", False)
                batches, expired = self._batcher.pop_ready(now,
                                                           force=force)
                if not batches and not expired:
                    due = self._batcher.next_due(now)
                    wait = (max(1e-4, due - now) if due is not None
                            else None)
                    self._cond.wait(timeout=wait)
                    continue
                self.metrics.set_queue_depth(self._batcher.pending())
            self._expire(expired)
            for batch in batches:
                self._serve_batch(batch)
            with self._cond:
                self._cond.notify_all()       # wake drain() waiters

    # ---- observability ------------------------------------------------
    def _remember(self, reqs: List[SolveRequest]) -> None:
        with self._cond:
            self._history.extend(reqs)

    def recent_requests(self) -> List[SolveRequest]:
        """The most recent served requests (bounded window), for
        :func:`repro.obs.export.chrome_trace` lifecycle lanes."""
        with self._cond:
            return list(self._history)

    def stats(self) -> Dict:
        """One coherent serving snapshot: request/latency/occupancy
        metrics, queue depth, per-structure compiled-bucket census, the
        engine structure-cache health counters, and the program-cache
        hit/miss/store counters when one is configured."""
        out = self.metrics.snapshot()
        out["queue_depth"] = self._batcher.pending()
        out["structures"] = {
            skey[:12]: {"buckets_used":
                        sorted(self._buckets_used.get(skey, ())),
                        "trace_count": eng.trace_count,
                        "solve_calls": eng.solve_calls}
            for skey, eng in self._engines.items()}
        out["engine_cache"] = {
            "engines": len(PSelInvEngine._cache),
            "bytes": PSelInvEngine.cache_bytes(),
            "hits": PSelInvEngine.cache_hits,
            "misses": PSelInvEngine.cache_misses,
            "evictions": PSelInvEngine.cache_evictions}
        if self.cfg.prog_cache is not None:
            out["prog_cache"] = self.cfg.prog_cache.stats()
        return out
