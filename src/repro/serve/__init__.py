"""Structure-keyed request coalescing and batched serving on top of
:class:`~repro.core.engine.PSelInvEngine`.

The engine makes B same-structure matrices cost one compile and ~15×
less per matrix; this package turns real traffic into those batches.
:class:`SelInvServer` accepts single-matrix solve requests, hashes each
by block structure (the engine's structure sha1), coalesces
same-structure requests into batched ``solve_many`` calls under dynamic
batch windows (flush on max-batch, max-wait, queue pressure) with
padded power-of-2 batch buckets, and streams results back per request
with per-request status. The paper's load-balancing lesson — bound how
much concurrent work any one participant absorbs — reappears here as
admission control and backpressure on the request queue.

Layout: ``batcher`` (requests, futures, windows, the coalescing
queues), ``server`` (the serving loop: admission, dispatch, failure
isolation), ``progcache`` (warm engines + the on-disk serialized
program cache), ``metrics`` (latency percentiles, batch occupancy,
queue/rejection counters), ``traffic`` (the synthetic mixed-structure
Poisson harness behind ``tools/serve_bench.py``).
"""
from .batcher import (BatchWindow, RequestStatus, RequestTimedOut,
                      ServeError, ServerOverloaded, SolveRequest,
                      StructureBatcher)
from .metrics import ServeMetrics
from .progcache import ProgramDiskCache
from .server import SelInvServer, ServeConfig

__all__ = ["SelInvServer", "ServeConfig", "BatchWindow", "SolveRequest",
           "RequestStatus", "StructureBatcher", "ServeMetrics",
           "ProgramDiskCache", "ServeError", "ServerOverloaded",
           "RequestTimedOut"]
