"""Request objects and structure-keyed coalescing queues.

A :class:`SolveRequest` is one matrix's journey through the server:
``queued → batched → solved | failed | timed_out`` (or ``rejected`` at
admission). It doubles as the caller's future — :meth:`SolveRequest
.result` blocks until completion and returns the A⁻¹ shards or raises
the recorded error.

The :class:`StructureBatcher` holds one FIFO queue per structure key
and decides *when* a queue becomes a batch (the dynamic batch window):

- **max-batch**: a queue reaching ``max_batch`` flushes immediately —
  the batch the compiled B=max_batch program was built for;
- **max-wait**: a queue whose oldest request has waited ``max_wait_ms``
  flushes with whatever coalesced — bounded added latency at low rate;
- **queue pressure**: when the *total* backlog across structures
  exceeds ``pressure``, the fullest queues flush immediately — the
  paper's load-balancing lesson (bound the concurrent work any one
  participant absorbs) applied to the serving queue, and the reason a
  burst drains at batch speed instead of waiting out its windows.

The batcher is not thread-safe by itself; the server serializes access
under its own condition variable.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Deque, Dict, List, Optional, Tuple

__all__ = ["RequestStatus", "SolveRequest", "Batch", "BatchWindow",
           "StructureBatcher", "ServeError", "ServerOverloaded",
           "RequestTimedOut"]


class ServeError(RuntimeError):
    """Base class for serving-layer failures recorded on a request."""


class ServerOverloaded(ServeError):
    """Admission control rejected the request (queue at capacity)."""


class RequestTimedOut(ServeError, TimeoutError):
    """The request's deadline passed before a batch served it."""


class RequestStatus(str, Enum):
    QUEUED = "queued"
    BATCHED = "batched"
    SOLVED = "solved"
    FAILED = "failed"
    TIMED_OUT = "timed_out"
    REJECTED = "rejected"


_TERMINAL = (RequestStatus.SOLVED, RequestStatus.FAILED,
             RequestStatus.TIMED_OUT, RequestStatus.REJECTED)

_rid = itertools.count()


@dataclass
class SolveRequest:
    """One matrix's solve request + its future.

    Exactly one of ``matrix`` (raw, host-factorized at batch time) or
    ``values`` (pre-factorized ``SolveValues``-like pair in device
    layout) is set. ``skey`` is the engine structure sha1 the request
    coalesces under. ``deadline`` is an absolute ``time.monotonic``
    instant (None = no deadline)."""
    skey: str
    matrix: object = None
    values: object = None
    deadline: Optional[float] = None
    rid: int = field(default_factory=lambda: next(_rid))
    status: RequestStatus = RequestStatus.QUEUED
    submitted: float = field(default_factory=time.monotonic)
    batched_at: Optional[float] = None
    completed: Optional[float] = None
    error: Optional[BaseException] = None
    _result: object = field(default=None, repr=False)
    _done: threading.Event = field(default_factory=threading.Event,
                                   repr=False)

    def done(self) -> bool:
        return self.status in _TERMINAL

    def result(self, timeout: Optional[float] = None):
        """Block until the request completes; return the A⁻¹ shards
        (rank 5, this request's matrix only) or raise the recorded
        error. ``timeout`` (seconds) bounds the *wait*, not the
        request — a timed-out wait raises ``TimeoutError`` while the
        request stays in flight."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} still {self.status.value} after "
                f"waiting {timeout}s")
        if self.error is not None:
            raise self.error
        return self._result

    def _finish(self, status: RequestStatus, result=None,
                error: Optional[BaseException] = None) -> None:
        if self.done():            # first completion wins
            return
        self.status = status
        self._result = result
        self.error = error
        self.completed = time.monotonic()
        self._done.set()

    @property
    def latency_s(self) -> Optional[float]:
        if self.completed is None:
            return None
        return self.completed - self.submitted


class Batch(list):
    """A flushed same-structure batch — a plain request list plus the
    flush ``cause`` the window policy recorded (``"full"``,
    ``"window"``, ``"pressure"`` or ``"force"``), so the serve spans and
    metrics can attribute every executed batch to the policy leg that
    released it."""

    __slots__ = ("cause",)

    def __init__(self, reqs, cause: str):
        super().__init__(reqs)
        self.cause = cause


@dataclass(frozen=True)
class BatchWindow:
    """The dynamic batch window knobs (see module docstring)."""
    max_batch: int = 16
    max_wait_ms: float = 2.0
    pressure: int = 64

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got "
                             f"{self.max_wait_ms}")
        if self.pressure < 1:
            raise ValueError(f"pressure must be >= 1, got "
                             f"{self.pressure}")


class StructureBatcher:
    """Per-structure FIFO queues + the flush policy."""

    def __init__(self, window: BatchWindow = BatchWindow()):
        self.window = window
        self._q: "OrderedDict[str, Deque[SolveRequest]]" = OrderedDict()

    def add(self, req: SolveRequest) -> None:
        self._q.setdefault(req.skey, deque()).append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self._q.values())

    def pending_by_key(self) -> Dict[str, int]:
        return {k: len(q) for k, q in self._q.items() if q}

    def _pop_chunk(self, key: str, n: int) -> List[SolveRequest]:
        q = self._q[key]
        chunk = [q.popleft() for _ in range(min(n, len(q)))]
        if not q:
            del self._q[key]
        return chunk

    def next_due(self, now: Optional[float] = None) -> Optional[float]:
        """Earliest future instant at which some queue's window or some
        request's deadline expires — the worker's sleep bound. None when
        nothing is pending."""
        due = None
        for q in self._q.values():
            for r in q:
                w = r.submitted + self.window.max_wait_ms * 1e-3
                due = w if due is None else min(due, w)
                if r.deadline is not None:
                    due = min(due, r.deadline)
        return due

    def pop_ready(self, now: Optional[float] = None, *,
                  force: bool = False
                  ) -> Tuple[List[List[SolveRequest]],
                             List[SolveRequest]]:
        """The flush decision: returns ``(batches, expired)`` where each
        batch is ≤ max_batch same-structure requests and ``expired``
        are requests whose deadline passed while queued (never joined a
        batch). ``force=True`` flushes everything regardless of windows
        (drain/shutdown)."""
        now = time.monotonic() if now is None else now
        expired: List[SolveRequest] = []
        for key in list(self._q):
            q = self._q[key]
            live = deque(r for r in q
                         if not (r.deadline is not None
                                 and r.deadline <= now))
            expired.extend(r for r in q
                           if r.deadline is not None and r.deadline <= now)
            if live:
                self._q[key] = live
            else:
                del self._q[key]

        batches: List[List[SolveRequest]] = []
        w = self.window
        for key in list(self._q):
            # full buckets always flush
            while key in self._q and len(self._q[key]) >= w.max_batch:
                batches.append(Batch(self._pop_chunk(key, w.max_batch),
                                     "full"))
            # window expiry flushes the remainder
            if key in self._q:
                oldest = self._q[key][0]
                if force or (now - oldest.submitted
                             >= w.max_wait_ms * 1e-3):
                    batches.append(Batch(
                        self._pop_chunk(key, w.max_batch),
                        "force" if force else "window"))

        # queue pressure: the total backlog must not sit waiting out
        # windows — flush the fullest queues until under the bound
        while self.pending() > w.pressure:
            key = max(self._q, key=lambda k: len(self._q[k]))
            batches.append(Batch(self._pop_chunk(key, w.max_batch),
                                 "pressure"))
        return batches, expired
