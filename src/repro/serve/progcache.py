"""On-disk AOT program cache for warm serving across restarts.

A serving process that restarts loses every XLA compile; for the hot
structures that is seconds of warmup per (structure, bucket). The
engine's :meth:`~repro.core.engine.PSelInvEngine.aot_compile` exposes
the serialization seam — a ``jax.stages.Compiled`` sweep for one exact
shape class — and :mod:`jax.experimental.serialize_executable` can
persist and reload it without re-tracing or re-compiling.

:class:`ProgramDiskCache` keys each executable by everything that could
invalidate it: the structure sha1, supernode width, grid, plan options,
batch bucket, dtype, plus the jax version and backend platform (a
serialized CPU executable must never be fed to a TPU runtime or a
different jax). ``get`` returns a callable executable — loaded from
disk on hit, compiled + persisted on miss. Writes are atomic
(tmp + rename) so a crashed writer never leaves a torn entry.

Degradation is graceful: when the serialization API is unavailable the
cache counts the miss and returns a freshly compiled executable without
persisting — serving works, only restart-warmth is lost.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import threading
from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["ProgramDiskCache"]


class ProgramDiskCache:
    """Persisted AOT executables keyed by (structure, bucket, dtype,
    session + runtime parameters). One instance per directory; safe for
    concurrent ``get`` from one process (an in-memory layer fronts the
    disk)."""

    def __init__(self, path: str):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)
        self._lock = threading.Lock()
        self._mem: Dict[str, object] = {}
        self.hits = 0
        self.misses = 0
        self.stores = 0
        self.load_errors = 0

    # ---- keying -------------------------------------------------------
    @staticmethod
    def cache_key(engine, batch_size: int, dtype) -> str:
        """sha1 over every compile-relevant coordinate of the
        executable."""
        h = hashlib.sha1()
        skey, b, grid, options = engine.key
        h.update(skey.encode())
        h.update(repr((b, grid.pr, grid.pc, options)).encode())
        h.update(repr((int(batch_size), jnp.dtype(dtype).name)).encode())
        h.update(jax.__version__.encode())
        h.update(jax.default_backend().encode())
        return h.hexdigest()

    def _entry(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.pselinv.pkl")

    # ---- the cache ----------------------------------------------------
    def get(self, engine, batch_size: int, dtype=jnp.float32):
        """The compiled batched sweep for (engine, batch bucket, dtype):
        in-memory hit → disk hit (deserialize) → compile + persist."""
        key = self.cache_key(engine, batch_size, dtype)
        with self._lock:
            comp = self._mem.get(key)
            if comp is not None:
                self.hits += 1
                return comp
        comp = self._load(key)
        if comp is not None:
            with self._lock:
                self.hits += 1
                self._mem.setdefault(key, comp)
            return comp
        with self._lock:
            self.misses += 1
        comp = engine.aot_compile(batch_size=batch_size, dtype=dtype,
                                  batched=True)
        self._store(key, comp)
        with self._lock:
            self._mem.setdefault(key, comp)
        return comp

    def _load(self, key: str):
        path = self._entry(key)
        if not os.path.exists(path):
            return None
        try:
            from jax.experimental import serialize_executable as se
            with open(path, "rb") as f:
                payload, in_tree, out_tree = pickle.load(f)
            return se.deserialize_and_load(payload, in_tree, out_tree)
        except Exception:            # torn/stale/incompatible entry:
            with self._lock:         # fall through to a fresh compile
                self.load_errors += 1
            return None

    def _store(self, key: str, comp) -> None:
        try:
            from jax.experimental import serialize_executable as se
            blob = pickle.dumps(se.serialize(comp))
        except Exception:            # serialization unavailable here —
            return                   # serve from memory, skip persist
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._entry(key))   # atomic publish
            with self._lock:
                self.stores += 1
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "stores": self.stores,
                    "load_errors": self.load_errors,
                    "entries": len([n for n in os.listdir(self.path)
                                    if n.endswith(".pselinv.pkl")])}
