from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .schedules import cosine_warmup

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "cosine_warmup"]
