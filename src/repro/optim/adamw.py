"""Sharded AdamW (functional). Optimizer states inherit each param's
sharding (same tree structure => same PartitionSpecs under FSDP).
``state_dtype=bfloat16`` halves optimizer memory for the 314B/398B MoE
archs; bf16 m/v with f32 update math is the documented trade
(stochastic-rounding territory — see DESIGN.md §5)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0) -> Tuple[Any, AdamWState, Dict]:
    # global-norm clip FUSED into the update: compute the scale from
    # immediately-reduced sums of squares (no f32 grad copies), then fold
    # it into each leaf's single-pass m/v/p chain. Materializing the
    # scaled grads separately costs ~2 whole-model buffers at 398B scale
    # (EXPERIMENTS §Perf iter 9/10).
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        u = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
