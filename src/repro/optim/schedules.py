"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)
