"""Config system: frozen dataclasses + registry + CLI resolution.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<id>.py``; shapes are global (the assignment pairs every
LM arch with the same four shapes). ``--arch <id>`` resolves through
:func:`get_config`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "MeshConfig", "SHAPES",
           "register", "get_config", "list_configs", "reduced_config"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | encdec | ssm | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"              # mlp activation (silu => SwiGLU)

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # every k-th layer uses MoE FFN
    capacity_factor: float = 1.25

    # -- hybrid (jamba): attention every `attn_every`, else mamba ----------
    attn_every: int = 0            # 0 -> all layers attention
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # -- ssm (xlstm) --------------------------------------------------------
    xlstm_pattern: Tuple[str, ...] = ()   # e.g. ("m","m","s") repeating
    xlstm_chunk: int = 64

    # -- encoder-decoder -----------------------------------------------------
    enc_layers: int = 0            # >0 => enc-dec; n_layers = decoder layers
    frontend: str = ""             # "" | "audio" | "vision" (stub embeddings)
    n_frontend_tokens: int = 0     # stub embedding count per example

    # -- training policy -----------------------------------------------------
    param_dtype: str = "float32"   # giant MoE archs use bfloat16 (+SR note)
    remat: str = "block"           # "none" | "block" (remat each scanned block)
    layer_group: int = 1           # scan over groups of this many layers

    # paper-technique integration: cross-pod gradient reduction scheme
    grad_comm: str = "hierarchical-shifted"   # or "flat-psum"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/logits can
        always shard over the model axis (padding logits are masked to
        -inf before the loss/sampling)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def supports_shape(self, shape: "ShapeConfig") -> Tuple[bool, str]:
        """Assignment rules: long_500k only for sub-quadratic archs."""
        if shape.name == "long_500k" and not self.is_subquadratic:
            return False, ("pure full-attention arch: 500k-context decode "
                           "skipped per assignment (needs sub-quadratic "
                           "attention); see DESIGN.md §5")
        return True, ""


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def ndev(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers everything)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    if not _REGISTRY:
        import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """CPU-smoke-test reduction: tiny widths, few layers/experts, same
    family/topology so every code path is exercised."""
    base = dict(
        n_layers=max(2, cfg.layer_group if cfg.layer_group > 1 else 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        head_dim=16,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        enc_layers=2 if cfg.enc_layers else 0,
        n_frontend_tokens=8 if cfg.frontend else 0,
        param_dtype="float32",
        layer_group=1,
    )
    if cfg.attn_every:
        base["n_layers"] = cfg.attn_every  # one full hybrid period
        base["layer_group"] = cfg.attn_every
    if cfg.xlstm_pattern:
        base["n_layers"] = len(cfg.xlstm_pattern)
        base["layer_group"] = len(cfg.xlstm_pattern)
    if cfg.moe_every > 1:
        base["n_layers"] = max(base["n_layers"], 2 * cfg.moe_every)
        base["layer_group"] = base.get("layer_group", 1)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
