"""xLSTM blocks: chunkwise-parallel mLSTM (matrix memory) and recurrent
sLSTM (scalar memory), per arXiv:2405.04517.

TPU adaptation: the mLSTM recurrence is evaluated chunkwise — intra-chunk
contributions via an attention-like (L×L) masked product in log-gate
space, inter-chunk state carried through ``lax.scan`` — so nothing of
size (seq × d × d) is ever materialized and the MXU does the work. The
sLSTM keeps its true hidden-to-gate recurrence (not parallelizable) and
runs as a time scan. Stabilization: sigmoid forget gates in log space +
a per-sequence max-stabilized exponential input gate (documented
simplification of the paper's running-max stabilizer).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import init_linear, linear, rmsnorm, init_rmsnorm

Params = Dict

__all__ = ["init_mlstm", "mlstm", "mlstm_decode", "mlstm_state_spec",
           "init_slstm", "slstm", "slstm_decode", "slstm_state_spec"]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 7)
    return {
        "wq": init_linear(ks[0], d, h * hd, dtype),
        "wk": init_linear(ks[1], d, h * hd, dtype),
        "wv": init_linear(ks[2], d, h * hd, dtype),
        "wi": init_linear(ks[3], d, h, dtype),       # input gate (per head)
        "wf": init_linear(ks[4], d, h, dtype),       # forget gate
        "wo": init_linear(ks[5], h * hd, d, dtype),
        "ogate": init_linear(ks[6], d, h * hd, dtype),
    }


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int):
    """q/k/v: (B,S,H,hd) f32; log_i/log_f: (B,S,H). Returns y (B,S,H,hd)."""
    B, S, H, hd = q.shape
    L = min(chunk, S)
    nc = S // L
    scale = hd ** -0.5

    qr = q.reshape(B, nc, L, H, hd).transpose(1, 0, 3, 2, 4) * scale
    kr = k.reshape(B, nc, L, H, hd).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nc, L, H, hd).transpose(1, 0, 3, 2, 4)
    lir = log_i.reshape(B, nc, L, H).transpose(1, 0, 3, 2)
    lfr = log_f.reshape(B, nc, L, H).transpose(1, 0, 3, 2)

    tri = jnp.tril(jnp.ones((L, L), bool))

    def step(carry, inp):
        C0, n0 = carry                       # (B,H,hd,hd), (B,H,hd)
        qc, kc, vc, li, lf = inp             # (B,H,L,hd)... (B,H,L)
        bf = jnp.cumsum(lf, axis=-1)         # (B,H,L) log Π f
        # intra-chunk: w_tj = exp(bf_t - bf_j + li_j), j <= t
        wlog = bf[..., :, None] - bf[..., None, :] + li[..., None, :]
        w = jnp.where(tri[None, None], jnp.exp(wlog), 0.0)
        s = jnp.einsum("bhtd,bhjd->bhtj", qc, kc) * w
        y_intra = jnp.einsum("bhtj,bhjd->bhtd", s, vc)
        # n_t(intra) = Σ_j w_tj k_j  (the i_j factor is inside w)
        n_intra = jnp.einsum("bhtj,bhjd->bhtd", w, kc)
        # inter-chunk: carry contribution scaled by Π f up to t
        Ft = jnp.exp(bf)                     # (B,H,L)
        y_state = jnp.einsum("bhtd,bhde->bhte", qc, C0) * Ft[..., None]
        n_state = n0[:, :, None] * Ft[..., None]
        nvec = n_intra + n_state
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhtd,bhtd->bht", qc, nvec)), 1.0)
        y = (y_intra + y_state) / denom[..., None]
        # chunk-end state
        FL = jnp.exp(bf[..., -1])            # (B,H)
        decay = jnp.exp(bf[..., -1:] - bf + li)       # (B,H,L)
        C1 = C0 * FL[..., None, None] + jnp.einsum(
            "bhld,bhle,bhl->bhde", kc, vc, decay)
        n1 = n0 * FL[..., None] + jnp.einsum("bhld,bhl->bhd", kc, decay)
        return (C1, n1), y

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    _, ys = lax.scan(step, (C0, n0), (qr, kr, vr, lir, lfr))
    return ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)


def _gates(p, cfg, x):
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = linear(p["wq"], x).reshape(B, S, h, hd).astype(jnp.float32)
    k = linear(p["wk"], x).reshape(B, S, h, hd).astype(jnp.float32)
    v = linear(p["wv"], x).reshape(B, S, h, hd).astype(jnp.float32)
    i_raw = linear(p["wi"], x).astype(jnp.float32)            # (B,S,H)
    f_raw = linear(p["wf"], x).astype(jnp.float32)
    log_f = -jax.nn.softplus(-f_raw)                          # log σ(f)
    log_i = i_raw - lax.stop_gradient(i_raw.max())            # exp gate ≤ 1
    return q, k, v, log_i, log_f


def mlstm(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    B, S, _ = x.shape
    q, k, v, log_i, log_f = _gates(p, cfg, x)
    y = _mlstm_chunk_scan(q, k, v, log_i, log_f, cfg.xlstm_chunk)
    y = y.astype(x.dtype).reshape(B, S, cfg.n_heads * cfg.hd)
    o = jax.nn.sigmoid(linear(p["ogate"], x))
    return linear(p["wo"], y * o)


def mlstm_state_spec(cfg, batch: int):
    h, hd = cfg.n_heads, cfg.hd
    return {"C": (batch, h, hd, hd), "n": (batch, h, hd)}


def mlstm_decode(p: Params, cfg, x: jnp.ndarray, state: Dict
                 ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,1,D)."""
    B = x.shape[0]
    q, k, v, log_i, log_f = _gates(p, cfg, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                    # (B,H,hd)
    li, lf = log_i[:, 0], log_f[:, 0]                      # (B,H)
    f = jnp.exp(lf)[..., None, None]
    i = jnp.exp(li)[..., None, None]
    C = state["C"] * f + i * jnp.einsum("bhd,bhe->bhde", k, v)
    n = state["n"] * f[..., 0] + i[..., 0] * k
    qs = q * cfg.hd ** -0.5
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), 1.0)
    y = jnp.einsum("bhd,bhde->bhe", qs, C) / denom[..., None]
    y = y.astype(x.dtype).reshape(B, 1, cfg.n_heads * cfg.hd)
    o = jax.nn.sigmoid(linear(p["ogate"], x))
    return linear(p["wo"], y * o), {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype) -> Params:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 3)
    return {
        "wx": init_linear(ks[0], d, 4 * h * hd, dtype),     # z,i,f,o from x
        "wr": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
               / jnp.sqrt(hd)).astype(dtype),               # block-diag rec.
        "wo": init_linear(ks[2], h * hd, d, dtype),
    }


def _slstm_cell(p, cfg, xg, state):
    """One step. xg: (B,H,4*hd) pre-activations from x; state dict."""
    h_, c, n, m = state["h"], state["c"], state["n"], state["m"]
    rec = jnp.einsum("bhd,hde->bhe", h_, p["wr"].astype(h_.dtype))
    g = (xg + rec).astype(jnp.float32)
    hd = cfg.hd
    z, i_raw, f_raw, o_raw = [g[..., k * hd:(k + 1) * hd] for k in range(4)]
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(log_f + m - m_new)
    c_new = f * c + i * jnp.tanh(z)
    n_new = f * n + i
    hh = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    hh = hh.astype(h_.dtype)
    return hh, {"h": hh, "c": c_new, "n": n_new, "m": m_new}


def slstm(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xg = linear(p["wx"], x).reshape(B, S, h, 4 * hd)
    state = slstm_init_state(cfg, B, x.dtype)

    def step(st, xt):
        hh, st = _slstm_cell(p, cfg, xt, st)
        return st, hh

    _, hs = lax.scan(step, state, xg.transpose(1, 0, 2, 3))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, h * hd)
    return linear(p["wo"], y)


def slstm_init_state(cfg, batch: int, dtype):
    h, hd = cfg.n_heads, cfg.hd
    f32 = jnp.float32
    return {"h": jnp.zeros((batch, h, hd), dtype),
            "c": jnp.zeros((batch, h, hd), f32),
            "n": jnp.zeros((batch, h, hd), f32),
            "m": jnp.full((batch, h, hd), -1e30, f32)}


def slstm_state_spec(cfg, batch: int):
    h, hd = cfg.n_heads, cfg.hd
    return {"h": (batch, h, hd), "c": (batch, h, hd),
            "n": (batch, h, hd), "m": (batch, h, hd)}


def slstm_decode(p: Params, cfg, x: jnp.ndarray, state: Dict
                 ) -> Tuple[jnp.ndarray, Dict]:
    B = x.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    xg = linear(p["wx"], x)[:, 0].reshape(B, h, 4 * hd)
    hh, state = _slstm_cell(p, cfg, xg, state)
    return linear(p["wo"], hh.reshape(B, 1, h * hd)), state
