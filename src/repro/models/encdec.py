"""Encoder–decoder stack (seamless-m4t style): audio-frontend encoder
(precomputed frame embeddings — modality stub per assignment) + causal
text decoder with cross-attention."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn_mod
from .layers import (cross_entropy, embed, init_embed, init_linear,
                     init_mlp, init_rmsnorm, linear, mlp, rmsnorm)
from .sharding_hooks import constrain
from .transformer import param_dtype_of

Params = Dict

__all__ = ["init_encdec_params", "encdec_forward", "encdec_loss",
           "encdec_cache_spec", "encdec_init_cache", "encdec_decode_step",
           "encode"]


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn_mod.init_attention(k1, cfg, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)}


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": init_rmsnorm(cfg.d_model, dtype),
            "self": attn_mod.init_attention(k1, cfg, dtype),
            "normx": init_rmsnorm(cfg.d_model, dtype),
            "cross": attn_mod.init_attention(k2, cfg, dtype),
            "norm2": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype)}


def init_encdec_params(key, cfg) -> Params:
    dtype = param_dtype_of(cfg)
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": init_embed(ks[2], cfg.vocab_padded, cfg.d_model, dtype),
        "enc": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
        "dec": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "norm_enc": init_rmsnorm(cfg.d_model, dtype),
        "norm_f": init_rmsnorm(cfg.d_model, dtype),
        "unembed": init_linear(ks[3], cfg.d_model, cfg.vocab_padded, dtype),
    }


def encode(p: Params, cfg, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, D) precomputed frontend embeddings."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = constrain(frames, "hidden")

    def body(h, bp):
        x = rmsnorm(bp["norm1"], h, cfg.norm_eps)
        h = h + attn_mod.attention(bp["attn"], cfg, x, positions,
                                   causal=False)
        x = rmsnorm(bp["norm2"], h, cfg.norm_eps)
        h = h + mlp(bp["ffn"], x, cfg.act)
        return constrain(h, "hidden"), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    h, _ = lax.scan(body, h, p["enc"])
    return rmsnorm(p["norm_enc"], h, cfg.norm_eps)


def _cross_kv(bp, cfg, memory):
    B, S, _ = memory.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = linear(bp["cross"]["wk"], memory).reshape(B, S, kv, hd)
    v = linear(bp["cross"]["wv"], memory).reshape(B, S, kv, hd)
    return k, v


def encdec_forward(p: Params, cfg, tokens: jnp.ndarray,
                   frames: jnp.ndarray, last_only: bool = False):
    dtype = jnp.bfloat16   # compute dtype: bf16 everywhere (mixed precision)
    memory = encode(p, cfg, frames.astype(dtype))
    h = embed(p["embed"], tokens, dtype)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(h, bp):
        x = rmsnorm(bp["norm1"], h, cfg.norm_eps)
        h = h + attn_mod.attention(bp["self"], cfg, x, positions)
        x = rmsnorm(bp["normx"], h, cfg.norm_eps)
        kv = _cross_kv(bp, cfg, memory)
        h = h + attn_mod.attention(bp["cross"], cfg, x, positions,
                                   kv_override=kv)
        x = rmsnorm(bp["norm2"], h, cfg.norm_eps)
        h = h + mlp(bp["ffn"], x, cfg.act)
        return constrain(h, "hidden"), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    h, _ = lax.scan(body, h, p["dec"])
    if last_only:
        h = h[:, -1:]
    h = rmsnorm(p["norm_f"], h, cfg.norm_eps)
    h = constrain(h, "pre_logits")
    return constrain(linear(p["unembed"], h), "logits")


def encdec_loss(p: Params, cfg, batch: Dict) -> jnp.ndarray:
    logits = encdec_forward(p, cfg, batch["tokens"], batch["frontend"])
    return cross_entropy(logits, batch["labels"], batch.get("loss_mask"))


# -- decode -------------------------------------------------------------------

def encdec_cache_spec(cfg, batch: int, seq: int, enc_seq: int):
    L = cfg.n_layers
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "self_k": (L, batch, seq, kv, hd),
        "self_v": (L, batch, seq, kv, hd),
        "cross_k": (L, batch, enc_seq, kv, hd),
        "cross_v": (L, batch, enc_seq, kv, hd),
    }


def encdec_init_cache(p: Params, cfg, frames: jnp.ndarray, seq: int):
    """Run the encoder and precompute cross KV (serving prefill)."""
    memory = encode(p, cfg, frames.astype(jnp.bfloat16))
    B = frames.shape[0]
    dtype = memory.dtype

    def per_layer(bp):
        return _cross_kv(bp, cfg, memory)

    ck, cv = lax.map(per_layer, p["dec"])
    kv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "self_k": jnp.zeros((cfg.n_layers, B, seq, kv, hd), dtype),
        "self_v": jnp.zeros((cfg.n_layers, B, seq, kv, hd), dtype),
        "cross_k": ck, "cross_v": cv,
    }


def encdec_decode_step(p: Params, cfg, token: jnp.ndarray, pos: jnp.ndarray,
                       cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    dtype = jnp.bfloat16   # compute dtype: bf16 everywhere (mixed precision)
    h = embed(p["embed"], token[:, None], dtype)

    def body(h, xs):
        bp, sk, sv, ck, cv = xs
        x = rmsnorm(bp["norm1"], h, cfg.norm_eps)
        y, sk, sv = attn_mod.decode_attention(bp["self"], cfg, x, pos, sk, sv)
        h = h + y
        x = rmsnorm(bp["normx"], h, cfg.norm_eps)
        # cross attention: one query against the fixed encoder memory
        B = x.shape[0]
        q = linear(bp["cross"]["wq"], x).reshape(
            B, 1, cfg.n_heads, cfg.hd)
        if cfg.qk_norm:
            q = rmsnorm(bp["cross"]["qnorm"], q, cfg.norm_eps)
        G = cfg.n_heads // cfg.n_kv_heads
        qr = q.reshape(B, cfg.n_kv_heads, G, cfg.hd) * cfg.hd ** -0.5
        s = jnp.einsum("bkgd,bskd->bkgs", qr, ck).astype(jnp.float32)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        y = jnp.einsum("bkgs,bskd->bkgd", w, cv).reshape(
            B, 1, cfg.n_heads * cfg.hd)
        h = h + linear(bp["cross"]["wo"], y)
        x = rmsnorm(bp["norm2"], h, cfg.norm_eps)
        h = h + mlp(bp["ffn"], x, cfg.act)
        return h, (sk, sv)

    h, (sk, sv) = lax.scan(
        body, h, (p["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    cache = dict(cache, self_k=sk, self_v=sv)
    h = rmsnorm(p["norm_f"], h, cfg.norm_eps)
    logits = linear(p["unembed"], h)[:, 0]
    return logits, cache
