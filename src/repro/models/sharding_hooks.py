"""Sharding-constraint hooks: models stay mesh-agnostic; the launcher
installs a policy that maps logical names -> PartitionSpec."""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

import jax

_STATE = threading.local()


def constrain(x, name: str):
    """Apply the active policy's sharding constraint for logical tensor
    ``name`` (e.g. "hidden", "logits", "kv_cache"). No-op without policy."""
    pol: Optional[Callable] = getattr(_STATE, "policy", None)
    if pol is None:
        return x
    spec = pol(name, x)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def policy_info(key: str, default=None):
    """Mesh facts exposed by the active policy (e.g. data-shard count for
    the MoE grouped dispatch). Returns ``default`` with no policy."""
    pol = getattr(_STATE, "policy", None)
    info = getattr(pol, "info", None) if pol is not None else None
    if info is None:
        return default
    return info.get(key, default)


@contextlib.contextmanager
def sharding_policy(policy: Callable):
    prev = getattr(_STATE, "policy", None)
    _STATE.policy = policy
    try:
        yield
    finally:
        _STATE.policy = prev
