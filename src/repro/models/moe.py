"""Mixture-of-Experts FFN with capacity-based dispatch (TPU-native: static
shapes, scatter/gather — no dynamic ragged ops).

Expert weights are 2-D sharded (FSDP over `data` on d_model, TP over
`model` on d_ff); dispatch/combine use scatter/gather per token so compiled
FLOPs stay O(tokens · top_k · expert_ffn) rather than the quadratic
one-hot-einsum formulation.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import init_linear, linear

Params = Dict

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": init_linear(ks[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / jnp.sqrt(f)).astype(dtype),
    }
    return p


def moe_ffn(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (y, aux_loss). Top-k routing with per-expert capacity
    C = ceil(T_local·k/E · capacity_factor); overflow tokens are dropped
    (standard Switch/MTF semantics).

    Dispatch is *grouped by data shard* (G = data-axis size): each group
    scatters only its local tokens into its own (E, C, D) buffer — no
    cross-shard scatter, so the dispatched-activation buffer shards over
    the data axis, and over the model axis too via expert parallelism
    when E divides it (see runtime.sharding)."""
    from .sharding_hooks import constrain, policy_info

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = policy_info("data_groups", 1)
    if T % G:
        G = 1
    Tl = T // G
    cap = int((Tl * k) / E * cfg.capacity_factor) + 1

    xt = x.reshape(G, Tl, D)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                          # (G,Tl,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style), over all tokens
    me = probs.mean((0, 1))                                      # (E,)
    ce = jnp.zeros((E,)).at[ids.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # position of each (token, slot) within its expert, per group
    flat_ids = ids.reshape(G, Tl * k)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)        # (G,Tl*k,E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_ids[..., None], axis=2)[..., 0]
    keep = pos < cap
    dpos = jnp.where(keep, pos, cap)                 # overflow -> drop slot

    # index-map dispatch: scatter only int32 slot->token indices (tiny),
    # then move rows by gather — the D-wide row scatter would otherwise
    # be replicated across the model axis by the SPMD partitioner.
    Tk = Tl * k
    def imap_group(e_ids, p_ids):
        return jnp.full((E, cap + 1), Tk, jnp.int32).at[
            e_ids, p_ids].set(jnp.arange(Tk, dtype=jnp.int32))

    imap = jax.vmap(imap_group)(flat_ids, dpos)          # (G,E,cap+1)
    slot2tok = imap[:, :, :cap] // k                     # (G,E,cap) ∈ [0,Tl]
    # (the // k maps (token,choice) slots to token rows; the Tk sentinel
    # maps to the zero padding row Tl — never materialize repeat(x, k))
    xt_pad = jnp.concatenate(
        [xt, jnp.zeros((G, 1, D), x.dtype)], axis=1)     # (G,Tl+1,D)
    eb = jax.vmap(lambda s, m: s[m])(xt_pad, slot2tok)   # (G,E,cap,D)
    eb = constrain(eb, "moe_dispatch")

    # expert computation (SwiGLU), expert-parallel when E | model axis
    up = jnp.einsum("gecd,edf->gecf", eb, p["w_up"].astype(x.dtype))
    g = jnp.einsum("gecd,edf->gecf", eb, p["w_gate"].astype(x.dtype))
    h = constrain(jax.nn.silu(g) * up, "moe_ffn_act")
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    out = constrain(out, "moe_dispatch")

    # combine: weight each slot by its gate, then scatter-add straight
    # into token rows (commutative add => the partitioner keeps updates
    # expert-local and psums over the model axis — no expert-buffer
    # all-gather, no (tokens·k, D) intermediate)
    gate_pad = jnp.concatenate(
        [gate.reshape(G, Tk), jnp.zeros((G, 1), gate.dtype)], axis=1)
    gate_of_slot = jax.vmap(lambda g0, m: g0[m])(
        gate_pad, imap[:, :, :cap])                      # (G,E,cap)
    out = out * gate_of_slot[..., None].astype(x.dtype)

    def combine_group(o, m):
        return jnp.zeros((Tl + 1, D), x.dtype).at[m.reshape(-1)].add(
            o.reshape(E * cap, D))

    y = jax.vmap(combine_group)(out, slot2tok)[:, :Tl]   # (G,Tl,D)
    return y.reshape(B, S, D), aux
