"""repro.models — model zoo covering the 10 assigned architectures."""
from .registry import ModelAPI, get_model

__all__ = ["ModelAPI", "get_model"]
