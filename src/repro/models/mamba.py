"""Mamba (S6) block for the Jamba hybrid — selective SSM with associative
scan over the sequence (TPU-native: `lax.associative_scan` instead of the
CUDA selective-scan kernel), plus O(1)-state single-token decode."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import init_linear, linear

Params = Dict

__all__ = ["init_mamba", "mamba", "mamba_decode", "mamba_state_spec"]


def init_mamba(key, cfg, dtype) -> Params:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   * 0.1).astype(dtype),
        "x_proj": init_linear(ks[2], di, ds * 2 + 1, dtype),   # B, C, dt
        "dt_bias": jnp.zeros((di,), dtype=dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1., ds + 1.)[None, :], (di, 1))
                         ).astype(jnp.float32),
        "D": jnp.ones((di,), dtype=dtype),
        "out_proj": init_linear(ks[3], di, d, dtype),
    }


_MAMBA_CHUNK = 128


def _ssm_scan(u, dt, A, Bc, Cc, chunk: int = _MAMBA_CHUNK):
    """u: (B,S,di); dt: (B,S,di); A: (di,ds); Bc/Cc: (B,S,ds).
    h_t = exp(dt·A) h_{t-1} + dt·B_t u_t ;  y_t = C_t·h_t.

    Chunked: the (B, L, di, ds) gate/update tensors exist only per chunk
    (transient, rematerialized in backward); the cross-chunk carry is the
    (B, di, ds) state — without this, a 72-layer Jamba at 4k×256 would
    materialize petabytes."""
    B, S, di = u.shape
    ds = Bc.shape[-1]
    L = min(chunk, S)
    while S % L:
        L -= 1
    nc = S // L

    def chunk_body(h0, inp):
        uc, dtc, bc, cc = inp                       # (B,L,·)
        dA = jnp.exp(dtc[..., None] * (-jnp.exp(A))[None, None])
        dBu = (dtc * uc)[..., None] * bc[..., None, :]   # (B,L,di,ds)

        def combine(a, b):
            (ga, xa), (gb, xb) = a, b
            return ga * gb, xb + gb * xa

        cum_dA, h_intra = lax.associative_scan(combine, (dA, dBu), axis=1)
        h = h_intra + cum_dA * h0[:, None]
        y = jnp.einsum("bsdn,bsn->bsd", h, cc)
        return h[:, -1], y

    xs = tuple(x.reshape(B, nc, L, -1).swapaxes(0, 1)
               for x in (u, dt, Bc, Cc))
    h0 = jnp.zeros((B, di, ds), u.dtype)
    _, ys = lax.scan(jax.checkpoint(chunk_body), h0, xs)
    return ys.swapaxes(0, 1).reshape(B, S, di)


def mamba(p: Params, cfg, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence forward. x: (B,S,D)."""
    B, S, D = x.shape
    di = cfg.mamba_expand * D
    dc = cfg.mamba_d_conv
    xz = linear(p["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)                          # (B,S,di)

    # depthwise causal conv1d
    pad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
               for i in range(dc))
    u = jax.nn.silu(conv)

    bcd = linear(p["x_proj"], u)
    ds = cfg.mamba_d_state
    Bc, Cc, dt = bcd[..., :ds], bcd[..., ds:2 * ds], bcd[..., 2 * ds:]
    # scalar selective dt per position, per-channel learned bias
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)[None, None, :])
    y = _ssm_scan(u.astype(jnp.float32), dt, p["A_log"],
                  Bc.astype(jnp.float32), Cc.astype(jnp.float32))
    y = y.astype(x.dtype) + u * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y)


def mamba_state_spec(cfg, batch: int):
    """State carried across decode steps: SSM state + conv window."""
    di = cfg.mamba_expand * cfg.d_model
    return {
        "ssm": (batch, di, cfg.mamba_d_state),
        "conv": (batch, cfg.mamba_d_conv - 1, di),
    }


def mamba_decode(p: Params, cfg, x: jnp.ndarray, state: Dict
                 ) -> Tuple[jnp.ndarray, Dict]:
    """Single-token step. x: (B,1,D)."""
    B, _, D = x.shape
    di = cfg.mamba_expand * D
    dc = cfg.mamba_d_conv
    ds = cfg.mamba_d_state
    xz = linear(p["in_proj"], x)[:, 0]
    u, z = jnp.split(xz, 2, axis=-1)                          # (B,di)

    win = jnp.concatenate([state["conv"], u[:, None]], axis=1)  # (B,dc,di)
    conv = jnp.einsum("bcd,cd->bd", win, p["conv_w"].astype(x.dtype))
    u = jax.nn.silu(conv)

    bcd = u @ p["x_proj"]["w"].astype(x.dtype)
    Bc, Cc, dt = bcd[..., :ds], bcd[..., ds:2 * ds], bcd[..., 2 * ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)[None, :])
    dA = jnp.exp(dt[..., None] * (-jnp.exp(p["A_log"]))[None])   # (B,di,ds)
    h = state["ssm"] * dA + (dt * u.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32))
    y = y.astype(x.dtype) + u * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"]["w"].astype(x.dtype))[:, None]
    return out, {"ssm": h, "conv": win[:, 1:]}
