"""Decoder-only LM assembly covering every assigned family:

* dense / MoE transformers (GQA, RoPE, qk-norm),
* hybrid Mamba+attention (Jamba: attention every ``attn_every`` layers,
  MoE every ``moe_every``),
* xLSTM stacks (mLSTM/sLSTM pattern),
* VLM/audio frontends as precomputed-embedding stubs,
* encoder-decoder (see :mod:`repro.models.encdec`).

Layers are scanned in *groups* (``cfg.layer_group`` consecutive layers per
scan step — the group is the smallest period of the layer pattern), with
params stacked over groups: compile time is O(group), not O(n_layers).
``cfg.remat`` wraps the group body in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import attention as attn_mod
from . import mamba as mamba_mod
from . import moe as moe_mod
from . import xlstm as xlstm_mod
from .layers import (cross_entropy, embed, init_embed, init_linear,
                     init_mlp, init_rmsnorm, linear, mlp, rmsnorm)
from .sharding_hooks import constrain

Params = Dict

__all__ = ["layer_kinds", "init_params", "lm_forward", "lm_loss",
           "init_cache", "cache_spec", "lm_decode_step", "param_dtype_of"]


def param_dtype_of(cfg):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.param_dtype]


# ---------------------------------------------------------------------------
# layer pattern
# ---------------------------------------------------------------------------

def layer_kinds(cfg) -> List[str]:
    kinds = []
    for l in range(cfg.n_layers):
        if cfg.xlstm_pattern:
            kinds.append("mlstm" if cfg.xlstm_pattern[
                l % len(cfg.xlstm_pattern)] == "m" else "slstm")
            continue
        if cfg.attn_every and (l % cfg.attn_every) != cfg.attn_every // 2:
            mixer = "mamba"
        else:
            mixer = "attn"
        if cfg.n_experts and (l % cfg.moe_every) == cfg.moe_every - 1:
            ffn = "moe"
        else:
            ffn = "mlp"
        kinds.append(f"{mixer}+{ffn}")
    return kinds


def _group_kinds(cfg) -> List[str]:
    kinds = layer_kinds(cfg)
    g = cfg.layer_group
    assert cfg.n_layers % g == 0
    per_group = [kinds[i * g:(i + 1) * g] for i in range(cfg.n_layers // g)]
    assert all(pg == per_group[0] for pg in per_group), \
        "layer pattern must be periodic with period layer_group"
    return per_group[0]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block(key, cfg, kind: str) -> Params:
    dtype = param_dtype_of(cfg)
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": init_rmsnorm(d, dtype)}
    if kind == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(k1, cfg, dtype)
        return p
    if kind == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(k1, cfg, dtype)
        return p
    mixer, ffn = kind.split("+")
    if mixer == "attn":
        p["mixer"] = attn_mod.init_attention(k1, cfg, dtype)
    else:
        p["mixer"] = mamba_mod.init_mamba(k1, cfg, dtype)
    p["norm2"] = init_rmsnorm(d, dtype)
    if ffn == "moe":
        p["ffn"] = moe_mod.init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = init_mlp(k2, d, cfg.d_ff, cfg.act, dtype)
    return p


def block_forward(p: Params, cfg, kind: str, h: jnp.ndarray,
                  positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block. Returns (h, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = rmsnorm(p["norm1"], h, cfg.norm_eps)
    if kind == "mlstm":
        return h + xlstm_mod.mlstm(p["mixer"], cfg, x), aux
    if kind == "slstm":
        return h + xlstm_mod.slstm(p["mixer"], cfg, x), aux
    mixer, ffn = kind.split("+")
    if mixer == "attn":
        h = h + attn_mod.attention(p["mixer"], cfg, x, positions)
    else:
        h = h + mamba_mod.mamba(p["mixer"], cfg, x)
    h = constrain(h, "hidden")
    x = rmsnorm(p["norm2"], h, cfg.norm_eps)
    if ffn == "moe":
        y, aux = moe_mod.moe_ffn(p["ffn"], cfg, x)
        h = h + y
    else:
        h = h + mlp(p["ffn"], x, cfg.act)
    return constrain(h, "hidden"), aux


def block_decode(p: Params, cfg, kind: str, h: jnp.ndarray,
                 pos: jnp.ndarray, cache: Params
                 ) -> Tuple[jnp.ndarray, Params]:
    x = rmsnorm(p["norm1"], h, cfg.norm_eps)
    if kind == "mlstm":
        y, cache = xlstm_mod.mlstm_decode(p["mixer"], cfg, x, cache)
        return h + y, cache
    if kind == "slstm":
        y, cache = xlstm_mod.slstm_decode(p["mixer"], cfg, x, cache)
        return h + y, cache
    mixer, ffn = kind.split("+")
    if mixer == "attn":
        y, kc, vc = attn_mod.decode_attention(
            p["mixer"], cfg, x, pos, cache["k"], cache["v"])
        cache = {"k": kc, "v": vc}
        h = h + y
    else:
        y, cache = mamba_mod.mamba_decode(p["mixer"], cfg, x, cache)
        h = h + y
    x = rmsnorm(p["norm2"], h, cfg.norm_eps)
    if ffn == "moe":
        y, _ = moe_mod.moe_ffn(p["ffn"], cfg, x)
        h = h + y
    else:
        h = h + mlp(p["ffn"], x, cfg.act)
    return h, cache


# ---------------------------------------------------------------------------
# whole model
# ---------------------------------------------------------------------------

def init_params(key, cfg) -> Params:
    dtype = param_dtype_of(cfg)
    gk = _group_kinds(cfg)
    n_groups = cfg.n_layers // cfg.layer_group
    keys = jax.random.split(key, 3 + len(gk))

    blocks = []
    for gp, kind in enumerate(gk):
        gkeys = jax.random.split(keys[3 + gp], n_groups)
        stacked = jax.vmap(lambda k: init_block(k, cfg, kind))(gkeys)
        blocks.append(stacked)

    p: Params = {
        "embed": init_embed(keys[0], cfg.vocab_padded, cfg.d_model, dtype),
        "norm_f": init_rmsnorm(cfg.d_model, dtype),
        "blocks": tuple(blocks),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_linear(keys[1], cfg.d_model, cfg.vocab_padded,
                                   dtype)
    return p


def _logits(p: Params, cfg, h: jnp.ndarray) -> jnp.ndarray:
    h = rmsnorm(p["norm_f"], h, cfg.norm_eps)
    # gather seq over model before the vocab projection so the matmul
    # produces vocab-sharded logits directly (no unsharded-V intermediate)
    h = constrain(h, "pre_logits")
    if cfg.tie_embeddings:
        logits = h @ p["embed"]["table"].astype(h.dtype).T
    else:
        logits = linear(p["unembed"], h)
    if cfg.vocab_padded != cfg.vocab:   # mask padding columns (fused)
        valid = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(valid, logits, -1e30)
    return constrain(logits, "logits")


def lm_forward(p: Params, cfg, tokens: jnp.ndarray,
               frontend: Optional[jnp.ndarray] = None,
               last_only: bool = False) -> jnp.ndarray:
    """Train / prefill forward. tokens: (B, S) int32; frontend: (B, F, D)
    precomputed modality embeddings, prepended (VLM stub)."""
    dtype = jnp.bfloat16   # compute dtype: bf16 everywhere (mixed precision)
    h = embed(p["embed"], tokens, dtype)
    if frontend is not None:
        h = jnp.concatenate([frontend.astype(dtype), h], axis=1)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = constrain(h, "hidden")

    gk = _group_kinds(cfg)

    # NOTE: nested per-sublayer checkpointing inside the group was tried
    # for jamba's period-8 groups and REGRESSED memory (123.7→127.8 GB)
    # and compile time (53→122 s) — see EXPERIMENTS §Perf iter 9. The
    # peak is optimizer-stage whole-model temporaries, not sublayer
    # transient overlap.
    def group_body(h, gparams):
        aux = jnp.zeros((), jnp.float32)
        for gp, kind in enumerate(gk):
            h, a = block_forward(gparams[gp], cfg, kind, h, positions)
            aux = aux + a
        return h, aux

    body = group_body
    if cfg.remat == "block":
        body = jax.checkpoint(group_body)

    def scan_fn(carry, gparams):
        h, aux = carry
        h, a = body(h, gparams)
        return (h, aux + a), None

    (h, aux), _ = lax.scan(scan_fn, (h, jnp.zeros((), jnp.float32)),
                           p["blocks"])
    if frontend is not None:
        h = h[:, frontend.shape[1]:]
    if last_only:
        h = h[:, -1:]
    logits = _logits(p, cfg, h)
    return logits, aux


def lm_loss(p: Params, cfg, batch: Dict) -> jnp.ndarray:
    tokens = batch["tokens"]
    labels = batch["labels"]
    logits, aux = lm_forward(p, cfg, tokens,
                             frontend=batch.get("frontend"))
    return cross_entropy(logits, labels,
                         batch.get("loss_mask")) + 0.01 * aux


# -- decode -------------------------------------------------------------------

def cache_spec(cfg, batch: int, seq: int) -> Params:
    """Shape spec (dicts of tuples) for the decode cache."""
    gk = _group_kinds(cfg)
    n_groups = cfg.n_layers // cfg.layer_group
    out = []
    for kind in gk:
        if kind == "mlstm":
            spec = xlstm_mod.mlstm_state_spec(cfg, batch)
        elif kind == "slstm":
            spec = xlstm_mod.slstm_state_spec(cfg, batch)
        elif kind.startswith("mamba"):
            spec = mamba_mod.mamba_state_spec(cfg, batch)
        else:
            spec = {"k": (batch, seq, cfg.n_kv_heads, cfg.hd),
                    "v": (batch, seq, cfg.n_kv_heads, cfg.hd)}
        out.append({k: (n_groups,) + v for k, v in spec.items()})
    return tuple(out)


_F32_CACHE_KEYS = {"c", "n", "m", "ssm", "C"}


def cache_dtype(key: str, cfg):
    """Recurrent statistics stay f32; KV/conv caches are bf16 (matching
    the bf16 compute dtype — keeps the decode scan carry type stable)."""
    if key in _F32_CACHE_KEYS:
        return jnp.float32
    return jnp.bfloat16


def init_cache(cfg, batch: int, seq: int) -> Params:
    spec = cache_spec(cfg, batch, seq)
    out = []
    for entry in spec:
        d = {}
        for k, shape in entry.items():
            fill = -1e30 if k == "m" else 0.0
            d[k] = jnp.full(shape, fill, cache_dtype(k, cfg))
        out.append(d)
    return tuple(out)


def lm_decode_step(p: Params, cfg, token: jnp.ndarray, pos: jnp.ndarray,
                   cache) -> Tuple[jnp.ndarray, Params]:
    """One serving step. token: (B,) int32; pos: (B,) current position;
    cache as from init_cache. Returns (logits (B, vocab), new cache)."""
    dtype = jnp.bfloat16   # compute dtype: bf16 everywhere (mixed precision)
    h = embed(p["embed"], token[:, None], dtype)        # (B,1,D)
    gk = _group_kinds(cfg)

    def scan_fn(h, xs):
        gparams, gcache = xs
        new_cache = []
        for gp, kind in enumerate(gk):
            h, nc = block_decode(gparams[gp], cfg, kind, h, pos, gcache[gp])
            new_cache.append(nc)
        return h, tuple(new_cache)

    h, new_cache = lax.scan(scan_fn, h, (p["blocks"], cache))
    logits = _logits(p, cfg, h)[:, 0]
    return logits, new_cache
