"""Model registry: uniform init/forward/loss/decode entry points per
family, so the launcher and dry-run treat every arch identically."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec as encdec_mod
from . import transformer as tfm

__all__ = ["ModelAPI", "get_model"]


class ModelAPI:
    """Family-dispatched model functions (all pure)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.is_encdec = cfg.enc_layers > 0

    # -- params ------------------------------------------------------------
    def init(self, key):
        if self.is_encdec:
            return encdec_mod.init_encdec_params(key, self.cfg)
        return tfm.init_params(key, self.cfg)

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- training ------------------------------------------------------------
    def loss(self, params, batch: Dict) -> jnp.ndarray:
        if self.is_encdec:
            return encdec_mod.encdec_loss(params, self.cfg, batch)
        return tfm.lm_loss(params, self.cfg, batch)

    # -- prefill (forward, last-position logits) ----------------------------
    def prefill(self, params, batch: Dict) -> jnp.ndarray:
        if self.is_encdec:
            return encdec_mod.encdec_forward(
                params, self.cfg, batch["tokens"], batch["frontend"],
                last_only=True)
        logits, _ = tfm.lm_forward(params, self.cfg, batch["tokens"],
                                   frontend=batch.get("frontend"),
                                   last_only=True)
        return logits

    # -- decode ---------------------------------------------------------------
    def cache_spec(self, batch: int, seq: int):
        if self.is_encdec:
            return encdec_mod.encdec_cache_spec(self.cfg, batch, seq,
                                                enc_seq=seq)
        return tfm.cache_spec(self.cfg, batch, seq)

    def init_cache(self, batch: int, seq: int):
        if self.is_encdec:
            raise NotImplementedError(
                "enc-dec cache needs encoder output; use encdec_init_cache")
        return tfm.init_cache(self.cfg, batch, seq)

    def decode_step(self, params, token, pos, cache):
        if self.is_encdec:
            return encdec_mod.encdec_decode_step(params, self.cfg, token,
                                                 pos, cache)
        return tfm.lm_decode_step(params, self.cfg, token, pos, cache)


def get_model(cfg) -> ModelAPI:
    return ModelAPI(cfg)
