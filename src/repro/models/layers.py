"""Shared model layers (pure-functional JAX; params are plain dict trees)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm", "init_rmsnorm", "init_linear", "linear", "rope_freqs",
           "apply_rope", "init_mlp", "mlp", "init_embed", "embed",
           "cross_entropy"]

Params = Dict


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["scale"].astype(x.dtype)


def init_linear(key, d_in: int, d_out: int, dtype) -> Params:
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
    w = (w / jnp.sqrt(d_in)).astype(dtype)
    return {"w": w}


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


# -- RoPE -------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- MLP ---------------------------------------------------------------------

def init_mlp(key, d: int, f: int, act: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": init_linear(k1, d, f, dtype),
         "down": init_linear(k2, f, d, dtype)}
    if act == "silu":                       # SwiGLU
        p["gate"] = init_linear(k3, d, f, dtype)
    return p


def mlp(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    up = linear(p["up"], x)
    if act == "silu":
        up = jax.nn.silu(linear(p["gate"], x)) * up
    else:
        up = jax.nn.gelu(up)
    return linear(p["down"], up)


# -- embedding / unembedding ---------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype) -> Params:
    e = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"table": e.astype(dtype)}


def embed(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token-mean CE, shard-safe over a vocab-partitioned logits tensor:
    the gold logit is a masked reduction (iota==label fuses; no gather
    across the sharded vocab axis, no full f32 log-prob tensor)."""
    V = logits.shape[-1]
    lmax = jax.lax.stop_gradient(
        jnp.max(logits, axis=-1, keepdims=True)).astype(jnp.float32)
    shifted = logits.astype(jnp.float32) - lmax
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    onehot_mask = labels[..., None] == jnp.arange(V, dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot_mask, shifted, 0.0), axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
