"""GQA attention: chunked-flash for train/prefill, cached for decode.

Train/prefill use a two-level ``lax.scan`` flash formulation (q-chunks ×
kv-chunks with running (m, l, acc)): nothing larger than
(q_chunk × kv_chunk) scores is ever materialized, which is what makes
prefill_32k fit on 16 GB chips. Fully-masked kv-chunks are still visited
(static schedule) — the compiled-FLOPs overhead shows up in the roofline
waste ratio and is a documented hillclimb target.

Decode attends one new token against a seq-sharded KV cache; the softmax
over the sharded axis is expressed as plain jnp ops so GSPMD inserts the
required all-reduces (flash-decoding style combine).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import apply_rope, init_linear, linear, rmsnorm, init_rmsnorm
from .sharding_hooks import constrain

Params = Dict

__all__ = ["init_attention", "attention", "decode_attention", "AttnCache"]


def init_attention(key, cfg, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, h * hd, dtype),
        "wk": init_linear(ks[1], d, kv * hd, dtype),
        "wv": init_linear(ks[2], d, kv * hd, dtype),
        "wo": init_linear(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_rmsnorm(hd, dtype)
        p["knorm"] = init_rmsnorm(hd, dtype)
    return p


def _project_qkv(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray):
    B, S, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(p["wq"], x).reshape(B, S, h, hd)
    k = linear(p["wk"], x).reshape(B, S, kv, hd)
    v = linear(p["wv"], x).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q, cfg.norm_eps)
        k = rmsnorm(p["knorm"], k, cfg.norm_eps)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _best_chunk(seq: int, target: int) -> int:
    """Largest divisor of ``seq`` that is <= target (sequences with a
    prepended frontend stub, e.g. 4096+256 image tokens, are not
    power-of-two)."""
    c = min(target, seq)
    while seq % c:
        c -= 1
    return c


def _flash(q, k, v, q_offset: int, causal: bool, q_chunk: int, kv_chunk: int):
    """Two-level chunked attention. q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).

    GQA KV heads are repeated up to H before the chunk loops (MHA compute
    form): every chunk einsum is then purely local under (batch→data,
    heads→model) sharding — no collectives inside the scan bodies. The
    G× duplicate KV bytes are a documented baseline cost (hillclimb
    candidate: two-level GQA sharding). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    Skv, KVh = k.shape[1], k.shape[2]
    if KVh != H:                       # GQA -> MHA compute form
        # gather the (small) KV heads across the model axis first — the
        # standard GQA KV-allgather — so the repeat+head-shard below is a
        # local slice instead of an involuntary full rematerialization
        k = constrain(k, "attn_kv_full")
        v = constrain(v, "attn_kv_full")
        k = jnp.repeat(k, H // KVh, axis=2)
        v = jnp.repeat(v, H // KVh, axis=2)
    KV, G = H, 1
    scale = hd ** -0.5
    q_chunk = _best_chunk(Sq, q_chunk)
    kv_chunk = _best_chunk(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk

    # (nq, B, KV, G, qc, hd) / (nk, B, KV, kc, hd)
    qr = (q * scale).reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kr = k.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kv_chunk, KV, hd).transpose(1, 0, 3, 2, 4)
    qr = constrain(qr, "attn_chunked_q")
    kr = constrain(kr, "attn_chunked_kv")
    vr = constrain(vr, "attn_chunked_kv")

    def per_q(qi, qblock):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kblock, vblock = inp
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblock,
                           kblock).astype(jnp.float32)
            if causal:
                k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(qblock.dtype),
                vblock).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        # checkpoint the chunk step: backward recomputes the (qc×kc)
        # scores instead of saving them — this is what keeps training
        # memory flash-like (scan would otherwise stash S×S residuals)
        (m, l, acc), _ = lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)

    outs = lax.map(jax.checkpoint(lambda args: per_q(*args)),
                   (jnp.arange(nq), qr))
    # (nq, B, KV, G, qc, hd) -> (B, Sq, H, hd)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)


def attention(p: Params, cfg, x: jnp.ndarray, positions: jnp.ndarray,
              causal: bool = True, kv_override=None,
              q_chunk: int = 512, kv_chunk: int = 1024) -> jnp.ndarray:
    """Full-sequence attention (train / prefill / encoder)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    if kv_override is not None:                 # cross-attention
        k, v = kv_override
        causal = False
    out = _flash(q, k, v, 0, causal, q_chunk, kv_chunk)
    B, S = x.shape[:2]
    return linear(p["wo"], out.reshape(B, S, cfg.n_heads * cfg.hd))


# -- decode -------------------------------------------------------------------

def decode_attention(p: Params, cfg, x: jnp.ndarray, pos: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B,1,D); caches: (B,S,KV,hd); pos: (B,) current
    index. Returns (out, k_cache, v_cache)."""
    B = x.shape[0]
    S = k_cache.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = h // kv
    q, k_new, v_new = _project_qkv(p, cfg, x, pos[:, None])

    # write the new KV at position `pos` (dynamic per batch row)
    onehot = jax.nn.one_hot(pos, S, dtype=k_cache.dtype)   # (B,S)
    k_cache = k_cache * (1 - onehot[..., None, None]) + \
        onehot[..., None, None] * k_new
    v_cache = v_cache * (1 - onehot[..., None, None]) + \
        onehot[..., None, None] * v_new

    qr = q.reshape(B, kv, G, hd) * hd ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache).astype(jnp.float32)
    mask = (jnp.arange(S)[None] <= pos[:, None])           # (B,S)
    s = jnp.where(mask[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache).astype(x.dtype)
    out = out.reshape(B, 1, h * hd)
    return linear(p["wo"], out), k_cache, v_cache
