"""Version compatibility shims for the JAX surface this repo touches.

Two APIs moved/changed shape across the JAX versions we support:

* ``shard_map`` — exported as ``jax.shard_map`` on newer releases, lives
  in ``jax.experimental.shard_map`` on older ones (e.g. 0.4.x).  Import
  :func:`shard_map` from here everywhere instead of touching ``jax``
  directly.
* ``Compiled.cost_analysis()`` — returns a single dict on new JAX, a
  per-computation *list* of dicts on older releases.  Use
  :func:`cost_analysis_dict` to always get one flat dict.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

try:  # JAX >= 0.4.35 with the top-level export
    shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # older JAX: experimental home
    from jax.experimental.shard_map import shard_map  # type: ignore

__all__ = ["shard_map", "cost_analysis_dict"]


def cost_analysis_dict(compiled: Any) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a single flat dict
    (older JAX returns a list with one entry per computation)."""
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        merged: Dict[str, float] = {}
        for entry in cost:
            for k, v in (entry or {}).items():
                if isinstance(v, (int, float)):
                    merged[k] = merged.get(k, 0.0) + float(v)
                else:
                    merged.setdefault(k, v)
        return merged
    return dict(cost)
