#!/usr/bin/env python
"""Record this checkout's benchmark rows in the machine-readable perf
trajectory at the repo root (``BENCH_pselinv.json``). Idempotent per
``--rev``: re-running replaces that rev's entry in place (a repeated
verify run no longer stacks duplicate trajectory rows).

Part of the verify flow (see ``.claude/skills/verify/SKILL.md``): run
once per PR so every change lands a ``us_per_call`` row per bench and
regressions are visible across the PR stack:

    PYTHONPATH=src python tools/record_bench.py [--full] \\
        [--only selinv] [--rev PR2]

The trajectory file is a JSON list of ``{"rev", "benches", "failed"}``
entries, one per recorded run; ``benches`` rows are the driver's
``{name, us_per_call, derived}`` dicts (`benchmarks/common.RESULTS`).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def validate_rows(rows, *, where: str) -> None:
    """Schema-check one session's bench rows before they land in the
    trajectory: every row must be a dict with a ``name`` string and a
    numeric ``us_per_call`` — a malformed row fails the recording run
    instead of silently poisoning downstream tooling."""
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or not isinstance(
                row.get("name"), str) or not row["name"]:
            raise SystemExit(
                f"[bench] {where}: row {i} has no 'name' string: {row!r}")
        if not isinstance(row.get("us_per_call"), (int, float)) \
                or isinstance(row["us_per_call"], bool):
            raise SystemExit(
                f"[bench] {where}: row {i} ({row['name']}) has no "
                f"numeric 'us_per_call': {row.get('us_per_call')!r}")


def validate_history(hist) -> None:
    """Schema-check the merged trajectory before writing it back: rev
    labels unique, every entry's rows well-formed."""
    seen = set()
    for entry in hist:
        rev = entry.get("rev")
        if rev in seen:
            raise SystemExit(
                f"[bench] trajectory has duplicate rev {rev!r} — the "
                "idempotent replace-in-place path was bypassed")
        seen.add(rev)
        validate_rows(entry.get("benches", []), where=f"rev {rev}")


def preflight_pycache() -> None:
    """Hygiene gate before a recording run: ``.gitignore`` must cover
    bytecode caches, none may be git-tracked, and stray ones in the
    working tree are swept (they regenerate; a stale ``.pyc`` shadowing
    an edited module is exactly the artifact a perf trajectory must not
    measure)."""
    import shutil

    gi = os.path.join(ROOT, ".gitignore")
    patterns = set()
    if os.path.exists(gi):
        with open(gi) as f:
            patterns = {ln.strip() for ln in f}
    missing = {"__pycache__/", "*.pyc"} - patterns
    if missing:
        raise SystemExit(f"[bench] .gitignore does not cover "
                         f"{sorted(missing)} — add the pattern(s) before "
                         f"recording")
    try:
        tracked = subprocess.run(
            ["git", "ls-files", "--", "*.pyc", "**/__pycache__/**"],
            cwd=ROOT, capture_output=True, text=True,
            check=True).stdout.split()
    except Exception:
        tracked = []
    if tracked:
        raise SystemExit(f"[bench] bytecode artifacts are git-tracked: "
                         f"{tracked[:5]} — `git rm --cached` them first")
    swept = 0
    for dirpath, dirnames, filenames in os.walk(ROOT):
        if ".git" in dirnames:
            dirnames.remove(".git")
        if "__pycache__" in dirnames:
            shutil.rmtree(os.path.join(dirpath, "__pycache__"),
                          ignore_errors=True)
            dirnames.remove("__pycache__")
            swept += 1
        for fn in filenames:
            if fn.endswith(".pyc"):
                try:
                    os.unlink(os.path.join(dirpath, fn))
                    swept += 1
                except OSError:
                    pass
    if swept:
        print(f"[bench] preflight swept {swept} bytecode cache artifact(s)")


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT,
            capture_output=True, text=True, check=True).stdout.strip()
    except Exception:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="selinv",
                    help="comma list forwarded to benchmarks.run")
    ap.add_argument("--rev", default=None,
                    help="label for this entry (default: git short rev)")
    ap.add_argument("--out",
                    default=os.path.join(ROOT, "BENCH_pselinv.json"))
    args = ap.parse_args()

    preflight_pycache()
    fd, tmp = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", "")).rstrip(os.pathsep)
    cmd = [sys.executable, "-m", "benchmarks.run",
           "--only", args.only, "--json", tmp]
    if args.full:
        cmd.append("--full")
    r = subprocess.run(cmd, cwd=ROOT, env=env)
    # the driver writes the JSON (with its `failed` bench names) even
    # when it exits non-zero — record the partial session so the
    # trajectory shows the regression instead of silently skipping it
    try:
        with open(tmp) as f:
            session = json.load(f)
    except (OSError, json.JSONDecodeError):
        if r.returncode:
            raise SystemExit(r.returncode)
        raise
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)

    # the engine-era selinv bench must land its batched-throughput,
    # structure-cache and round-stream rows
    # (`selinv/solve_batched_us_per_matrix_b{1,4,16}`,
    # `selinv/engine_cache_hits`, `selinv/stream_{compile_ms,hlo_bytes,
    # us_per_call,wire_bytes,shifts_per_round}`) — fail loudly if a
    # refactor drops them from the trajectory instead of silently
    # recording a thinner entry
    validate_rows(session["benches"], where="session")
    if "selinv" in args.only.split(",") and "selinv" not in session["failed"]:
        names = {row["name"] for row in session["benches"]}
        need = ({f"selinv/solve_batched_us_per_matrix_b{B}"
                 for B in (1, 4, 16)}
                | {"selinv/engine_cache_hits", "selinv/stream_compile_ms",
                   "selinv/stream_hlo_bytes", "selinv/stream_us_per_call",
                   "selinv/stream_wire_bytes",
                   "selinv/stream_shifts_per_round",
                   "selinv/plan_lint_ms", "selinv/bigmesh_8x4_lint_ms",
                   "selinv/hlo_lint_ms",
                   # the serving layer's scorecard (PR 9): coalesced
                   # latency, throughput and bucket occupancy
                   "selinv/serve_p50_us",
                   "selinv/serve_throughput_rps",
                   "selinv/serve_batch_occupancy",
                   # the SweepScope scorecard (PR 10): tracing tax on
                   # the solve hot path + measured round timeline
                   "selinv/trace_overhead_pct",
                   "selinv/round_p95_us",
                   "selinv/inbound_skew_ratio"})
        missing = sorted(need - names)
        if missing:
            raise SystemExit(
                f"[bench] selinv session is missing required engine "
                f"rows: {missing}")

    hist = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            hist = json.load(f)
    rev = args.rev or git_rev()
    entry = {"rev": rev, "benches": session["benches"],
             "failed": session["failed"]}
    # idempotent verify flow: re-running with the same --rev replaces
    # that rev's entry in place instead of stacking duplicate rows
    for i, h in enumerate(hist):
        if h.get("rev") == rev:
            hist[i] = entry
            action = f"replaced rev {rev}"
            break
    else:
        hist.append(entry)
        action = f"appended rev {rev}"
    validate_history(hist)
    with open(args.out, "w") as f:
        json.dump(hist, f, indent=1)
        f.write("\n")
    print(f"[bench] {action} ({len(session['benches'])} rows) in "
          f"{os.path.relpath(args.out, ROOT)}; history={len(hist)} entries")
    if r.returncode:
        raise SystemExit(r.returncode)   # recorded, but still a failure


if __name__ == "__main__":
    main()
