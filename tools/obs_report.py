#!/usr/bin/env python
"""SweepScope CLI — trace a structure corpus, profile its sweep rounds,
and emit a Chrome-trace/Perfetto file plus the inbound-imbalance table.

For each (structure, grid) case the tool runs the full observed
pipeline on real (host-simulated) devices:

- enables the global span tracer (``repro.obs.trace``) and runs
  ``PSelInvEngine.analyze`` → ``prepare_values`` → ``solve`` so the
  host-side spans (symbolic → plan → lower → compile, factorization,
  dispatch) land in the buffer;
- replays the sweep through ``engine.profile_rounds()`` — the
  per-round segmented re-execution with ``block_until_ready`` fencing —
  joining measured walls against the plan wire tables and the α-β
  simulator;
- writes everything (span lanes, round timeline with per-rank inbound
  bytes, optional serve request lifecycles) to one ``*.trace.json``
  loadable in ``chrome://tracing`` / `ui.perfetto.dev`;
- prints ``RoundProfile.report()`` — the per-round timeline and the
  per-rank inbound bytes/messages/attributed-time skew table,
  cross-checked against PlanLint's static ``load/imbalance`` WARN
  threshold.

Exits non-zero iff any case's measured inbound-byte skew ratio
(max rank / mean rank) exceeds ``--skew-threshold`` (default: the
PlanLint static threshold, ``verify.IMBALANCE_MAX``).

    PYTHONPATH=src python tools/obs_report.py                # nb=16 4x2
    PYTHONPATH=src python tools/obs_report.py --nb 32 --grid 4x2
    PYTHONPATH=src python tools/obs_report.py --chunk 4 --serve 24
    PYTHONPATH=src python tools/obs_report.py -o sweep.trace.json

Needs ``pr*pc`` devices; when the host has fewer the tool re-execs
itself under ``XLA_FLAGS=--xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))


def _reexec(ndev: int, argv) -> int:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={ndev}"
                        ).strip()
    env["PYTHONPATH"] = os.path.join(_ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env["_OBS_REPORT_CHILD"] = "1"
    r = subprocess.run([sys.executable, os.path.abspath(__file__)]
                       + list(argv), env=env, cwd=_ROOT)
    return r.returncode


def _serve_lanes(n: int):
    """Optional serve corpus: push ``n`` mixed-structure requests
    through a worker-threaded SelInvServer (Grid(1,1) — structure
    coalescing, not mesh parallelism) and return the completed request
    objects for the exporter's lifecycle lanes."""
    import scipy.sparse as sp

    from repro.core import sparse
    from repro.core.engine import Grid
    from repro.serve.batcher import BatchWindow
    from repro.serve.server import SelInvServer, ServeConfig

    mats = [sp.csr_matrix(sparse.laplacian_2d(nx, 4) +
                          sp.eye(nx * 4) * 0.1) for nx in (8, 12)]
    cfg = ServeConfig(b=4, grid=Grid(1, 1),
                      window=BatchWindow(max_batch=8, max_wait_ms=2.0))
    with SelInvServer(cfg) as srv:
        reqs = [srv.submit(mats[i % len(mats)]) for i in range(n)]
        srv.drain(timeout=120.0)
        for r in reqs:
            r.result(timeout=120.0)
        return srv.recent_requests()


def run_case(nb: int, pr: int, pc: int, *, chunk: int, reps: int,
             serve: int, out: str, skew_threshold: float) -> int:
    import scipy.sparse as sp

    import jax

    from repro.core import sparse
    from repro.core.engine import Grid, PSelInvEngine
    from repro.obs.export import write_trace
    from repro.obs.trace import TRACER

    TRACER.enable()
    A = sp.csr_matrix(sparse.laplacian_2d(nb, 8))
    eng = PSelInvEngine.analyze(A, b=8, grid=Grid(pr, pc))
    vals = eng.prepare_values(A)
    jax.block_until_ready(eng.solve(vals))     # warm + span-recorded

    profile = eng.profile_rounds(vals, chunk=chunk, reps=reps)
    requests = _serve_lanes(serve) if serve else None
    TRACER.disable()

    write_trace(out, spans=TRACER.spans(), profile=profile,
                requests=requests)
    with open(out) as f:
        nev = len(json.load(f)["traceEvents"])
    print(f"[obs-report] laplacian_2d({nb},8) b=8 grid {pr}x{pc}: "
          f"{len(TRACER.spans())} span(s), {profile.nrounds} round(s)"
          + (f", {len(requests)} request(s)" if requests else ""))
    print(f"[obs-report] wrote {out} ({nev} trace events)")
    print()
    print(profile.report())

    skew = profile.skew()
    ratio = skew["skew_ratio"]
    if ratio > skew_threshold:
        print(f"[obs-report] FAIL: measured inbound-byte skew "
              f"{ratio:.2f}x exceeds threshold {skew_threshold:.2f}x")
        return 1
    print(f"[obs-report] OK: measured inbound-byte skew {ratio:.2f}x "
          f"<= threshold {skew_threshold:.2f}x")
    return 0


def main(argv=None) -> int:
    from repro.core.verify import IMBALANCE_MAX

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nb", type=int, default=16,
                    help="supernode grid size: laplacian_2d(nb, 8) at "
                         "b=8 (default 16)")
    ap.add_argument("--grid", default="4x2",
                    help="PRxPC process grid (default 4x2)")
    ap.add_argument("--chunk", type=int, default=1,
                    help="rounds per jitted replay segment (default 1)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed replay passes, per-segment min kept "
                         "(default 3)")
    ap.add_argument("--serve", type=int, default=0,
                    help="additionally run N requests through a "
                         "SelInvServer and export their lifecycle "
                         "lanes (default 0 = skip)")
    ap.add_argument("-o", "--out", default="selinv.trace.json",
                    help="output trace path (default selinv.trace.json)")
    ap.add_argument("--skew-threshold", type=float, default=IMBALANCE_MAX,
                    help="fail when measured max/mean inbound-byte skew "
                         "exceeds this ratio (default: PlanLint's "
                         f"static IMBALANCE_MAX = {IMBALANCE_MAX})")
    args = ap.parse_args(argv)
    pr, pc = (int(x) for x in args.grid.lower().split("x"))

    import jax
    if len(jax.devices()) < pr * pc:
        if os.environ.get("_OBS_REPORT_CHILD"):
            print(f"[obs-report] need {pr * pc} devices, have "
                  f"{len(jax.devices())} even after re-exec",
                  file=sys.stderr)
            return 2
        return _reexec(pr * pc, sys.argv[1:])

    return run_case(args.nb, pr, pc, chunk=args.chunk, reps=args.reps,
                    serve=args.serve, out=args.out,
                    skew_threshold=args.skew_threshold)


if __name__ == "__main__":
    raise SystemExit(main())
