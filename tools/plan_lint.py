#!/usr/bin/env python
"""PlanLint CLI — run the static schedule verifier (``core/verify.py``)
over a generated structure corpus and report every diagnostic.

Lints each (structure, grid) case through every lowering the stack
ships — the CommPlan IR, the level-serial ExecPlan, the overlapped
round stream (with and without a Û liveness window), and the gated
stream tables under both ``axis_factored`` settings — entirely
host-side (no devices needed, an 8×4 corpus lints in seconds):

    PYTHONPATH=src python tools/plan_lint.py            # default corpus
    PYTHONPATH=src python tools/plan_lint.py --grid 8x4 --nb 32
    PYTHONPATH=src python tools/plan_lint.py -v         # per-case report
    PYTHONPATH=src python tools/plan_lint.py --compiled # + HloLint

``--compiled`` chains the HloLint compiled-artifact verifier
(``tools/hlo_lint.py`` / ``core/hlo_verify.py``) over the same corpus:
each executor lowering is traced on an abstract mesh and its jaxpr /
StableHLO layers cross-checked against the plan tables — still no
devices required.

Exits non-zero iff any case produces an ERROR-severity diagnostic —
the CI contract "every lowered program passes PlanLint and (with
``--compiled``) HloLint".
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import scipy.sparse as sp_mod                                  # noqa: E402

from repro.core import sparse, verify                          # noqa: E402
from repro.core.plan import (TreeKind, build_plan, compile_exec,  # noqa: E402
                             schedule_overlapped)
from repro.core.schedule import Grid2D                         # noqa: E402
from repro.core.stream import lower_stream                     # noqa: E402
from repro.core.symbolic import symbolic_factorize             # noqa: E402

#: default corpus: (nx, ny, nb, pr, pc) — the shipped plan shapes (the
#: tier-1 nb=16/32 structures at grid 4×2, plus the 8×4 bigmesh case)
DEFAULT_CORPUS = [
    (16, 8, 16, 4, 2),
    (32, 8, 32, 4, 2),
    (32, 8, 32, 8, 4),
]


def lint_case(nx: int, ny: int, nb: int, pr: int, pc: int, *,
              windows=(None, 1), verbose: bool = False):
    """Lint every lowering of one (structure, grid) case. Returns
    (n_errors, n_warnings, n_artifacts)."""
    bs = symbolic_factorize(
        sp_mod.csr_matrix(sparse.laplacian_2d(nx, ny)), max_supernode=8)
    plan = build_plan(bs, Grid2D(pr, pc), TreeKind.SHIFTED, nb=nb)
    artifacts = [("plan", verify.check_plan(plan)),
                 ("exec", verify.check_exec(compile_exec(plan)))]
    for w in windows:
        ov = schedule_overlapped(plan, window=w)
        artifacts.append((f"overlap(window={w})",
                          verify.check_overlap(ov, plan)))
        for af in (True, False):
            st = lower_stream(ov, axis_factored=af)
            artifacts.append(
                (f"stream(window={w}, axis_factored={af})",
                 verify.check_stream(st, plan)))
    nerr = nwarn = 0
    case = f"laplacian_2d({nx},{ny}) nb={nb} grid {pr}x{pc}"
    for what, diags in artifacts:
        errs = [d for d in diags if d.severity == "error"]
        warns = [d for d in diags if d.severity == "warn"]
        nerr += len(errs)
        nwarn += len(warns)
        if errs or warns or verbose:
            print(f"  {case} :: {what}: "
                  f"{len(errs)} error(s), {len(warns)} warning(s)")
        for d in errs + warns:
            print(f"    {d}")
    return nerr, nwarn, len(artifacts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", default=None,
                    help="lint one PRxPC grid (e.g. 8x4) instead of the "
                         "default corpus")
    ap.add_argument("--nb", type=int, default=32,
                    help="supernode blocking for --grid (default 32)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="report clean artifacts too")
    ap.add_argument("--compiled", action="store_true",
                    help="additionally run the HloLint compiled-"
                         "artifact verifier (tools/hlo_lint.py) over "
                         "the same corpus")
    args = ap.parse_args(argv)

    if args.grid:
        pr, pc = (int(x) for x in args.grid.lower().split("x"))
        corpus = [(args.nb, 8, args.nb, pr, pc)]
    else:
        corpus = DEFAULT_CORPUS

    t0 = time.time()
    nerr = nwarn = narts = 0
    for (nx, ny, nb, pr, pc) in corpus:
        e, w, a = lint_case(nx, ny, nb, pr, pc, verbose=args.verbose)
        nerr += e
        nwarn += w
        narts += a
    status = "FAIL" if nerr else "OK"
    print(f"[plan-lint] {status}: {narts} artifact(s) across "
          f"{len(corpus)} case(s) — {nerr} error(s), {nwarn} warning(s) "
          f"in {time.time() - t0:.1f}s")
    if args.compiled:
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        import hlo_lint
        ce = cw = cp = 0
        for (nx, ny, nb, pr, pc) in corpus:
            e, w, p = hlo_lint.lint_case(nx, ny, nb, pr, pc,
                                         verbose=args.verbose)
            ce += e
            cw += w
            cp += p
        cstatus = "FAIL" if ce else "OK"
        print(f"[hlo-lint] {cstatus}: {cp} compiled program(s) across "
              f"{len(corpus)} case(s) — {ce} error(s), {cw} warning(s)")
        nerr += ce
    return 1 if nerr else 0


if __name__ == "__main__":
    raise SystemExit(main())
