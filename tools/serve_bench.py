#!/usr/bin/env python
"""Serving-layer benchmark: synthetic mixed-structure Poisson traffic
through :class:`repro.serve.SelInvServer`.

Runs the full acceptance harness (``repro.serve.traffic.run_traffic``):
cold pass → one-compile-per-(structure, bucket) conformance off the
engine trace counters → warm timed pass → warm sequential baseline over
the same matrices → f64 identity check — then prints the serving
scorecard. Run it on a real mesh with f64 enabled:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    JAX_ENABLE_X64=1 PYTHONPATH=src \\
        python tools/serve_bench.py --grid 4x2 [--requests 120] \\
            [--structures 2] [--rate 4000] [--burst] [--json out.json]

``benchmarks/pselinv_bench.py`` drives the same harness in-process for
the recorded trajectory rows; this CLI is the standalone knob-turning
entry point.
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser(
        description="mixed-structure serving benchmark")
    ap.add_argument("--requests", type=int, default=120,
                    help="trace length (acceptance floor: 100)")
    ap.add_argument("--structures", type=int, default=2,
                    help="distinct block structures in the mix (>= 2)")
    ap.add_argument("--rate", type=float, default=4000.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--burst", action="store_true",
                    help="submit with zero gaps instead of Poisson")
    ap.add_argument("--grid", default="1x1",
                    help="process grid PRxPC (e.g. 4x2; needs PR*PC "
                         "devices)")
    ap.add_argument("--b", type=int, default=8, help="supernode width")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--pressure", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=1,
                    help="repeat each timed pass, keep the best wall "
                         "(steadies ratios on shared hosts)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless coalesced serving beats the "
                         "sequential baseline by this factor")
    ap.add_argument("--json", default=None,
                    help="also dump the full result dict to this path")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.core.engine import Grid
    from repro.serve.batcher import BatchWindow
    from repro.serve.traffic import run_traffic

    pr, pc = (int(x) for x in args.grid.lower().split("x"))
    if jax.config.jax_enable_x64:
        dtype, tol, check = jnp.float64, 1e-12, True
    else:
        print("[serve-bench] x64 disabled — skipping the f64 identity "
              "check (set JAX_ENABLE_X64=1)", flush=True)
        dtype, tol, check = jnp.float32, 1e-4, True

    res = run_traffic(
        n_requests=args.requests, n_structures=args.structures,
        rate_hz=(None if args.burst else args.rate), seed=args.seed,
        b=args.b, grid=Grid(pr, pc),
        window=BatchWindow(max_batch=args.max_batch,
                           max_wait_ms=args.max_wait_ms,
                           pressure=args.pressure),
        dtype=dtype, check_identity=check, tol=tol, reps=args.reps,
        log=lambda s: print(f"[serve-bench] {s}", flush=True))

    print(f"[serve-bench] {res['n_requests']} requests, "
          f"{res['n_structures']} structures, grid {pr}x{pc}")
    print(f"  serve:    {res['serve_per_matrix_us']:9.1f} us/matrix  "
          f"({res['serve_throughput_rps']:.0f} rps, "
          f"{res['batches']} batches, occupancy "
          f"{res['serve_batch_occupancy']:.2f})")
    print(f"  baseline: {res['baseline_per_matrix_us']:9.1f} us/matrix")
    print(f"  speedup:  {res['speedup']:9.2f}x")
    print(f"  latency:  p50 {res['serve_p50_us']:.0f} us   p95 "
          f"{res['serve_p95_us']:.0f} us   p99 "
          f"{res['serve_p99_us']:.0f} us")
    print(f"  identity: max |serve - unbatched| = "
          f"{res['identity_max_abs']:.2e} (tol {tol:g})")
    print(f"  compiles: "
          + "  ".join(f"{k}: {t} traces / {b} buckets"
                      for k, (t, b) in res["conformance"].items()))

    if args.json:
        with open(args.json, "w") as f:
            json.dump({k: v for k, v in res.items() if k != "stats"},
                      f, indent=1, default=str)
        print(f"[serve-bench] wrote {args.json}")

    if args.min_speedup and res["speedup"] < args.min_speedup:
        print(f"[serve-bench] FAIL: speedup {res['speedup']:.2f}x < "
              f"{args.min_speedup}x", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
