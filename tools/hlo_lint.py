#!/usr/bin/env python
"""HloLint CLI — run the compiled-artifact verifier
(``core/hlo_verify.py``) over a generated structure corpus: every
shipped executor lowering (level-serial, overlapped, gated stream under
both ``axis_factored`` settings) is traced and lowered on an abstract
mesh and its jaxpr / StableHLO layers are cross-checked against the
plan tables — permute conformance, loop trip counts, wire-byte
conservation, hot-path hygiene. No physical devices are needed (the
8×4 corpus case lints on a single-CPU host):

    PYTHONPATH=src python tools/hlo_lint.py             # default corpus
    PYTHONPATH=src python tools/hlo_lint.py --grid 8x4 --nb 32
    PYTHONPATH=src python tools/hlo_lint.py --compile   # + optimized HLO
    PYTHONPATH=src python tools/hlo_lint.py -v          # per-case report

``--compile`` additionally runs a real XLA compile per case and lints
the optimized HLO (the program XLA actually runs) — the XLA_FLAGS
assignment below provisions enough host devices for every corpus grid
and MUST stay before any other import (jax locks the device count at
first init).

Exits non-zero iff any case produces an ERROR-severity diagnostic —
the CI contract "every lowered program passes PlanLint AND HloLint".
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=32")

import argparse                                                # noqa: E402
import sys                                                     # noqa: E402
import time                                                    # noqa: E402

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import scipy.sparse as sp_mod                                  # noqa: E402

from repro.core import hlo_verify, sparse                      # noqa: E402
from repro.core.plan import PlanOptions                        # noqa: E402
from repro.core.pselinv_dist import build_program              # noqa: E402
from repro.core.symbolic import symbolic_factorize             # noqa: E402

#: default corpus: (nx, ny, nb, pr, pc) — the shipped plan shapes,
#: same as ``tools/plan_lint.py``
DEFAULT_CORPUS = [
    (16, 8, 16, 4, 2),
    (32, 8, 32, 4, 2),
    (32, 8, 32, 8, 4),
]

#: the executor lowerings every case lints at the compiled layer
EXECUTORS = [
    ("exec", PlanOptions(overlap=False)),
    ("overlap", PlanOptions(overlap=True)),
    ("stream", PlanOptions(stream=True)),
    ("stream(axis_factored=False)",
     PlanOptions(stream=True, axis_factored=False)),
]


def pad_to_grid(nb: int, pr: int, pc: int) -> int:
    from repro.core.pselinv_dist import pad_nb
    return pad_nb(nb, pr, pc)


def lint_case(nx: int, ny: int, nb: int, pr: int, pc: int, *,
              compile: bool = False, verbose: bool = False):
    """HloLint every executor lowering of one (structure, grid) case.
    Returns (n_errors, n_warnings, n_programs)."""
    bs = symbolic_factorize(
        sp_mod.csr_matrix(sparse.laplacian_2d(nx, ny)), max_supernode=8)
    nbp = pad_to_grid(bs.nsuper, pr, pc)
    nerr = nwarn = 0
    case = f"laplacian_2d({nx},{ny}) nb={nbp} grid {pr}x{pc}"
    for what, opts in EXECUTORS:
        prog = build_program(bs, nbp, 8, pr, pc, options=opts)
        diags = hlo_verify.lint_program(prog, compile=compile)
        errs = [d for d in diags if d.severity == "error"]
        warns = [d for d in diags if d.severity == "warn"]
        nerr += len(errs)
        nwarn += len(warns)
        if errs or warns or verbose:
            print(f"  {case} :: {what}: "
                  f"{len(errs)} error(s), {len(warns)} warning(s)")
        for d in errs + warns:
            print(f"    {d}")
    return nerr, nwarn, len(EXECUTORS)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", default=None,
                    help="lint one PRxPC grid (e.g. 8x4) instead of the "
                         "default corpus")
    ap.add_argument("--nb", type=int, default=32,
                    help="supernode blocking for --grid (default 32)")
    ap.add_argument("--compile", action="store_true",
                    help="additionally XLA-compile each program and "
                         "lint the optimized HLO (needs pr*pc devices)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="report clean programs too")
    args = ap.parse_args(argv)

    if args.grid:
        pr, pc = (int(x) for x in args.grid.lower().split("x"))
        corpus = [(args.nb, 8, args.nb, pr, pc)]
    else:
        corpus = DEFAULT_CORPUS

    t0 = time.time()
    nerr = nwarn = nprog = 0
    for (nx, ny, nb, pr, pc) in corpus:
        e, w, p = lint_case(nx, ny, nb, pr, pc, compile=args.compile,
                            verbose=args.verbose)
        nerr += e
        nwarn += w
        nprog += p
    status = "FAIL" if nerr else "OK"
    print(f"[hlo-lint] {status}: {nprog} compiled program(s) across "
          f"{len(corpus)} case(s) — {nerr} error(s), {nwarn} warning(s) "
          f"in {time.time() - t0:.1f}s")
    return 1 if nerr else 0


if __name__ == "__main__":
    raise SystemExit(main())
