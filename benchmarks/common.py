"""Shared benchmark utilities."""
from __future__ import annotations

import os
import re
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

#: every csv_row lands here so ``run.py --json`` can persist the whole
#: session machine-readably (perf-trajectory tracking)
RESULTS: list = []


def ensure_out() -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return OUT_DIR


def timed(fn, *args, reps: int = 3, best: bool = False, **kw):
    """Warm up once, then time ``reps`` calls. ``best=True`` returns the
    fastest rep instead of the mean — use it for asserted ratios, where
    a scheduler hiccup inflating one rep must not flip the verdict (the
    min is the standard low-interference estimate of the code's speed;
    the mean stays the default for recorded throughput rows)."""
    fn(*args, **kw)                      # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    dt = min(ts) if best else sum(ts) / reps
    return out, dt


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append({"name": name, "us_per_call": float(us_per_call),
                    "derived": derived})
    print(line, flush=True)
    return line


_ROW_NAME = re.compile(r"^[\w./-]+$")


def reemit_child_rows(stdout: str) -> None:
    """Re-record ``name,us,derived`` rows printed by a re-exec'd child
    bench process through :func:`csv_row` (so --json captures them).
    Only lines whose name field looks like a bench id are recorded —
    library warnings with commas pass through verbatim."""
    for line in stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3 and _ROW_NAME.match(parts[0]):
            try:
                us = float(parts[1])
            except ValueError:
                print(line, flush=True)
                continue
            csv_row(parts[0], us, parts[2])
        elif line.strip():
            print(line, flush=True)
