"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def ensure_out() -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return OUT_DIR


def timed(fn, *args, reps: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
