"""Paper Figs 4–7: communication-volume heat maps (per-rank volume laid
out on the Pr×Pc grid) + distribution histograms, for Col-Bcast (sent)
and Row-Reduce (received), per tree scheme. Emits CSV grids."""
from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import sparse
from repro.core.schedule import Grid2D
from repro.core.simulator import volumes_fast
from repro.core.symbolic import symbolic_factorize_elements
from repro.core.trees import TreeKind

from .common import csv_row, ensure_out


def run(full: bool = False):
    dims = (32, 32, 32) if full else (20, 20, 20)
    G, sizes = sparse.fem3d_like_structure(*dims, 3)
    bs = symbolic_factorize_elements(G, sizes, max_supernode=12)
    out = ensure_out()

    # Fig 5 (4096 ranks) and Fig 6 (256 ranks, flat — imbalance shrinks)
    for grid, kinds, tag in [
        (Grid2D(64, 64), (TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED),
         "fig5"),
        (Grid2D(16, 16), (TreeKind.FLAT,), "fig6"),
    ]:
        for kind in kinds:
            t0 = time.perf_counter()
            v = volumes_fast(bs, grid, kind)
            dt = time.perf_counter() - t0
            for op, key in [("colbcast", "col-bcast"),
                            ("rowreduce", "row-reduce")]:
                gridvals = v[key].reshape(grid.pr, grid.pc) / 1e6
                path = os.path.join(out, f"{tag}_{kind.value}_{op}.csv")
                np.savetxt(path, gridvals, delimiter=",", fmt="%.3f")
            rel = v["col-bcast"].std() / max(v["col-bcast"].mean(), 1e-12)
            csv_row(f"{tag}/{kind.value}", dt * 1e6,
                    f"relstd={rel:.3f} ranks={grid.size}")
    return True


if __name__ == "__main__":
    run(full=True)
