"""Paper Fig 8: strong scaling of PSelInv with Flat / Binary / Shifted
trees on both matrix classes, plus run-to-run variability from network
inhomogeneity (jittered per-node-pair bandwidths). Discrete-event
simulation on the Edison-like model.

Validation targets: flat-tree scalability stalls around ~1k ranks;
shifted keeps improving to 6400 with multi-× speedup over flat at scale;
shifted's run-to-run σ is lower than flat's."""
from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import sparse
from repro.core.schedule import Grid2D
from repro.core.simulator import NetworkModel, simulate
from repro.core.symbolic import symbolic_factorize_elements
from repro.core.trees import TreeKind

from .common import csv_row, ensure_out

GRIDS = {256: (16, 16), 1024: (32, 32), 4096: (64, 64), 6400: (80, 80)}


def matrices(full: bool):
    if full:
        return {
            "dg_like": sparse.dg_like_structure(36, 36, 12),
            "fem_like": sparse.fem3d_like_structure(24, 24, 24, 3),
        }, {"dg_like": 36, "fem_like": 12}
    return {
        "dg_like": sparse.dg_like_structure(24, 24, 12),
        "fem_like": sparse.fem3d_like_structure(16, 16, 16, 3),
    }, {"dg_like": 36, "fem_like": 12}


def run(full: bool = False, seeds=(0, 1, 2)):
    out = ensure_out()
    mats, caps = matrices(full)
    rows = []
    summary = {}
    for mname, (G, sizes) in mats.items():
        bs = symbolic_factorize_elements(G, sizes,
                                         max_supernode=caps[mname])
        for P, (pr, pc) in GRIDS.items():
            grid = Grid2D(pr, pc)
            for kind in (TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED):
                times = []
                t0 = time.perf_counter()
                for seed in seeds:
                    model = NetworkModel(jitter_sigma=0.3,
                                         placement_seed=seed)
                    res = simulate(bs, grid, kind, model)
                    times.append(res.total_time)
                dt = time.perf_counter() - t0
                mean, std = float(np.mean(times)), float(np.std(times))
                rows.append([mname, P, kind.value, mean, std])
                summary[(mname, P, kind.value)] = mean
                csv_row(f"fig8/{mname}/p{P}/{kind.value}", dt * 1e6,
                        f"simtime={mean:.4f}s runstd={std:.4f}")

    with open(os.path.join(out, "fig8_scaling.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["matrix", "ranks", "tree", "sim_time_s", "run_std_s"])
        w.writerows(rows)

    for mname in mats:
        sp = {P: summary[(mname, P, "flat")]
              / summary[(mname, P, "shifted")] for P in GRIDS}
        csv_row(f"fig8/{mname}/speedup_shifted_vs_flat", 0.0,
                " ".join(f"p{P}={v:.2f}x" for P, v in sp.items()))
    return summary


if __name__ == "__main__":
    run(full=True)
