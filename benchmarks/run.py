"""Benchmark driver — one module per paper table/figure plus kernel and
system microbenches. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json PATH]

``--full`` uses paper-scale matrices (minutes); default sizes finish in
~2-4 minutes on one CPU core. ``--json BENCH_pselinv.json`` additionally
writes every row ({name, us_per_call, derived}) as JSON so the perf
trajectory is machine-readable across PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all rows as JSON (e.g. BENCH_pselinv.json)")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig5,fig8,fig9,kernels,"
                         "selinv,treecomm")
    args = ap.parse_args()

    from . import (fig5_heatmap, fig8_scaling, fig9_ratio, kernels_bench,
                   pselinv_bench, table1_volume, treecomm_bench)

    benches = {
        "table1": table1_volume.run,
        "fig5": fig5_heatmap.run,
        "fig8": fig8_scaling.run,
        "fig9": fig9_ratio.run,
        "kernels": kernels_bench.run,
        "selinv": pselinv_bench.run,
        "treecomm": treecomm_bench.run,
    }
    selected = (args.only.split(",") if args.only else list(benches))

    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            benches[name](full=args.full)
        except Exception as e:
            traceback.print_exc()
            failed.append((name, repr(e)))
    if args.json:
        import json

        from .common import RESULTS
        with open(args.json, "w") as f:
            json.dump({"benches": RESULTS,
                       "failed": [n for n, _ in failed]}, f, indent=2)
        print(f"[bench] wrote {len(RESULTS)} rows to {args.json}",
              file=sys.stderr)
    if failed:
        for name, err in failed:
            print(f"{name},FAILED,{err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
