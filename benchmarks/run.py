"""Benchmark driver — one module per paper table/figure plus kernel and
system microbenches. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full]

``--full`` uses paper-scale matrices (minutes); default sizes finish in
~2-4 minutes on one CPU core.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig5,fig8,fig9,kernels,"
                         "selinv,treecomm")
    args = ap.parse_args()

    from . import (fig5_heatmap, fig8_scaling, fig9_ratio, kernels_bench,
                   pselinv_bench, table1_volume, treecomm_bench)

    benches = {
        "table1": table1_volume.run,
        "fig5": fig5_heatmap.run,
        "fig8": fig8_scaling.run,
        "fig9": fig9_ratio.run,
        "kernels": kernels_bench.run,
        "selinv": pselinv_bench.run,
        "treecomm": treecomm_bench.run,
    }
    selected = (args.only.split(",") if args.only else list(benches))

    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            benches[name](full=args.full)
        except Exception as e:
            traceback.print_exc()
            failed.append((name, repr(e)))
    if failed:
        for name, err in failed:
            print(f"{name},FAILED,{err}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
