"""Selected-inversion numeric benchmark: numpy vs jax vs pallas backends
(the supernodal GEMM/TRSM hot spots through the kernel layer), plus the
four-way distributed sweep comparison — legacy unrolled vs level-serial
IR vs cross-level *overlapped* IR executor vs the uniform round-*stream*
executor (one ``lax.fori_loop`` body; the latter three through the
``PSelInvEngine`` session API) — on an 8-device host mesh (re-exec'd in
a subprocess so the main process stays single-device): trace (lower)
time, XLA compile time, HLO size, run time, ppermute round counts (the
overlapped+coalesced stream must issue fewer), the simulated
executed-schedule times of the IR paths, and their peak arena
footprints (with the copy-free L̂ gathers the overlapped arena must stay
within 1.1× of the level-serial executor's transient peak — it lands
*below* it). The stream section records
``selinv/stream_compile_ms``/``stream_hlo_bytes``/``stream_us_per_call``
plus the grid-factored wire metrics
``selinv/stream_wire_bytes``/``stream_shifts_per_round``, and asserts
the stream program's HLO text is ≤ 0.5× the unrolled overlapped
program's (the whole point: program size independent of the round
count) *and* its gated executed wire bytes are ≤ 2× the unrolled
overlapped executor's (the flat ring of PR 5 paid ~36× here) while
staying bit-identical in the f32 run (≤1e-4 asserted; tests assert
≤1e-12 in f64). The engine section records
multi-matrix batched solve throughput
(``selinv/solve_batched_us_per_matrix_b{1,4,16}``), the speedup of one
batched B=16 solve over sequential ``run_distributed`` calls (asserted
≥5× per matrix, cold analyze excluded), and the engine structure-cache
hit count. The serve section re-execs the mixed-structure Poisson
traffic harness (``repro.serve.traffic``) with 8 devices + f64 and
records the serving scorecard
(``selinv/serve_{p50_us,throughput_rps,batch_occupancy}``), asserting
coalesced serving ≥5× the sequential per-matrix baseline, exactly one
compile per (structure, bucket), and ≤1e-12 batched-vs-unbatched
identity. The SweepScope section records the tracing tax on the solve
hot path (``selinv/trace_overhead_pct``, asserted ≤2 % — what lets the
spans stay inline in ``engine.solve``) and the measured per-round
timeline statistics off the ``profile_rounds`` segmented replay
(``selinv/round_p95_us``, ``selinv/inbound_skew_ratio`` — the latter
asserted under PlanLint's static imbalance WARN threshold)."""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

import jax

from repro.core import sparse
from repro.core.selinv import compare_with_oracle, selected_inverse

from .common import csv_row, reemit_child_rows, timed


def run(full: bool = False):
    n = 16 if full else 10
    A = sparse.laplacian_2d(n, n)
    for backend in ("numpy", "jax", "pallas"):
        t0 = time.perf_counter()
        Ainv, bs = selected_inverse(A, max_supernode=16, backend=backend)
        dt = time.perf_counter() - t0
        err = compare_with_oracle(Ainv, bs, A)
        csv_row(f"selinv/{backend}", dt * 1e6,
                f"N={A.shape[0]} nsuper={bs.nsuper} err={err:.2e}")
        assert err < 1e-3
    _plan_lint_bench()
    _hlo_lint_bench()
    _run_ir_compare(full)
    _run_serve_bench(full)
    return True


def _plan_lint_bench():
    """PlanLint static-verifier cost + diagnostic counts, host-side (the
    checker pipeline never touches a device). Records the tier-1 4×2
    lint cost (`selinv/plan_lint_ms`) and the 8×4 ``bigmesh`` case
    (`selinv/bigmesh_8x4_lint_ms`) — the first bench row at a >8-device
    grid (ROADMAP: bench, not just validate, bigger grids). Both must
    report zero ERROR diagnostics: every shipped plan passes PlanLint."""
    import scipy.sparse as sp

    from repro.core import verify
    from repro.core.plan import TreeKind, build_plan, schedule_overlapped
    from repro.core.schedule import Grid2D
    from repro.core.stream import lower_stream, stream_wire_blocks
    from repro.core.symbolic import symbolic_factorize

    for name, nx, nb, pr, pc in (("plan_lint_ms", 16, 16, 4, 2),
                                 ("bigmesh_8x4_lint_ms", 32, 32, 8, 4)):
        bs = symbolic_factorize(
            sp.csr_matrix(sparse.laplacian_2d(nx, 8)), max_supernode=8)
        plan = build_plan(bs, Grid2D(pr, pc), TreeKind.SHIFTED, nb=nb)
        ov = schedule_overlapped(plan)
        st = lower_stream(ov)
        t0 = time.perf_counter()
        diags = (verify.check_plan(plan) + verify.check_overlap(ov, plan)
                 + verify.check_stream(st, plan))
        dt = time.perf_counter() - t0
        nerr = sum(1 for d in diags if d.severity == "error")
        nwarn = len(diags) - nerr
        csv_row(f"selinv/{name}", dt * 1e6,
                f"nb={nb} grid={pr}x{pc} errors={nerr} warnings={nwarn} "
                f"rounds={len(ov.rounds)} "
                f"wire_blocks={stream_wire_blocks(st)}")
        assert nerr == 0, verify.lint_report(diags)


def _hlo_lint_bench():
    """HloLint compiled-artifact verifier cost + diagnostic counts,
    host-side (abstract-mesh trace + lower, `core/hlo_verify.py` — no
    devices). Records the tier-1 nb=16 4×2 stream case
    (`selinv/hlo_lint_ms`): trace + lower the sweep and cross-check the
    compiled jaxpr/StableHLO layers against the plan tables. Must
    report zero ERROR diagnostics: every lowered program passes
    PlanLint AND HloLint."""
    import scipy.sparse as sp

    from repro.core import hlo_verify, verify
    from repro.core.plan import PlanOptions
    from repro.core.pselinv_dist import build_program, pad_nb
    from repro.core.symbolic import symbolic_factorize

    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(16, 8)), max_supernode=8)
    prog = build_program(bs, pad_nb(bs.nsuper, 4, 2), 8, 4, 2,
                         options=PlanOptions(stream=True))
    t0 = time.perf_counter()
    diags = hlo_verify.lint_program(prog)
    dt = time.perf_counter() - t0
    nerr = sum(1 for d in diags if d.severity == "error")
    nwarn = len(diags) - nerr
    csv_row("selinv/hlo_lint_ms", dt * 1e6,
            f"nb=16 grid=4x2 errors={nerr} warnings={nwarn} "
            f"permutes={len(hlo_verify.expected_permutes(prog))} "
            f"wire_blocks={hlo_verify.expected_wire_blocks(prog)}")
    assert nerr == 0, verify.lint_report(diags)


def _run_ir_compare(full: bool):
    """Re-exec the sweep comparison with 8 host devices."""
    if len(jax.devices()) >= 8:
        return _ir_compare_child(full)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.pselinv_bench", "--ir-compare"]
        + (["--full"] if full else []),
        env=env, cwd=root, capture_output=True, text=True, timeout=900)
    reemit_child_rows(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])


def _ir_compare_child(full: bool):
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.engine import Grid, PlanOptions, PSelInvEngine
    from repro.core.pselinv_dist import (analyze_structure,
                                         build_program_unrolled,
                                         make_sweep_unrolled,
                                         prepare_values, run_distributed)
    from repro.core.trees import TreeKind

    nx = 32 if full else 16          # nb = nx (b=8 supernodes per grid row)
    A = sparse.laplacian_2d(nx, 8)
    b, pr, pc = 8, 4, 2
    bs, nb = analyze_structure(A, b, pr, pc)
    Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
    devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
    mesh = Mesh(devs, ("xy",))
    Lh = jnp.asarray(Lh_s, jnp.float32)
    Dinv = jnp.asarray(Dinv_s, jnp.float32)

    outs = {}
    rounds = {}
    peaks = {}
    engines = {}
    hlo_bytes = {}
    times = {}

    def lower_unrolled():
        prog = build_program_unrolled(bs, nb, b, pr, pc, TreeKind.SHIFTED)
        return jax.jit(shard_map(make_sweep_unrolled(prog), mesh=mesh,
                                 in_specs=(P("xy"), P("xy")),
                                 out_specs=P("xy")))

    def lower_engine(overlap, stream=False):
        eng = PSelInvEngine.analyze(
            bs, b=b, grid=Grid(pr, pc),
            options=PlanOptions(kind=TreeKind.SHIFTED, overlap=overlap,
                                stream=stream))
        return eng, eng.jitted()

    for name in ("unrolled", "ir", "overlap", "stream"):
        t0 = time.perf_counter()
        if name == "unrolled":
            fn = lower_unrolled()
        else:
            engines[name], fn = lower_engine(
                overlap=(name in ("overlap", "stream")),
                stream=(name == "stream"))
        lowered = fn.lower(Lh, Dinv)
        t_trace = time.perf_counter() - t0
        hlo_text = lowered.as_text()
        hlo_lines = len(hlo_text.splitlines())
        hlo_bytes[name] = len(hlo_text)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        times[name] = (t_trace, t_compile)
        out, dt = timed(
            lambda: jax.block_until_ready(compiled(Lh, Dinv)), reps=3)
        outs[name] = np.asarray(out)
        if name in ("ir", "overlap", "stream"):
            # static schedule metrics + executed-schedule timing, straight
            # off the cached session (no re-lowering, no hand-wired
            # round_schedule_from_* plumbing)
            stats = engines[name].stats()
            rounds[name] = stats["ppermute_rounds"]
            peaks[name] = stats["peak_arena_blocks"]
            sim = engines[name].simulate()
            csv_row(f"selinv/sweep_{name}_simulated", sim.total_time * 1e6,
                    f"nb={nb} rounds={rounds[name]} "
                    f"peak_arena_blocks={sim.peak_arena_blocks}")
        csv_row(f"selinv/sweep_{name}_trace", t_trace * 1e6,
                f"nb={nb} hlo_lines={hlo_lines}")
        csv_row(f"selinv/sweep_{name}_compile", t_compile * 1e6, f"nb={nb}")
        csv_row(f"selinv/sweep_{name}_trace_compile",
                (t_trace + t_compile) * 1e6, f"nb={nb}")
        csv_row(f"selinv/sweep_{name}_run", dt * 1e6, f"nb={nb}")
        if name == "stream":
            csv_row("selinv/stream_us_per_call", dt * 1e6, f"nb={nb}")
    err = float(abs(outs["ir"] - outs["unrolled"]).max())
    csv_row("selinv/sweep_ir_vs_unrolled_maxdiff", 0.0, f"err={err:.2e}")
    assert err < 1e-4, err
    err_o = float(abs(outs["overlap"] - outs["ir"]).max())
    csv_row("selinv/sweep_overlap_vs_ir_maxdiff", 0.0, f"err={err_o:.2e}")
    assert err_o < 1e-4, err_o
    # the uniform round-stream executor replays the overlapped rounds
    # bit-for-bit (f64 identity asserted in tests; ≤1e-4 in this f32 run)
    err_t = float(abs(outs["stream"] - outs["overlap"]).max())
    csv_row("selinv/sweep_stream_vs_overlap_maxdiff", 0.0,
            f"err={err_t:.2e}")
    assert err_t < 1e-4, err_t
    # ...and its program must be small: trace+compile in one fori_loop
    # body, HLO ≤ 0.5× the unrolled overlapped program's (the stream's
    # point — program size independent of the round count)
    csv_row("selinv/stream_compile_ms",
            sum(times["stream"]) * 1e3,
            f"nb={nb} overlap_ms={sum(times['overlap']) * 1e3:.0f} "
            f"trace_ms={times['stream'][0] * 1e3:.0f}")
    csv_row("selinv/stream_hlo_bytes", float(hlo_bytes["stream"]),
            f"nb={nb} overlap_hlo_bytes={hlo_bytes['overlap']}")
    assert hlo_bytes["stream"] <= 0.5 * hlo_bytes["overlap"], hlo_bytes
    # ...and its wire must be near-unrolled: the grid-factored shift
    # scheduling gates each round to only its active comm slots, so the
    # executed wire bytes (engine stats == simulator accounting) land
    # within 2× of the unrolled overlapped executor's, where the PR-5
    # flat ring shipped every device's lane stack on every shift of
    # every round (~36× unrolled at this grid)
    from repro.core.schedule import BYTES_PER_ELT
    from repro.core.simulator import executed_wire_bytes
    from repro.core.stream import overlap_wire_blocks
    st_eng = engines["stream"]
    s_stats = st_eng.stats()
    wire_stream = s_stats["stream_wire_bytes"]
    assert executed_wire_bytes(st_eng) == wire_stream
    wire_unrolled = (overlap_wire_blocks(st_eng.program.overlap_plan)
                     * b * b * BYTES_PER_ELT)
    csv_row("selinv/stream_wire_bytes", wire_stream,
            f"nb={nb} unrolled={wire_unrolled:.0f} "
            f"ratio={wire_stream / wire_unrolled:.2f}")
    csv_row("selinv/stream_shifts_per_round",
            s_stats["stream_shifts_per_round"],
            f"nb={nb} "
            f"nshifts={len(st_eng.program.stream_tables.shifts)}")
    assert wire_stream <= 2.0 * wire_unrolled, (wire_stream,
                                                wire_unrolled)
    csv_row("selinv/sweep_ppermute_rounds", float(rounds["overlap"]),
            f"nb={nb} serial={rounds['ir']} overlap={rounds['overlap']}")
    assert rounds["overlap"] < rounds["ir"], rounds
    # memory axis: with the copy-free L̂ gathers the overlapped arena
    # peak must stay within 1.1× of the level-serial executor's
    # transient peak (it lands *below* it; ~1.2× with the arena L̂ copy,
    # ~3-4× before slot recycling)
    csv_row("selinv/sweep_peak_arena_blocks", float(peaks["overlap"]),
            f"nb={nb} serial={peaks['ir']} overlap={peaks['overlap']}")
    assert peaks["overlap"] <= 1.1 * peaks["ir"], peaks
    _engine_batched_bench(A, b, pr, pc, nb, engines["overlap"],
                          run_distributed)
    _obs_bench(engines["overlap"], Lh, Dinv, nb)
    return True


def _obs_bench(eng, Lh, Dinv, nb):
    """SweepScope scorecard: the tracing tax on the solve hot path
    (spans left inline in ``engine.solve`` — the ≤2 % bar is what lets
    them stay there), plus the measured per-round timeline statistics
    from the ``profile_rounds`` segmented replay (p95 round wall and
    the paper's inbound-overload skew, measured rather than simulated)."""
    import numpy as np

    from repro.obs.trace import TRACER

    vals = (Lh, Dinv)

    def hot():
        return jax.block_until_ready(eng.solve(vals))

    # best-of-many on both sides: the overhead is a ratio of two timed
    # passes on a possibly starved host (cf. _engine_batched_bench)
    TRACER.disable()
    _, dt_off = timed(hot, reps=20, best=True)
    TRACER.enable()
    try:
        _, dt_on = timed(hot, reps=20, best=True)
    finally:
        TRACER.disable()
    overhead_pct = max(0.0, (dt_on - dt_off) / dt_off * 100.0)
    csv_row("selinv/trace_overhead_pct", overhead_pct,
            f"nb={nb} off_us={dt_off * 1e6:.1f} on_us={dt_on * 1e6:.1f}")
    assert overhead_pct <= 2.0, (
        f"tracing tax {overhead_pct:.2f}% on the solve hot path "
        f"(bar: 2%) — off {dt_off * 1e6:.1f}us on {dt_on * 1e6:.1f}us")

    prof = eng.profile_rounds(vals, reps=3)
    walls = prof.round_walls_us()
    sk = prof.skew()
    alpha, beta = prof.fit_alpha_beta()
    csv_row("selinv/round_p95_us", float(np.percentile(walls, 95)),
            f"nb={nb} rounds={prof.nrounds} "
            f"median_us={np.percentile(walls, 50):.1f} "
            f"total_us={prof.wall_us:.0f} "
            f"alpha_us={alpha * 1e6:.1f} beta_ns_per_B={beta * 1e9:.2f}")
    csv_row("selinv/inbound_skew_ratio", sk["skew_ratio"],
            f"nb={nb} static_warn>{sk['static_warn_threshold']:.1f} "
            f"exceeded={sk['exceeds_static_warn']} "
            f"max_B={int(max(sk['inbound_bytes']))} "
            f"mean_B={np.mean(sk['inbound_bytes']):.0f}")
    assert not sk["exceeds_static_warn"], sk
    return True


def _run_serve_bench(full: bool):
    """Re-exec the serving-layer traffic bench under f64 (the ≤1e-12
    identity between every batched result and its unbatched solve is
    only meaningful in double precision)."""
    import jax.numpy  # noqa: F401 — force config resolution
    if jax.config.jax_enable_x64:
        return _serve_bench_child(full)
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.pselinv_bench",
         "--serve-bench"] + (["--full"] if full else []),
        env=env, cwd=root, capture_output=True, text=True, timeout=900)
    reemit_child_rows(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])


def _serve_bench_child(full: bool):
    """Mixed-structure burst traffic through SelInvServer: records the
    serving scorecard (``selinv/serve_p50_us``/
    ``serve_throughput_rps``/``serve_batch_occupancy``) and asserts
    the PR's three acceptance bars — coalesced serving ≥5× the
    per-matrix throughput of sequential single solves over the same
    ≥100-request ≥2-structure trace, exactly one compile per
    (structure, bucket) off the engine trace counters, and every
    batched result within 1e-12 (f64) of its unbatched solve.

    Grid(1, 1) and a burst (saturated) trace keep the asserted ratio
    about *coalescing* rather than the host scheduler: with simulated
    devices and Poisson sleeps, every thread in the box shares one
    core and the measurement swings 2-3× run to run (the Poisson +
    4×2-mesh path stays covered, unasserted-for-throughput, by the
    ``slow``-marked ``test_serve_traffic_acceptance_4x2``)."""
    import jax.numpy as jnp

    from repro.core.engine import Grid
    from repro.serve.batcher import BatchWindow
    from repro.serve.traffic import run_traffic

    n = 200 if full else 120
    # reps=3, best-of: the ≥5× assert below is a ratio of two timed
    # passes (see _engine_batched_bench for the same treatment).
    res = run_traffic(
        n_requests=n, n_structures=3 if full else 2, rate_hz=None,
        seed=0, b=8, grid=Grid(1, 1), window=BatchWindow(),
        dtype=jnp.float64, check_identity=True, tol=1e-12, reps=3)
    occ = res["serve_batch_occupancy"]
    csv_row("selinv/serve_p50_us", res["serve_p50_us"],
            f"n={n} structures={res['n_structures']} "
            f"p95={res['serve_p95_us']:.0f} p99={res['serve_p99_us']:.0f}")
    csv_row("selinv/serve_throughput_rps", res["serve_throughput_rps"],
            f"n={n} per_matrix_us={res['serve_per_matrix_us']:.1f} "
            f"baseline_us={res['baseline_per_matrix_us']:.1f} "
            f"speedup={res['speedup']:.2f}")
    csv_row("selinv/serve_batch_occupancy", occ,
            f"n={n} batches={res['batches']} "
            f"identity={res['identity_max_abs']:.2e}")
    assert res["speedup"] >= 5.0, (
        f"coalesced serving only {res['speedup']:.2f}x the sequential "
        f"baseline (bar: 5x)")
    return True


def _engine_batched_bench(A, b, pr, pc, nb, eng, run_distributed):
    """Analyze-once / solve-many throughput: batched engine solves at
    B∈{1,4,16} (per-matrix microseconds), the speedup of the batched
    B=16 hot path over sequential ``run_distributed`` calls (warmed
    first, so cold analyze/compile is excluded on both sides), and the
    session structure-cache hit count."""
    import jax.numpy as jnp
    from repro.core.engine import PSelInvEngine, stack_values

    vals = eng.prepare_values(A)
    per_matrix = {}
    for B in (1, 4, 16):
        vb = stack_values([vals] * B)
        # best-of-reps: the ≥5× assert below is a ratio of two timings
        # on a possibly starved host (8 simulated devices share the
        # box), and one descheduled rep at mean-of-3 has flipped it
        _, dt = timed(lambda: jax.block_until_ready(
            eng.solve(vb, dtype=jnp.float32)), reps=5, best=True)
        per_matrix[B] = dt / B
        csv_row(f"selinv/solve_batched_us_per_matrix_b{B}",
                dt / B * 1e6, f"nb={nb} B={B}")
    # sequential run_distributed: one matrix per call through the shim
    # (structure-cache warm — the 5× bar is about the per-call host
    # factorization + dispatch the batched path amortizes away)
    _, dt_seq = timed(lambda: run_distributed(
        A, b=b, pr=pr, pc=pc, dtype=jnp.float32), reps=3, best=True)
    speedup = dt_seq / per_matrix[16]
    csv_row("selinv/engine_batched_speedup", speedup,
            f"nb={nb} B=16 seq_us={dt_seq * 1e6:.1f} "
            f"batched_us={per_matrix[16] * 1e6:.1f}")
    assert speedup >= 5.0, (dt_seq, per_matrix)
    csv_row("selinv/engine_cache_hits", float(PSelInvEngine.cache_hits),
            f"misses={PSelInvEngine.cache_misses}")
    return True


if __name__ == "__main__":
    if "--ir-compare" in sys.argv:
        # _run_ir_compare re-execs with 8 host devices when needed
        _run_ir_compare(full="--full" in sys.argv)
    elif "--serve-bench" in sys.argv:
        # _run_serve_bench re-execs with 8 devices + x64 when needed
        _run_serve_bench(full="--full" in sys.argv)
    else:
        run(full="--full" in sys.argv)
