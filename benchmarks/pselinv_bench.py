"""Selected-inversion numeric benchmark: numpy vs jax vs pallas backends
(the supernodal GEMM/TRSM hot spots through the kernel layer), plus the
three-way distributed sweep comparison — legacy unrolled vs level-serial
IR vs cross-level *overlapped* IR executor — on an 8-device host mesh
(re-exec'd in a subprocess so the main process stays single-device):
trace (lower) time, XLA compile time, HLO size, run time, ppermute round
counts (the overlapped+coalesced stream must issue fewer), the
simulated executed-schedule times of both IR paths, and their peak
arena footprints (the slot-recycled overlapped arena must stay within
1.5× of the level-serial executor's transient peak)."""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

import jax

from repro.core import sparse
from repro.core.selinv import compare_with_oracle, selected_inverse

from .common import csv_row, reemit_child_rows, timed


def run(full: bool = False):
    n = 16 if full else 10
    A = sparse.laplacian_2d(n, n)
    for backend in ("numpy", "jax", "pallas"):
        t0 = time.perf_counter()
        Ainv, bs = selected_inverse(A, max_supernode=16, backend=backend)
        dt = time.perf_counter() - t0
        err = compare_with_oracle(Ainv, bs, A)
        csv_row(f"selinv/{backend}", dt * 1e6,
                f"N={A.shape[0]} nsuper={bs.nsuper} err={err:.2e}")
        assert err < 1e-3
    _run_ir_compare(full)
    return True


def _run_ir_compare(full: bool):
    """Re-exec the sweep comparison with 8 host devices."""
    if len(jax.devices()) >= 8:
        return _ir_compare_child(full)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.pselinv_bench", "--ir-compare"]
        + (["--full"] if full else []),
        env=env, cwd=root, capture_output=True, text=True, timeout=900)
    reemit_child_rows(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])


def _ir_compare_child(full: bool):
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.plan import peak_arena_blocks, ppermute_round_count
    from repro.core.pselinv_dist import (build_program,
                                         build_program_unrolled, make_sweep,
                                         make_sweep_overlapped,
                                         make_sweep_unrolled, prepare_inputs)
    from repro.core.simulator import (round_schedule_from_exec,
                                      round_schedule_from_overlap,
                                      simulate_schedule)
    from repro.core.trees import TreeKind

    nx = 32 if full else 16          # nb = nx (b=8 supernodes per grid row)
    A = sparse.laplacian_2d(nx, 8)
    b, pr, pc = 8, 4, 2
    bs, nb, Lh_s, Dinv_s = prepare_inputs(A, b, pr, pc)
    devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
    mesh = Mesh(devs, ("xy",))
    Lh = jnp.asarray(Lh_s, jnp.float32)
    Dinv = jnp.asarray(Dinv_s, jnp.float32)

    def build_overlap(bs, nb, b, pr, pc, kind):
        return build_program(bs, nb, b, pr, pc, kind, overlap=True)

    outs = {}
    rounds = {}
    peaks = {}
    for name, builder, mk in (
            ("unrolled", build_program_unrolled, make_sweep_unrolled),
            ("ir", build_program, make_sweep),
            ("overlap", build_overlap, make_sweep_overlapped)):
        t0 = time.perf_counter()
        prog = builder(bs, nb, b, pr, pc, TreeKind.SHIFTED)
        sweep = mk(prog)
        fn = jax.jit(shard_map(sweep, mesh=mesh,
                               in_specs=(P("xy"), P("xy")),
                               out_specs=P("xy")))
        lowered = fn.lower(Lh, Dinv)
        t_trace = time.perf_counter() - t0
        hlo_lines = len(lowered.as_text().splitlines())
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        out, dt = timed(
            lambda: jax.block_until_ready(compiled(Lh, Dinv)), reps=3)
        outs[name] = np.asarray(out)
        if name == "ir":
            rounds["ir"] = ppermute_round_count(prog.exec_plan)
            peaks["ir"] = peak_arena_blocks(prog.exec_plan)
            sim = simulate_schedule(
                round_schedule_from_exec(prog.exec_plan, prog.plan))
        elif name == "overlap":
            rounds["overlap"] = ppermute_round_count(prog.overlap_plan)
            peaks["overlap"] = peak_arena_blocks(prog.overlap_plan)
            sim = simulate_schedule(
                round_schedule_from_overlap(prog.overlap_plan, prog.plan))
        if name in ("ir", "overlap"):
            csv_row(f"selinv/sweep_{name}_simulated", sim.total_time * 1e6,
                    f"nb={nb} rounds={rounds[name]} "
                    f"peak_arena_blocks={sim.peak_arena_blocks}")
        csv_row(f"selinv/sweep_{name}_trace", t_trace * 1e6,
                f"nb={nb} hlo_lines={hlo_lines}")
        csv_row(f"selinv/sweep_{name}_compile", t_compile * 1e6, f"nb={nb}")
        csv_row(f"selinv/sweep_{name}_trace_compile",
                (t_trace + t_compile) * 1e6, f"nb={nb}")
        csv_row(f"selinv/sweep_{name}_run", dt * 1e6, f"nb={nb}")
    err = float(abs(outs["ir"] - outs["unrolled"]).max())
    csv_row("selinv/sweep_ir_vs_unrolled_maxdiff", 0.0, f"err={err:.2e}")
    assert err < 1e-4, err
    err_o = float(abs(outs["overlap"] - outs["ir"]).max())
    csv_row("selinv/sweep_overlap_vs_ir_maxdiff", 0.0, f"err={err_o:.2e}")
    assert err_o < 1e-4, err_o
    csv_row("selinv/sweep_ppermute_rounds", float(rounds["overlap"]),
            f"nb={nb} serial={rounds['ir']} overlap={rounds['overlap']}")
    assert rounds["overlap"] < rounds["ir"], rounds
    # memory axis: the recycled overlapped arena must stay within 1.5×
    # of the level-serial executor's transient peak (was ~3-4× when
    # every level's stacks stayed live for the whole sweep)
    csv_row("selinv/sweep_peak_arena_blocks", float(peaks["overlap"]),
            f"nb={nb} serial={peaks['ir']} overlap={peaks['overlap']}")
    assert peaks["overlap"] <= 1.5 * peaks["ir"], peaks
    return True


if __name__ == "__main__":
    if "--ir-compare" in sys.argv:
        # _run_ir_compare re-execs with 8 host devices when needed
        _run_ir_compare(full="--full" in sys.argv)
    else:
        run(full="--full" in sys.argv)
