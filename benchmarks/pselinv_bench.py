"""Selected-inversion numeric benchmark: numpy vs jax vs pallas backends
(the supernodal GEMM/TRSM hot spots through the kernel layer), plus the
distributed ppermute sweep on host devices when >1 device is available."""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import sparse
from repro.core.selinv import compare_with_oracle, selected_inverse

from .common import csv_row, timed


def run(full: bool = False):
    n = 16 if full else 10
    A = sparse.laplacian_2d(n, n)
    for backend in ("numpy", "jax", "pallas"):
        t0 = time.perf_counter()
        Ainv, bs = selected_inverse(A, max_supernode=16, backend=backend)
        dt = time.perf_counter() - t0
        err = compare_with_oracle(Ainv, bs, A)
        csv_row(f"selinv/{backend}", dt * 1e6,
                f"N={A.shape[0]} nsuper={bs.nsuper} err={err:.2e}")
        assert err < 1e-3
    return True


if __name__ == "__main__":
    run(full=True)
