"""Per-kernel microbenchmarks (CPU: interpret-mode correctness-scale
timings; the numbers are for relative tracking, not TPU projections)."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import csv_row, timed


def run(full: bool = False):
    rng = np.random.default_rng(0)
    s = 512 if full else 256

    a = jnp.asarray(rng.standard_normal((s, s)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((s, s)), jnp.float32)
    _, dt = timed(lambda: np.asarray(ops.block_gemm(a, b)))
    _, dtr = timed(lambda: np.asarray(ref.gemm_ref(a, b)))
    csv_row("kernel/block_gemm", dt * 1e6, f"ref_us={dtr*1e6:.0f} n={s}")

    q = jnp.asarray(rng.standard_normal((1, s, 4, 64)), jnp.float32)
    _, dt = timed(lambda: np.asarray(ops.flash_attention(q, q, q)))
    _, dtr = timed(lambda: np.asarray(ref.flash_attention_ref(q, q, q)))
    csv_row("kernel/flash_attention", dt * 1e6, f"ref_us={dtr*1e6:.0f} s={s}")

    x = jnp.asarray(rng.standard_normal((s, 1024)), jnp.float32)
    sc = jnp.ones((1024,), jnp.float32)
    _, dt = timed(lambda: np.asarray(ops.rmsnorm(x, sc)))
    csv_row("kernel/rmsnorm", dt * 1e6, f"rows={s}")

    u = jnp.asarray(np.triu(rng.standard_normal((64, 64))) + 4 * np.eye(64),
                    jnp.float32)
    bm = jnp.asarray(rng.standard_normal((s, 64)), jnp.float32)
    _, dt = timed(lambda: np.asarray(ops.trsm(bm, u)))
    csv_row("kernel/trsm", dt * 1e6, f"m={s} k=64")
    return True


if __name__ == "__main__":
    run(full=True)
