"""Paper Table 1: Col-Bcast outgoing-volume stats (min/max/median/σ) per
rank for Flat / Binary / Shifted Binary trees — audikw_1-like matrix on a
64×64 grid. Validation targets (§7 of DESIGN.md): binary max/σ > flat;
shifted σ < flat σ, shifted max < flat max, shifted min > flat min."""
from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import sparse
from repro.core.schedule import Grid2D
from repro.core.simulator import volume_stats, volumes_fast
from repro.core.symbolic import symbolic_factorize_elements
from repro.core.trees import TreeKind

from .common import csv_row, ensure_out


def run(full: bool = False):
    dims = (32, 32, 32) if full else (20, 20, 20)
    cap = 12
    G, sizes = sparse.fem3d_like_structure(*dims, 3)
    bs = symbolic_factorize_elements(G, sizes, max_supernode=cap)
    grid = Grid2D(64, 64)

    out = ensure_out()
    rows = []
    stats = {}
    for kind in (TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED,
                 TreeKind.HYBRID):
        t0 = time.perf_counter()
        v = volumes_fast(bs, grid, kind)
        dt = time.perf_counter() - t0
        s = volume_stats(v["col-bcast"] / 1e6)
        stats[kind.value] = s
        rows.append([kind.value] + [round(s[k], 3) for k in
                                    ("min", "max", "median", "std")])
        csv_row(f"table1/{kind.value}", dt * 1e6,
                f"minMB={s['min']:.1f} maxMB={s['max']:.1f} "
                f"medMB={s['median']:.1f} stdMB={s['std']:.2f}")

    with open(os.path.join(out, "table1_volume.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["tree", "min_mb", "max_mb", "median_mb", "std_mb"])
        w.writerows(rows)

    # paper-claim checks (directional)
    flat, binry, shift = (stats["flat"], stats["binary"], stats["shifted"])
    checks = {
        "binary_max_worse_than_flat": binry["max"] > flat["max"],
        "binary_std_worse_than_flat": binry["std"] > flat["std"],
        "shifted_std_better_than_flat": shift["std"] < flat["std"],
        "shifted_max_better_than_flat": shift["max"] < flat["max"],
        "shifted_min_better_than_flat": shift["min"] > flat["min"],
    }
    csv_row("table1/claims", 0.0,
            " ".join(f"{k}={v}" for k, v in checks.items()))
    return stats, checks


if __name__ == "__main__":
    run(full=True)
