"""Tree-collective HLO accounting: hierarchical (RS + tree cross-pod AR +
AG) vs flat psum gradient sync — collective op counts/bytes from compiled
HLO on an 8-device host mesh (2 pods × 4). Requires the bench process to
be launched with XLA_FLAGS=--xla_force_host_platform_device_count=8;
skips gracefully otherwise."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.comm.hierarchical import hierarchical_allreduce
from repro.compat import shard_map
from repro.core.trees import TreeKind

from .common import csv_row, reemit_child_rows


def run(full: bool = False):
    if len(jax.devices()) < 8:
        # re-exec in a subprocess with 8 host devices
        import os
        import subprocess
        import sys
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.treecomm_bench"]
            + (["--full"] if full else []),
            env=env, cwd=root, capture_output=True, text=True, timeout=600)
        reemit_child_rows(r.stdout)
        if r.returncode != 0:
            raise RuntimeError(r.stderr[-2000:])
        return None
    devs = np.array(jax.devices()[:8]).reshape(2, 4)
    mesh = Mesh(devs, ("pod", "data"))
    n = 1 << (16 if full else 12)
    x = jnp.zeros((2, 4, n), jnp.float32)

    def flat(xs):
        g = xs.reshape(n)
        return jax.lax.psum(g, ("pod", "data")).reshape(1, 1, n)

    def tree(xs):
        g = xs.reshape(n)
        out = hierarchical_allreduce(g, "pod", "data", npods=2,
                                     inner_size=4, kind=TreeKind.SHIFTED,
                                     tag=3)
        return out.reshape(1, 1, n)

    from repro.launch.dryrun import collective_bytes
    results = {}
    for name, f in (("flat_psum", flat), ("hier_tree", tree)):
        sm = shard_map(f, mesh=mesh, in_specs=P("pod", "data"),
                           out_specs=P("pod", "data"))
        txt = jax.jit(sm).lower(x).compile().as_text()
        cb = collective_bytes(txt)
        results[name] = cb
        csv_row(f"treecomm/{name}", 0.0,
                " ".join(f"{k}={v/1e3:.1f}KB" for k, v in cb.items()))
        # numerics must agree
    a = jax.jit(shard_map(flat, mesh=mesh, in_specs=P("pod", "data"),
                              out_specs=P("pod", "data")))(x + 1.0)
    b = jax.jit(shard_map(tree, mesh=mesh, in_specs=P("pod", "data"),
                              out_specs=P("pod", "data")))(x + 1.0)
    assert np.allclose(np.asarray(a), np.asarray(b))
    csv_row("treecomm/equivalence", 0.0, "tree == psum: True")
    return results


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
