"""Paper Fig 9: communication vs computation time at 256 and 4096 ranks,
flat vs shifted — the dense (DG-like) matrix. Paper: comm/comp drops
from 11.8 (flat) to 1.9 (shifted) at 4096 ranks; at 256 ranks the gain
is small (intra-node fast path)."""
from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import sparse
from repro.core.schedule import Grid2D
from repro.core.simulator import NetworkModel, simulate
from repro.core.symbolic import symbolic_factorize_elements
from repro.core.trees import TreeKind

from .common import csv_row, ensure_out


def run(full: bool = False):
    out = ensure_out()
    G, sizes = (sparse.dg_like_structure(36, 36, 12) if full
                else sparse.dg_like_structure(24, 24, 12))
    bs = symbolic_factorize_elements(G, sizes, max_supernode=36)
    rows = []
    ratios = {}
    for P, (pr, pc) in {256: (16, 16), 4096: (64, 64)}.items():
        grid = Grid2D(pr, pc)
        for kind in (TreeKind.FLAT, TreeKind.SHIFTED, TreeKind.HYBRID):
            t0 = time.perf_counter()
            res = simulate(bs, grid, kind, NetworkModel())
            dt = time.perf_counter() - t0
            ratio = res.comm_to_comp_ratio()
            ratios[(P, kind.value)] = ratio
            rows.append([P, kind.value, res.total_time, ratio])
            csv_row(f"fig9/p{P}/{kind.value}", dt * 1e6,
                    f"total={res.total_time:.4f}s comm/comp={ratio:.2f}")
    with open(os.path.join(out, "fig9_ratio.csv"), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["ranks", "tree", "sim_time_s", "comm_comp_ratio"])
        w.writerows(rows)
    return ratios


if __name__ == "__main__":
    run(full=True)
