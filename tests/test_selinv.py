"""Selected inversion correctness: Alg. 1 vs the dense-inverse oracle."""
import numpy as np
import pytest
import scipy.sparse as sp
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # container ships without hypothesis
    HAVE_HYPOTHESIS = False

from repro.core import sparse
from repro.core.selinv import (compare_with_oracle, dense_selinv_oracle,
                               selected_inverse)
from repro.core.supernodal_lu import dense_lu_nopivot, factorize
from repro.core.symbolic import symbolic_factorize


def test_dense_lu_nopivot():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((24, 24)) + 24 * np.eye(24)
    L, U = dense_lu_nopivot(a)
    np.testing.assert_allclose(L @ U, a, atol=1e-10)
    assert np.allclose(np.diag(L), 1.0)


def test_lu_reconstructs_matrix():
    A = sparse.laplacian_2d(7, 7)
    lu = factorize(A, max_supernode=5)
    bs = lu.bs
    n = A.shape[0]
    Lfull = np.zeros((n, n))
    Ufull = np.zeros((n, n))
    for K in range(bs.nsuper):
        r = slice(bs.offsets[K], bs.offsets[K + 1])
        Lfull[r, r] = lu.Ldiag[K]
        Ufull[r, r] = lu.Udiag[K]
        for I in bs.struct[K]:
            I = int(I)
            ri = slice(bs.offsets[I], bs.offsets[I + 1])
            Lfull[ri, r] = lu.L[(I, K)]
            Ufull[r, ri] = lu.U[(K, I)]
    np.testing.assert_allclose(Lfull @ Ufull, A.todense(), atol=1e-9)


@pytest.mark.parametrize("backend", ["numpy", "jax", "pallas"])
def test_selinv_matches_oracle(backend):
    A = sparse.laplacian_2d(8, 8)
    Ainv, bs = selected_inverse(A, max_supernode=6, backend=backend)
    err = compare_with_oracle(Ainv, bs, A)
    assert err < (1e-9 if backend == "numpy" else 1e-4)


def test_selinv_nonsymmetric_values():
    A = sparse.make_numeric(sparse.grid_graph_2d(6, 7, stencil=5), seed=3)
    Ainv, bs = selected_inverse(A, max_supernode=5)
    assert compare_with_oracle(Ainv, bs, A) < 1e-9


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(3, 7), st.integers(3, 7), st.integers(2, 9),
           st.integers(0, 10_000))
    def test_selinv_property_random_grids(nx, ny, cap, seed):
        """Property: selected entries equal the dense inverse for random
        diagonally-dominant matrices on random grid shapes and supernode
        caps."""
        A = sparse.make_numeric(sparse.grid_graph_2d(nx, ny, stencil=9),
                                seed=seed)
        Ainv, bs = selected_inverse(A, max_supernode=cap)
        assert compare_with_oracle(Ainv, bs, A) < 1e-8
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_selinv_property_random_grids():
        pass


def test_symbolic_fill_is_superset_and_etree_consistent():
    A = sparse.laplacian_2d(9, 9)
    bs = symbolic_factorize(A, max_supernode=4)
    for K in range(bs.nsuper):
        a = set(int(i) for i in bs.a_struct[K])
        f = set(int(i) for i in bs.struct[K])
        assert a <= f
        if f:
            assert bs.parent[K] == min(f)
        assert all(i > K for i in f)
