import os
import sys

# tests run single-device (the dry-run owns the 512-device trick);
# distributed tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, ndev: int = 8, x64: bool = False, timeout=420):
    """Run a code snippet in a subprocess with its own XLA device count
    (the main pytest process stays single-device)."""
    import subprocess
    import sys
    import textwrap
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(root, "src")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
