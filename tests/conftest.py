import os
import sys

# tests run single-device (the dry-run owns the 512-device trick);
# distributed tests spawn subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
