"""Tree-construction unit + property tests (paper §3)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # container ships without hypothesis
    HAVE_HYPOTHESIS = False

from repro.core.trees import (CommTree, TreeKind, binary_tree, build_tree,
                              flat_tree, shifted_binary_tree, stable_hash)


def test_paper_fig3_binary_example():
    """Root P4, receivers P1,P2,P3,P5,P6 — paper Fig. 3(b)."""
    t = binary_tree(4, [1, 2, 3, 5, 6])
    assert t.children_map() == {4: (1, 5), 1: (2, 3), 5: (6,)}
    t.validate()


def test_flat_tree_root_sends_all():
    t = flat_tree(0, [1, 2, 3, 4])
    assert t.messages_sent() == {0: 4}
    assert t.depth() == 4          # one message per round from the root


def test_binary_root_sends_two():
    t = binary_tree(0, list(range(1, 64)))
    assert t.messages_sent()[0] == 2


def test_shifted_is_deterministic():
    a = shifted_binary_tree(3, [0, 1, 2, 4, 5], tag=77)
    b = shifted_binary_tree(3, [0, 1, 2, 4, 5], tag=77)
    assert a == b
    c = shifted_binary_tree(3, [0, 1, 2, 4, 5], tag=78)
    assert a != c or True  # different tags usually differ; no hard claim


def test_stable_hash_is_stable():
    assert stable_hash(3, 77) == stable_hash(3, 77)
    assert stable_hash(3, 77) != stable_hash(3, 78)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.sets(st.integers(0, 127), min_size=1, max_size=40),
           st.integers(0, 1 << 30),
           st.sampled_from(list(TreeKind)))
    def test_tree_properties(ranks, tag, kind):
        """Every participant reached exactly once; bcast rounds well-formed;
        reduce rounds mirror; binary-ish depth bound."""
        ranks = sorted(ranks)
        root = ranks[tag % len(ranks)]
        receivers = [r for r in ranks if r != root]
        t = build_tree(kind, root, receivers, tag=tag)
        t.validate()
        # per-round: each src sends at most once, each dst receives once total
        seen = set()
        for rnd in t.bcast_rounds():
            srcs = [s for s, _ in rnd]
            assert len(set(srcs)) == len(srcs)
            for _, d in rnd:
                assert d not in seen
                seen.add(d)
        assert seen == set(receivers)
        if kind in (TreeKind.BINARY, TreeKind.SHIFTED) and receivers:
            p = len(ranks)
            # serialized binomial schedule: depth <= ~2*log2(p)
            assert t.depth() <= 2 * int(np.ceil(np.log2(p))) + 2
        # reduction mirrors the broadcast
        fwd = [e for rnd in t.bcast_rounds() for e in rnd]
        rev = [(d, s) for rnd in t.reduce_rounds() for (s, d) in rnd]
        assert sorted(fwd) == sorted(rev)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_tree_properties():
        pass


