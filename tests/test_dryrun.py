"""Dry-run smoke anchors: one train and one decode cell lower+compile on
a reduced (4×4 / 2×2×4) mesh in a subprocess (the full 256/512-device
sweeps live in dryrun_results.json / dryrun_multipod.json)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, mesh, ndev):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DRYRUN_MESH"] = mesh
    # dryrun.py sets its own XLA_FLAGS=512 first — override afterwards is
    # impossible, so 512 placeholder devices are always available; the
    # mesh override just uses fewer of them.
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


@pytest.mark.parametrize("arch,shape", [("granite-3-2b", "train_4k"),
                                        ("granite-3-2b", "decode_32k")])
def test_dryrun_cell_single_pod(arch, shape, tmp_path):
    out = str(tmp_path / "r.json")
    _run(["--arch", arch, "--shape", shape, "--out", out], "4x4", 16)
    res = json.load(open(out))[0]
    assert res["status"] == "ok"
    assert res["flops"] > 0
    assert res["memory"]["temp_size_in_bytes"] > 0
    assert sum(res["collective_bytes"].values()) > 0


def test_dryrun_cell_multi_pod(tmp_path):
    out = str(tmp_path / "r.json")
    _run(["--arch", "granite-3-2b", "--shape", "train_4k", "--multi-pod",
          "--out", out], "2x2x4", 16)
    res = json.load(open(out))[0]
    assert res["status"] == "ok" and res["multi_pod"]


def test_dryrun_long500k_skip_rule(tmp_path):
    out = str(tmp_path / "r.json")
    _run(["--arch", "granite-3-2b", "--shape", "long_500k", "--out", out],
         "4x4", 16)
    res = json.load(open(out))[0]
    assert res["status"] == "skipped"
    assert "sub-quadratic" in res["reason"]


def test_committed_sweep_artifacts_are_green():
    """The repo-level sweep artifacts must show every runnable cell ok on
    both meshes (40 cells each: 32 ok + 8 mandated skips)."""
    for fname, mp in (("dryrun_results.json", False),
                      ("dryrun_multipod.json", True)):
        path = os.path.join(ROOT, fname)
        if not os.path.exists(path):
            pytest.skip(f"{fname} not generated yet")
        cells = json.load(open(path))
        assert len(cells) == 40
        assert sum(c["status"] == "ok" for c in cells) == 32
        assert sum(c["status"] == "skipped" for c in cells) == 8
        assert all(c["status"] != "error" for c in cells)
