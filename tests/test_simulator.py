"""Comm-schedule + simulator tests, incl. the paper's Table-1 claims."""
import numpy as np
import pytest

from repro.core import sparse
from repro.core.plan import build_plan, compile_exec, schedule_overlapped
from repro.core.schedule import Grid2D, pselinv_events
from repro.core.simulator import (NetworkModel, _msgs_vector,
                                  round_schedule_from_exec,
                                  round_schedule_from_overlap, simulate,
                                  simulate_schedule, volume_stats, volumes,
                                  volumes_fast)
from repro.core.symbolic import symbolic_factorize, symbolic_factorize_elements
from repro.core.trees import HYBRID_FLAT_MAX, TreeKind


@pytest.fixture(scope="module")
def small_case():
    G, sizes = sparse.fem3d_like_structure(8, 8, 8, 3)
    bs = symbolic_factorize_elements(G, sizes, max_supernode=12)
    return bs, Grid2D(8, 8)


def test_events_well_formed(small_case):
    bs, grid = small_case
    events, tasks = pselinv_events(bs, grid)
    assert events and tasks
    for ev in events:
        assert ev.root in ev.participants
        assert len(set(ev.participants)) == len(ev.participants)
        assert ev.nbytes > 0
        for r in ev.participants:
            assert 0 <= r < grid.size


@pytest.mark.parametrize("kind", list(TreeKind))
def test_fast_volume_path_matches_slow(small_case, kind):
    bs, grid = small_case
    out, _ = volumes(bs, grid, kind)
    fast = volumes_fast(bs, grid, kind)
    np.testing.assert_allclose(out["col-bcast"], fast["col-bcast"])
    np.testing.assert_allclose(out["row-reduce"], fast["row-reduce"])


def test_volume_conservation(small_case):
    """Total bytes sent == total bytes received per event kind."""
    bs, grid = small_case
    out, inc = volumes(bs, grid, TreeKind.SHIFTED)
    for kind in out:
        assert out[kind].sum() == pytest.approx(inc[kind].sum())


def test_total_volume_scheme_invariant(small_case):
    """Tree shape redistributes but does not change total traffic for
    broadcasts with identical participant sets per event... (flat and
    binary carry identical per-event message counts = p-1)."""
    bs, grid = small_case
    a = volumes_fast(bs, grid, TreeKind.FLAT)["col-bcast"].sum()
    b = volumes_fast(bs, grid, TreeKind.BINARY)["col-bcast"].sum()
    c = volumes_fast(bs, grid, TreeKind.SHIFTED)["col-bcast"].sum()
    assert a == pytest.approx(b)
    assert a == pytest.approx(c)


def test_paper_table1_directional_claims():
    """Binary raises max/σ vs flat under concurrency; shifted lowers σ
    and max and raises min (paper Table 1)."""
    G, sizes = sparse.fem3d_like_structure(16, 16, 16, 3)
    bs = symbolic_factorize_elements(G, sizes, max_supernode=12)
    grid = Grid2D(32, 32)
    stats = {k: volume_stats(volumes_fast(bs, grid, k)["col-bcast"])
             for k in (TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED)}
    flat, binry, shift = (stats[TreeKind.FLAT], stats[TreeKind.BINARY],
                          stats[TreeKind.SHIFTED])
    assert binry["max"] > flat["max"]
    assert binry["std"] > flat["std"]
    assert shift["std"] < flat["std"]
    assert shift["max"] < flat["max"]
    assert shift["min"] > flat["min"]


def test_simulation_shifted_beats_flat_at_scale(small_case):
    bs, _ = small_case
    grid = Grid2D(32, 32)
    t_flat = simulate(bs, grid, TreeKind.FLAT, NetworkModel()).total_time
    t_shift = simulate(bs, grid, TreeKind.SHIFTED,
                       NetworkModel()).total_time
    assert t_shift < t_flat


def test_simulation_deterministic(small_case):
    bs, grid = small_case
    m = NetworkModel(jitter_sigma=0.3, placement_seed=7)
    t1 = simulate(bs, grid, TreeKind.SHIFTED, m).total_time
    t2 = simulate(bs, grid, TreeKind.SHIFTED, m).total_time
    assert t1 == t2


def test_msgs_vector_resolves_hybrid():
    """HYBRID handed to the fast-path tree accounting resolves to the
    concrete kind ``build_tree`` would pick at that participant count —
    flat at/below HYBRID_FLAT_MAX participants, shifted (with the
    caller's tag-derived rotation, NOT a shift-0 tree) above it."""
    small = tuple(range(1, HYBRID_FLAT_MAX))        # 24 participants
    np.testing.assert_array_equal(
        _msgs_vector(TreeKind.HYBRID, 0, small, 5, HYBRID_FLAT_MAX),
        _msgs_vector(TreeKind.FLAT, 0, small, 0, HYBRID_FLAT_MAX))
    big = tuple(range(1, HYBRID_FLAT_MAX + 1))      # 25 participants
    n = HYBRID_FLAT_MAX + 1
    np.testing.assert_array_equal(
        _msgs_vector(TreeKind.HYBRID, 0, big, 5, n),
        _msgs_vector(TreeKind.SHIFTED, 0, big, 5, n))
    # a shift-0 tree would be a different schedule — the old dead ternary
    # silently produced exactly that
    assert not np.array_equal(
        _msgs_vector(TreeKind.HYBRID, 0, big, 5, n),
        _msgs_vector(TreeKind.SHIFTED, 0, big, 0, n))


def test_simulate_schedule_overlap_not_slower():
    """The executed-timeline accounting: the overlapped round stream is
    never slower than the level-serial stream of the same plan, and both
    move the volumes' bytes."""
    import scipy.sparse as sp
    A = sparse.laplacian_2d(12, 8)
    bs = symbolic_factorize(sp.csr_matrix(A), max_supernode=8)
    grid = Grid2D(4, 2)
    for kind in (TreeKind.FLAT, TreeKind.SHIFTED):
        plan = build_plan(bs, grid, kind, nb=12)
        rs = simulate_schedule(
            round_schedule_from_exec(compile_exec(plan), plan))
        ro = simulate_schedule(
            round_schedule_from_overlap(schedule_overlapped(plan), plan))
        assert ro.total_time <= rs.total_time
        out_v, _ = volumes(bs, grid, kind)
        z = np.zeros(grid.size)
        for k in ("xfer", "col-bcast"):
            np.testing.assert_allclose(ro.send_bytes.get(k, z),
                                       out_v.get(k, z))
        np.testing.assert_allclose(ro.recv_bytes["row-reduce"],
                                   out_v["row-reduce"])
        np.testing.assert_allclose(rs.compute_time, ro.compute_time)
