"""PSelInvEngine session API tests: the analyze-once / solve-many
contract.

(a) structure cache — a second ``analyze`` with an identical (structure,
    b, grid, options) returns the *same* engine object, compiled program
    included; different options miss;
(b) no retrace — repeated ``solve`` calls of one shape class reuse the
    jitted sweep (trace counter flat after warmup), including the
    batched shape;
(c) batching — ``solve`` over a leading batch axis of B same-structure
    matrices is bit-identical (f64, ≤1e-12; observed exact) to a Python
    loop of single solves;
(d) shim equivalence — ``run_distributed`` (now a thin shim over the
    engine) returns exactly what the explicit PlanOptions engine path
    returns, for both overlapped and level-serial options.
"""
import numpy as np
import pytest

from conftest import run_sub

from repro.core import sparse
from repro.core.engine import (Grid, PlanOptions, PSelInvEngine,
                               structure_key)
from repro.core.schedule import Grid2D


def test_grid_is_the_session_alias():
    """The engine API's Grid *is* schedule.Grid2D — one grid type, no
    parallel definition to drift."""
    assert Grid is Grid2D


def test_structure_key_content_hash():
    """Equal structures (independently symbolic-factorized) hash equal;
    a different sparsity structure hashes different."""
    import scipy.sparse as sp
    from repro.core.symbolic import symbolic_factorize
    A = sparse.laplacian_2d(12, 8)
    bs1 = symbolic_factorize(sp.csr_matrix(A), max_supernode=8)
    bs2 = symbolic_factorize(sp.csr_matrix(A + sp.identity(A.shape[0])),
                             max_supernode=8)   # same pattern, new values
    bs3 = symbolic_factorize(sp.csr_matrix(sparse.laplacian_2d(16, 8)),
                             max_supernode=8)
    assert structure_key(bs1) == structure_key(bs2)
    assert structure_key(bs1) != structure_key(bs3)


def test_engine_structure_cache_and_no_retrace():
    """Cache-hit + retrace contract, executed on 8 devices: the second
    analyze of an identical structure is a cache hit returning the same
    session; solve re-traces neither across repeated single solves nor
    across repeated batched solves of one shape."""
    run_sub("""
        import numpy as np
        import scipy.sparse as sp
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.engine import Grid, PlanOptions, PSelInvEngine

        A = sparse.laplacian_2d(12, 8)
        PSelInvEngine.clear_cache()
        e1 = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                   options=PlanOptions())
        # same structure, different values, independently analyzed
        e2 = PSelInvEngine.analyze(A + sp.identity(A.shape[0]), b=8,
                                   grid=Grid(4, 2), options=PlanOptions())
        assert e2 is e1, "identical structure must return the cached engine"
        assert e2.program is e1.program
        assert PSelInvEngine.cache_hits == 1
        assert PSelInvEngine.cache_misses == 1
        # options are part of the key: a different window is a new session
        e3 = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                   options=PlanOptions(window=2))
        assert e3 is not e1
        assert PSelInvEngine.cache_misses == 2

        # ---- solve does not retrace (trace counter flat after warmup)
        v = e1.prepare_values(A)
        out1 = e1.solve(v)
        t0 = e1.trace_count
        assert t0 >= 1
        out2 = e1.solve(v)
        assert e1.trace_count == t0, "second solve of one shape retraced"
        assert np.asarray(out1).shape == np.asarray(out2).shape

        # batched shape class: one extra trace, then flat
        from repro.core.engine import stack_values
        vb = stack_values([v, v, v])
        e1.solve(vb)
        tb = e1.trace_count
        e1.solve(vb)
        assert e1.trace_count == tb, "second batched solve retraced"
        print("OK")
    """)


def test_engine_batched_solve_matches_single_loop():
    """solve(values[B]) over B same-structure matrices is bit-identical
    (f64) to a loop of single solves, and matches the dense oracle on
    the selected pattern for every batch member."""
    run_sub("""
        import numpy as np
        import scipy.sparse as sp
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.engine import Grid, PlanOptions, PSelInvEngine
        from repro.core.pselinv_dist import gather_blocks
        from repro.core.selinv import dense_selinv_oracle

        A = sparse.laplacian_2d(12, 8)
        mats = [A + sp.identity(A.shape[0]) * c for c in (0.0, 0.25, 1.0)]
        eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                    options=PlanOptions())
        outs_b = np.asarray(eng.solve_many(mats, dtype=jnp.float64))
        assert outs_b.shape[0] == 3
        for i, M in enumerate(mats):
            single = np.asarray(eng.solve(M, dtype=jnp.float64))
            d = abs(outs_b[i] - single).max()
            assert d <= 1e-12, (i, d)
            ref = dense_selinv_oracle(M)
            blocks = gather_blocks(outs_b[i], eng)   # engine accepted
            bs = eng.bs
            err = 0.0
            for K in range(bs.nsuper):
                err = max(err, abs(blocks[K, K]
                                   - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
                for I in bs.struct[K]:
                    I = int(I)
                    err = max(err, abs(blocks[I, K]
                                       - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
            assert err < 1e-9, (i, err)
        print("OK")
    """, x64=True)


def test_planoptions_roundtrip_through_run_distributed_shim():
    """run_distributed(kind=..., overlap=...) is a pure shim: its output
    equals the explicit PSelInvEngine path with the equivalent
    PlanOptions, bit-for-bit, for both executors — and its program is
    the engine's cached program object."""
    run_sub("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.engine import Grid, PlanOptions, PSelInvEngine
        from repro.core.pselinv_dist import run_distributed
        from repro.core.trees import TreeKind

        A = sparse.laplacian_2d(12, 8)
        for overlap in (True, False):
            opts = PlanOptions(kind=TreeKind.SHIFTED, overlap=overlap)
            eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                        options=opts)
            out_e = np.asarray(eng.solve(A, dtype=jnp.float64))
            out_s, prog = run_distributed(A, b=8, pr=4, pc=2,
                                          kind=TreeKind.SHIFTED,
                                          dtype=jnp.float64,
                                          overlap=overlap)
            assert prog is eng.program, "shim bypassed the engine cache"
            assert abs(out_s - out_e).max() == 0.0
        print("OK")
    """, x64=True)


def test_engine_simulate_and_stats():
    """engine.simulate()/round_schedule() derive the executed timeline
    from the cached program without re-lowering, and simulate_schedule
    accepts the engine/program directly (no loose (exec, plan) args)."""
    from repro.core.plan import peak_arena_blocks, ppermute_round_count
    from repro.core.simulator import (RoundSchedule, round_schedule_of,
                                      simulate_schedule)
    A = sparse.laplacian_2d(12, 8)
    PSelInvEngine.clear_cache()
    eng = PSelInvEngine.analyze(A, b=8, grid=Grid(1, 1),
                                options=PlanOptions())
    rs = eng.round_schedule()
    assert isinstance(rs, RoundSchedule)
    assert eng.round_schedule() is rs          # cached, not re-lowered
    sim = eng.simulate()
    assert sim.peak_arena_blocks == peak_arena_blocks(
        eng.program.overlap_plan)
    st = eng.stats()
    assert st["ppermute_rounds"] == ppermute_round_count(
        eng.program.overlap_plan)
    assert st["peak_arena_blocks"] == sim.peak_arena_blocks
    # cache-health counters ride along (the serving layer reads them)
    assert st["cache_engines"] == len(PSelInvEngine._cache)
    assert st["cache_hits"] == PSelInvEngine.cache_hits
    assert st["cache_misses"] == PSelInvEngine.cache_misses
    assert st["cache_evictions"] == PSelInvEngine.cache_evictions
    assert st["table_bytes"] == eng.table_bytes() > 0
    # simulate_schedule takes the engine (or program) and derives the
    # schedule itself
    sim2 = simulate_schedule(eng)
    assert sim2.total_time == sim.total_time
    assert round_schedule_of(eng.program).peak_arena_blocks == \
        sim.peak_arena_blocks


def test_engine_rejects_bad_inputs():
    """analyze validates grid vs devices (the canonical diagnostic) and
    solve validates value rank; prepare_values rejects a wrong-size
    matrix instead of silently mis-slicing — and, crucially, a same-size
    matrix whose sparsity pattern escapes the analyzed structure (the
    structured factorization would silently truncate it into the
    selected inverse of a different matrix)."""
    import scipy.sparse as sp
    A = sparse.laplacian_2d(12, 8)
    with pytest.raises(ValueError, match=r"grid 64x64 needs 4096 devices"):
        PSelInvEngine.analyze(A, b=8, grid=Grid(64, 64))
    eng = PSelInvEngine.analyze(A, b=8, grid=Grid(1, 1),
                                options=PlanOptions())
    with pytest.raises(ValueError, match=r"rank 5 .* rank 6"):
        eng.solve((np.zeros((4, 4)), np.zeros((4, 4))), dtype=None)
    with pytest.raises(ValueError, match=r"does not match the analyzed"):
        eng.prepare_values(sparse.laplacian_2d(16, 8))
    B = sp.lil_matrix(A)
    B[0, 95] = B[95, 0] = 1.0           # same n, out-of-structure block
    with pytest.raises(ValueError, match=r"outside the analyzed block"):
        eng.prepare_values(B)
    # same pattern, different values still flows through the guard
    eng.prepare_values(A + sp.identity(A.shape[0]) * 0.5)


def test_engine_cache_eviction_bound():
    """The structure cache is LRU-bounded (a long-lived server over a
    stream of distinct structures must not pin every session forever):
    exceeding cache_max evicts the least-recently-used session, and
    re-analyzing an evicted structure builds a fresh engine."""
    PSelInvEngine.clear_cache()
    old = PSelInvEngine.cache_max
    PSelInvEngine.cache_max = 2
    try:
        engines = [PSelInvEngine.analyze(sparse.laplacian_2d(nx, 8),
                                         b=8, grid=Grid(1, 1),
                                         options=PlanOptions())
                   for nx in (4, 6, 8)]
        assert len(PSelInvEngine._cache) == 2
        again = PSelInvEngine.analyze(sparse.laplacian_2d(8, 8), b=8,
                                      grid=Grid(1, 1),
                                      options=PlanOptions())
        assert again is engines[2]      # newest still cached
        fresh = PSelInvEngine.analyze(sparse.laplacian_2d(4, 8), b=8,
                                      grid=Grid(1, 1),
                                      options=PlanOptions())
        assert fresh is not engines[0]  # oldest was evicted
    finally:
        PSelInvEngine.cache_max = old
        PSelInvEngine.clear_cache()


def test_engine_cache_lru_hit_keeps_session_warm():
    """A cache *hit* moves the session to the back of the eviction
    queue: with cache_max=2, re-hitting the oldest of two sessions
    makes the *other* one the eviction victim — the serving layer's hot
    structures stay resident however old they are."""
    PSelInvEngine.clear_cache()
    old = PSelInvEngine.cache_max
    PSelInvEngine.cache_max = 2
    try:
        e4 = PSelInvEngine.analyze(sparse.laplacian_2d(4, 8), b=8,
                                   grid=Grid(1, 1), options=PlanOptions())
        PSelInvEngine.analyze(sparse.laplacian_2d(6, 8), b=8,
                              grid=Grid(1, 1), options=PlanOptions())
        # hit the older session: under FIFO it would still be evicted
        # next; under LRU the hit re-warms it
        assert PSelInvEngine.analyze(sparse.laplacian_2d(4, 8), b=8,
                                     grid=Grid(1, 1),
                                     options=PlanOptions()) is e4
        PSelInvEngine.analyze(sparse.laplacian_2d(8, 8), b=8,
                              grid=Grid(1, 1), options=PlanOptions())
        assert PSelInvEngine.cache_evictions >= 1
        again = PSelInvEngine.analyze(sparse.laplacian_2d(4, 8), b=8,
                                      grid=Grid(1, 1),
                                      options=PlanOptions())
        assert again is e4, "the re-hit session was evicted (FIFO?)"
    finally:
        PSelInvEngine.cache_max = old
        PSelInvEngine.clear_cache()


def test_engine_cache_byte_bound_eviction():
    """The size-aware bound: with cache_max_bytes below two sessions'
    summed table footprint, inserting the second evicts the first even
    though the session *count* is under cache_max — but the newest
    session itself always stays (one over-budget structure must still
    solve)."""
    PSelInvEngine.clear_cache()
    old_max, old_bytes = (PSelInvEngine.cache_max,
                          PSelInvEngine.cache_max_bytes)
    try:
        e1 = PSelInvEngine.analyze(sparse.laplacian_2d(4, 8), b=8,
                                   grid=Grid(1, 1), options=PlanOptions())
        assert e1.table_bytes() > 0
        PSelInvEngine.cache_max_bytes = e1.table_bytes()  # room for ~one
        ev0 = PSelInvEngine.cache_evictions
        e2 = PSelInvEngine.analyze(sparse.laplacian_2d(6, 8), b=8,
                                   grid=Grid(1, 1), options=PlanOptions())
        assert PSelInvEngine.cache_evictions == ev0 + 1
        assert list(PSelInvEngine._cache.values()) == [e2]
        assert PSelInvEngine.cache_bytes() == e2.table_bytes()
        # the lone over-budget session is never evicted by its own insert
        assert e2.table_bytes() > PSelInvEngine.cache_max_bytes \
            or len(PSelInvEngine._cache) == 1
    finally:
        PSelInvEngine.cache_max = old_max
        PSelInvEngine.cache_max_bytes = old_bytes
        PSelInvEngine.clear_cache()


def test_engine_bucketed_solve_shares_pow2_programs():
    """bucket=True bounds the compiled-program population: organic batch
    sizes 3, 5, 13 ride the B=4, 8, 16 programs (three traces), and
    later exact power-of-2 batches add none — while every padded result
    still matches its unbatched solve."""
    run_sub("""
        import numpy as np
        import scipy.sparse as sp
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.engine import (Grid, PlanOptions, PSelInvEngine,
                                       bucket_size)

        A = sparse.laplacian_2d(12, 8)
        I = sp.identity(A.shape[0])
        eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                    options=PlanOptions())
        singles = {}
        t0 = eng.trace_count
        for B in (3, 5, 13):
            mats = [A + 0.1 * (B + i) * I for i in range(B)]
            out = np.asarray(eng.solve_many(mats, dtype=jnp.float64,
                                            bucket=True))
            assert out.shape[0] == B, out.shape      # pad sliced off
            for i in (0, B - 1):
                ref = np.asarray(eng.solve(mats[i], dtype=jnp.float64))
                assert abs(out[i] - ref).max() <= 1e-12
        assert eng.trace_count == t0 + 3 + 1, (
            "expected one batched trace per bucket {4, 8, 16} plus the "
            f"rank-5 single-solve trace, got {eng.trace_count - t0}")
        # exact power-of-2 batches reuse those same programs: no traces
        t1 = eng.trace_count
        for B in (4, 8, 16):
            mats = [A + 0.01 * (B + i) * I for i in range(B)]
            eng.solve_many(mats, dtype=jnp.float64, bucket=True)
        assert eng.trace_count == t1, "pow2 batches retraced"
        print("OK")
    """, x64=True)


def test_prepare_values_many_matches_per_matrix_path():
    """The stacked host factorization is numerically the per-matrix
    path: prepare_values_many over shifted copies matches a loop of
    prepare_values to ≤1e-12 (f64), and a bad-pattern member fails with
    its batch index named while the pure per-matrix error is unchanged."""
    import scipy.sparse as sp
    from repro.core.engine import stack_values
    A = sparse.laplacian_2d(12, 8)
    I_A = sp.identity(A.shape[0])
    mats = [A + c * I_A for c in (0.0, 0.25, 1.0, 2.0)]
    eng = PSelInvEngine.analyze(A, b=8, grid=Grid(1, 1),
                                options=PlanOptions())
    many = eng.prepare_values_many(mats)
    loop = stack_values([eng.prepare_values(M) for M in mats])
    assert many.Lh.shape == loop.Lh.shape
    assert abs(many.Lh - loop.Lh).max() <= 1e-12
    assert abs(many.Dinv - loop.Dinv).max() <= 1e-12
    # a member whose pattern escapes the structure names its index
    B = sp.lil_matrix(A)
    B[0, 95] = B[95, 0] = 1.0
    with pytest.raises(ValueError,
                       match=r"matrix 2 of 3:.*outside the analyzed"):
        eng.prepare_values_many([mats[0], mats[1], sp.csr_matrix(B)])
