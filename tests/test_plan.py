"""CommPlan IR tests: the single-derivation guarantees.

(a) bytes equivalence — per-rank byte counts summed over the *compiled*
    executor rounds (level-serial AND cross-level overlapped) equal
    ``simulator.volumes`` on the same structure/grid/tree-kind
    (simulated bytes == executed bytes);
(b) oracle — the IR sweeps (overlapped and level-serial) match the dense
    inverse on the selected pattern for several (pr, pc, TreeKind)
    combinations, and agree with the legacy unrolled sweep;
(c) overlap — the global round stream respects every producer→consumer
    round dependence, coalesces multi-block (src,dst) payloads, and
    issues fewer ppermute rounds than the level-serial path;
(d) fast-path drift — ``volumes_fast`` is bit-identical to the slow
    ``volumes`` for all four TreeKinds, including HYBRID at the
    flat/shifted boundary participant counts (24 and 25);
plus structural invariants of the level batching and the merged-round
diagnostics.
"""
import numpy as np
import pytest
import scipy.sparse as sp

from conftest import run_sub

from repro.core import sparse
from repro.core.plan import (build_plan, compile_exec, etree_levels,
                             exec_byte_counts, merge_round_lists,
                             ppermute_round_count, schedule_overlapped)
from repro.core.schedule import Grid2D
from repro.core.simulator import volumes, volumes_fast
from repro.core.symbolic import BlockStructure, symbolic_factorize
from repro.core.trees import HYBRID_FLAT_MAX, TreeKind, build_tree

@pytest.fixture(scope="module")
def lap_bs():
    A = sparse.laplacian_2d(12, 8)
    return A, symbolic_factorize(sp.csr_matrix(A), max_supernode=8)


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2), (2, 4)])
@pytest.mark.parametrize("kind",
                         [TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED])
def test_exec_bytes_match_volumes(lap_bs, pr, pc, kind):
    """The bytes the compiled device program moves are the bytes the
    simulator accounts — same plan, independent accounting paths."""
    _, bs = lap_bs
    grid = Grid2D(pr, pc)
    plan = build_plan(bs, grid, kind, nb=12)
    out_e, inc_e = exec_byte_counts(compile_exec(plan))
    out_v, inc_v = volumes(bs, grid, kind)
    z = np.zeros(grid.size)
    for k in ("xfer", "col-bcast"):
        np.testing.assert_allclose(out_e.get(k, z), out_v.get(k, z))
        np.testing.assert_allclose(inc_e.get(k, z), inc_v.get(k, z))
    # volumes reports reductions in broadcast orientation (§4.1 counts
    # received volume at the combining node): mirror to wire direction
    np.testing.assert_allclose(out_e.get("row-reduce", z),
                               inc_v.get("row-reduce", z))
    np.testing.assert_allclose(inc_e.get("row-reduce", z),
                               out_v.get("row-reduce", z))


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2), (2, 4)])
@pytest.mark.parametrize("kind",
                         [TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED])
def test_overlapped_bytes_match_volumes(lap_bs, pr, pc, kind):
    """Coalescing + cross-level interleaving move the same bytes in fewer
    rounds: the overlapped stream's per-rank byte counts equal the
    simulator's volumes (and hence the level-serial executor's)."""
    _, bs = lap_bs
    grid = Grid2D(pr, pc)
    plan = build_plan(bs, grid, kind, nb=12)
    out_e, inc_e = exec_byte_counts(schedule_overlapped(plan))
    out_v, inc_v = volumes(bs, grid, kind)
    z = np.zeros(grid.size)
    for k in ("xfer", "col-bcast"):
        np.testing.assert_allclose(out_e.get(k, z), out_v.get(k, z))
        np.testing.assert_allclose(inc_e.get(k, z), inc_v.get(k, z))
    np.testing.assert_allclose(out_e.get("row-reduce", z),
                               inc_v.get("row-reduce", z))
    np.testing.assert_allclose(inc_e.get("row-reduce", z),
                               out_v.get("row-reduce", z))


def _overlap_boundaries(ov):
    """Round boundary of each (compute kind, level) of the stream."""
    at = {}
    for t, ops in enumerate(ov.compute_at):
        for op in ops:
            at[(op.kind, op.level)] = t
    return at


@pytest.mark.parametrize("kind", [TreeKind.FLAT, TreeKind.SHIFTED])
def test_overlapped_respects_round_dependences(lap_bs, kind):
    """Every producer→consumer dependence of the sweep holds in the
    global round sequence: a level's xfer-in/col-bcast rounds precede its
    GEMM boundary, its reduce rounds sit between GEMM and column write,
    xfer-out follows the write, diag-reduce follows the S computation,
    the diagonal write follows its reduces — and level L's GEMM fires
    only after level L-1 finished every A⁻¹ write."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(4, 2), kind, nb=12)
    ov = schedule_overlapped(plan)
    at = _overlap_boundaries(ov)
    nlev = len(ov.levels)

    rounds_of = {}          # (kind, level) -> list of round indices
    for t, rnd in enumerate(ov.rounds):
        for (_s, _d, k, lv, _nb) in rnd.edges:
            rounds_of.setdefault((k, lv), []).append(t)
        for (_dev, k, lv) in rnd.lmoves:
            rounds_of.setdefault((k, lv), []).append(t)

    for L in range(nlev):
        tg, tw = at[("gemm", L)], at[("write", L)]
        ts, td = at[("scomp", L)], at[("diagw", L)]
        assert tg <= tw <= ts <= td
        for k in ("xfer", "xfer-local", "col-bcast"):
            assert all(t < tg for t in rounds_of.get((k, L), []))
        assert all(tg <= t < tw for t in rounds_of.get(("row-reduce", L), []))
        for k in ("xfer-out", "xfer-out-local"):
            assert all(tw <= t < ts for t in rounds_of.get((k, L), []))
        assert all(ts <= t < td
                   for t in rounds_of.get(("diag-reduce", L), []))
        if L:
            # cross-level serialization of the A⁻¹ writes only
            prev = rounds_of.get(("xfer-out", L - 1), []) \
                + rounds_of.get(("xfer-out-local", L - 1), [])
            assert tg > at[("write", L - 1)]
            # diagw(L-1) may share gemm(L)'s boundary: compute ops within
            # one boundary execute in dependence order
            assert tg >= at[("diagw", L - 1)]
            assert all(t < tg for t in prev)

    # ...and the point of the exercise: later levels' xfer-in/col-bcast
    # traffic actually rides rounds *before* the previous level's GEMM
    # has even fired (no level barrier left)
    overlapped = [
        L for L in range(1, nlev)
        if rounds_of.get(("xfer", L), []) and
        min(rounds_of[("xfer", L)]) < at[("gemm", L - 1)]]
    assert overlapped, "no cross-level interleaving happened"


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2)])
def test_overlapped_fewer_rounds_and_coalescing(lap_bs, pr, pc):
    """The overlapped+coalesced stream issues strictly fewer ppermute
    rounds than the level-serial path, some round carries a multi-block
    (src,dst) payload, and every round still satisfies the ppermute
    constraint (unique sources / destinations across pairs, lane count
    within the coalescing cap)."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(pr, pc), TreeKind.SHIFTED, nb=12)
    ex = compile_exec(plan)
    ov = schedule_overlapped(plan, coalesce_max=8)
    assert ppermute_round_count(ov) < ppermute_round_count(ex)
    assert any(r.width > 1 for r in ov.rounds)
    for rnd in ov.rounds:
        if not rnd.perm:        # local-copy-only rounds are legal
            assert rnd.width == 0 and not rnd.edges and rnd.lwidth
            continue
        srcs = [s for s, _ in rnd.perm]
        dsts = [d for _, d in rnd.perm]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        assert rnd.width <= 8
        lanes = {}
        for (s, d, _k, _lv, _nb) in rnd.edges:
            lanes[(s, d)] = lanes.get((s, d), 0) + 1
        assert lanes, rnd
        assert max(lanes.values()) == rnd.width


def test_overlapped_u_stacks_complete_at_gemm_boundary():
    """Replay only the comm rounds of the overlapped stream (numpy, host
    side) and check that at every GEMM boundary each participating device
    holds the exact Û(K,I) = L̂(I,K)ᵀ payload. Regression test for the
    per-device slot keying: I and I+1 with equal I//pc share a flat Û
    slot number on different grid columns, and a slot-only dependence key
    once wired a broadcast's root to the *wrong* xfer-in, shipping zeros
    (caught at nb=32, grid 4×2, where struct holds consecutive
    supernodes)."""
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(32, 8)), max_supernode=8)
    pr, pc = 4, 2
    plan = build_plan(bs, Grid2D(pr, pc), TreeKind.SHIFTED, nb=32)
    ov = schedule_overlapped(plan)
    P, nbr, nbc = pr * pc, ov.nbr, ov.nbc
    N = ov.n_ainv

    # distinguishable payload per global block (I, K)
    arena = np.zeros((P, ov.arena_blocks))
    for K in range(bs.nsuper):
        for I in bs.struct[K]:
            I = int(I)
            dev = (I % pr) * pc + (K % pc)
            arena[dev, ov.lh_base + (I // pr) * nbc + K // pc] = \
                1000.0 * I + K

    gemm_at = {t: op for t, ops in enumerate(ov.compute_at)
               for op in ops if op.kind == "gemm"}

    def check_level(L):
        lv = ov.levels[L]
        for k, K in enumerate(lv.Ks):
            C = [int(x) for x in bs.struct[K]]
            for I in C:
                slot = lv.base_u + k * nbc + I // pc
                need = ({(J % pr) * pc + I % pc for J in C}
                        | {(K % pr) * pc + I % pc})
                for dev in need:
                    assert arena[dev, slot] == 1000.0 * I + K, \
                        (L, K, I, dev)

    for t, rnd in enumerate(ov.rounds):
        if t in gemm_at:
            check_level(gemm_at[t].level)
        if rnd.lwidth:
            snap = arena.copy()
            for dev in range(P):
                for j in range(rnd.lwidth):
                    arena[dev, rnd.lscatter[dev, j]] = \
                        snap[dev, rnd.lgather[dev, j]]
        if rnd.perm:
            snap = arena.copy()
            moved = np.zeros((P, rnd.width))
            for (s, d) in rnd.perm:
                moved[d] = snap[s, rnd.gather[s, :rnd.width]]
            for dev in range(P):
                for j in range(rnd.width):
                    arena[dev, rnd.scatter[dev, j]] = (
                        moved[dev, j]
                        + rnd.addm[dev, j] * snap[dev, rnd.scatter[dev, j]])
    if len(ov.rounds) in gemm_at:
        check_level(gemm_at[len(ov.rounds)].level)


def _dense_chain_bs(ns: int, w: int = 1) -> BlockStructure:
    """Dense lower-triangular block structure: struct(K) = {K+1..ns-1}
    (a path etree) — every participant count from ns down to 2 appears,
    which pins the HYBRID flat/shifted boundary exactly."""
    struct = [np.arange(K + 1, ns, dtype=np.int64) for K in range(ns)]
    return BlockStructure(
        offsets=np.arange(ns + 1, dtype=np.int64) * w,
        struct=struct, a_struct=struct,
        parent=np.array([K + 1 if K + 1 < ns else -1 for K in range(ns)],
                        dtype=np.int64))


@pytest.mark.parametrize("pr,pc", [(HYBRID_FLAT_MAX + 2, 1),
                                   (1, HYBRID_FLAT_MAX + 2)])
@pytest.mark.parametrize("kind", list(TreeKind))
def test_volumes_fast_bit_identical_at_hybrid_boundary(pr, pc, kind):
    """``volumes_fast`` must agree bit-for-bit with the slow tree-walking
    ``volumes`` for every TreeKind — in particular HYBRID straddling the
    flat→shifted threshold: the dense chain on a 26-rank axis issues
    collectives with 26, 25, 24, ... participants, so both sides of
    ``HYBRID_FLAT_MAX = 24`` (and the boundary counts 24/25 themselves)
    are exercised with the tag-derived shifted rotations."""
    bs = _dense_chain_bs(HYBRID_FLAT_MAX + 2)
    grid = Grid2D(pr, pc)
    out, _ = volumes(bs, grid, kind)
    fast = volumes_fast(bs, grid, kind)
    z = np.zeros(grid.size)
    np.testing.assert_array_equal(out.get("col-bcast", z),
                                  fast["col-bcast"])
    np.testing.assert_array_equal(out.get("row-reduce", z),
                                  fast["row-reduce"])


def test_levels_are_independent(lap_bs):
    """Same-level supernodes never appear in each other's struct — the
    condition that makes the level batching a legal reordering of the
    reverse-elimination sweep."""
    _, bs = lap_bs
    level = etree_levels(bs)
    for K in range(bs.nsuper):
        for I in bs.struct[K]:
            assert level[int(I)] < level[K]   # struct(K) ⊆ ancestors(K)


def test_plan_padding_supernodes(lap_bs):
    """Grid padding adds diag-only supernodes and no communication."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(3, 2), TreeKind.SHIFTED, nb=18)
    assert plan.nb == 18
    assert set(range(bs.nsuper, 18)) <= set(plan.diag_only)
    assert all(op.supernode < bs.nsuper for op in plan.ops)
    ex = compile_exec(plan)
    assert len(ex.diag_set_root) == len(plan.diag_only)


def test_packed_rounds_respect_ppermute_constraint(lap_bs):
    """Every compiled round has unique sources and destinations."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=12)
    ex = compile_exec(plan)
    nrounds = 0
    for lv in ex.levels:
        for rounds in (lv.xfer_in, lv.bcast, lv.reduce, lv.xfer_out,
                       lv.diag_reduce):
            for rnd in rounds:
                srcs = [s for s, _ in rnd.perm]
                dsts = [d for _, d in rnd.perm]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)
                nrounds += 1
    assert nrounds > 0


def test_merge_round_lists_collision_diagnostics():
    """Non-disjoint trees raise ValueError naming the colliding pairs."""
    t1 = build_tree(TreeKind.FLAT, 0, [1, 2])
    t2 = build_tree(TreeKind.FLAT, 0, [3])
    per_tree = [t1.bcast_rounds(), t2.bcast_rounds()]
    with pytest.raises(ValueError) as ei:
        merge_round_lists(per_tree, "bcast")
    msg = str(ei.value)
    assert "round 0" in msg and "(0, 1)" in msg and "(0, 3)" in msg


def test_batched_rounds_uses_shared_merge():
    """treecomm.batched_rounds delegates to the IR merge (disjoint trees
    merge; overlapping trees get the diagnostic ValueError)."""
    from repro.comm.treecomm import batched_rounds
    t1 = build_tree(TreeKind.BINARY, 0, [1, 2, 3])
    t2 = build_tree(TreeKind.BINARY, 0, [1, 2, 3])
    merged = batched_rounds([(t1, 0), (t2, 4)], "bcast")
    flat = [e for rnd in merged for e in rnd]
    assert len(flat) == 6 and max(max(s, d) for s, d in flat) == 7
    with pytest.raises(ValueError):
        batched_rounds([(t1, 0), (t2, 0)], "bcast")


def test_ir_sweep_matches_oracle_multi_grid():
    """The overlapped IR sweep (the default executor) reproduces the
    dense inverse on the selected pattern for several grid shapes / tree
    kinds, and agrees with both the level-serial IR executor and the
    legacy unrolled executor."""
    run_sub("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import run_distributed, gather_blocks
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(12, 8)
        ref = dense_selinv_oracle(A)
        for (pr, pc, kind) in ((2, 4, TreeKind.SHIFTED),
                               (2, 2, TreeKind.FLAT),
                               (4, 2, TreeKind.BINARY)):
            out, prog = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                        dtype=jnp.float64)   # overlapped
            out_s, _ = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                       dtype=jnp.float64, overlap=False)
            out_u, _ = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                       dtype=jnp.float64, pipelined=False)
            assert abs(out - out_s).max() < 1e-12, (pr, pc, kind)
            assert abs(out - out_u).max() < 1e-12, (pr, pc, kind)
            blocks = gather_blocks(out, prog)
            bs = prog.bs
            err = 0.0
            for K in range(bs.nsuper):
                err = max(err, abs(blocks[K, K]
                                   - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
                for I in bs.struct[K]:
                    I = int(I)
                    err = max(err, abs(blocks[I, K]
                                       - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
            assert err < 1e-9, (pr, pc, kind, err)
        print("OK")
    """, x64=True)
