"""CommPlan IR tests: the single-derivation guarantees.

(a) bytes equivalence — per-rank byte counts summed over the *compiled*
    executor rounds (level-serial AND cross-level overlapped) equal
    ``simulator.volumes`` on the same structure/grid/tree-kind
    (simulated bytes == executed bytes);
(b) oracle — the IR sweeps (overlapped and level-serial) match the dense
    inverse on the selected pattern for several (pr, pc, TreeKind)
    combinations, and agree with the legacy unrolled sweep;
(c) overlap — the global round stream respects every producer→consumer
    round dependence, coalesces multi-block (src,dst) payloads, and
    issues fewer ppermute rounds than the level-serial path;
(d) fast-path drift — ``volumes_fast`` is bit-identical to the slow
    ``volumes`` for all four TreeKinds, including HYBRID at the
    flat/shifted boundary participant counts (24 and 25);
plus structural invariants of the level batching and the merged-round
diagnostics.
"""
import numpy as np
import pytest
import scipy.sparse as sp

from conftest import run_sub

from repro.core import sparse
from repro.core.plan import (build_plan, compile_exec, etree_levels,
                             exec_byte_counts, merge_round_lists,
                             peak_arena_blocks, ppermute_round_count,
                             schedule_overlapped, tree_for)
from repro.core.schedule import Grid2D
from repro.core.simulator import (round_schedule_from_overlap,
                                  simulate_schedule, volumes, volumes_fast)
from repro.core.symbolic import BlockStructure, symbolic_factorize
from repro.core.trees import HYBRID_FLAT_MAX, TreeKind, build_tree

@pytest.fixture(scope="module")
def lap_bs():
    A = sparse.laplacian_2d(12, 8)
    return A, symbolic_factorize(sp.csr_matrix(A), max_supernode=8)


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2), (2, 4)])
@pytest.mark.parametrize("kind",
                         [TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED])
def test_exec_bytes_match_volumes(lap_bs, pr, pc, kind):
    """The bytes the compiled device program moves are the bytes the
    simulator accounts — same plan, independent accounting paths."""
    _, bs = lap_bs
    grid = Grid2D(pr, pc)
    plan = build_plan(bs, grid, kind, nb=12)
    out_e, inc_e = exec_byte_counts(compile_exec(plan))
    out_v, inc_v = volumes(bs, grid, kind)
    z = np.zeros(grid.size)
    for k in ("xfer", "col-bcast"):
        np.testing.assert_allclose(out_e.get(k, z), out_v.get(k, z))
        np.testing.assert_allclose(inc_e.get(k, z), inc_v.get(k, z))
    # volumes reports reductions in broadcast orientation (§4.1 counts
    # received volume at the combining node): mirror to wire direction
    np.testing.assert_allclose(out_e.get("row-reduce", z),
                               inc_v.get("row-reduce", z))
    np.testing.assert_allclose(inc_e.get("row-reduce", z),
                               out_v.get("row-reduce", z))


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2), (2, 4)])
@pytest.mark.parametrize("kind",
                         [TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED])
def test_overlapped_bytes_match_volumes(lap_bs, pr, pc, kind):
    """Coalescing + cross-level interleaving move the same bytes in fewer
    rounds: the overlapped stream's per-rank byte counts equal the
    simulator's volumes (and hence the level-serial executor's)."""
    _, bs = lap_bs
    grid = Grid2D(pr, pc)
    plan = build_plan(bs, grid, kind, nb=12)
    out_e, inc_e = exec_byte_counts(schedule_overlapped(plan))
    out_v, inc_v = volumes(bs, grid, kind)
    z = np.zeros(grid.size)
    for k in ("xfer", "col-bcast"):
        np.testing.assert_allclose(out_e.get(k, z), out_v.get(k, z))
        np.testing.assert_allclose(inc_e.get(k, z), inc_v.get(k, z))
    np.testing.assert_allclose(out_e.get("row-reduce", z),
                               inc_v.get("row-reduce", z))
    np.testing.assert_allclose(inc_e.get("row-reduce", z),
                               out_v.get("row-reduce", z))


def _overlap_boundaries(ov):
    """Round boundary of each (compute kind, level) of the stream."""
    at = {}
    for t, ops in enumerate(ov.compute_at):
        for op in ops:
            at[(op.kind, op.level)] = t
    return at


@pytest.mark.parametrize("kind", [TreeKind.FLAT, TreeKind.SHIFTED])
def test_overlapped_respects_round_dependences(lap_bs, kind):
    """Every producer→consumer dependence of the sweep holds in the
    global round sequence: a level's xfer-in/col-bcast rounds precede its
    GEMM boundary, its reduce rounds sit between GEMM and column write,
    xfer-out follows the write, diag-reduce follows the S computation,
    the diagonal write follows its reduces — and level L's GEMM fires
    only after level L-1 finished every A⁻¹ write."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(4, 2), kind, nb=12)
    ov = schedule_overlapped(plan)
    at = _overlap_boundaries(ov)
    nlev = len(ov.levels)

    rounds_of = {}          # (kind, level) -> list of round indices
    for t, rnd in enumerate(ov.rounds):
        for (_s, _d, k, lv, _nb) in rnd.edges:
            rounds_of.setdefault((k, lv), []).append(t)
        for (_dev, k, lv) in rnd.lmoves:
            rounds_of.setdefault((k, lv), []).append(t)

    for L in range(nlev):
        tg, tw = at[("gemm", L)], at[("write", L)]
        ts, td = at[("scomp", L)], at[("diagw", L)]
        assert tg <= tw <= ts <= td
        for k in ("xfer", "xfer-local", "col-bcast"):
            assert all(t < tg for t in rounds_of.get((k, L), []))
        assert all(tg <= t < tw for t in rounds_of.get(("row-reduce", L), []))
        for k in ("xfer-out", "xfer-out-local"):
            assert all(tw <= t < ts for t in rounds_of.get((k, L), []))
        assert all(ts <= t < td
                   for t in rounds_of.get(("diag-reduce", L), []))
        if L:
            # cross-level serialization of the A⁻¹ writes only
            prev = rounds_of.get(("xfer-out", L - 1), []) \
                + rounds_of.get(("xfer-out-local", L - 1), [])
            assert tg > at[("write", L - 1)]
            # diagw(L-1) may share gemm(L)'s boundary: compute ops within
            # one boundary execute in dependence order
            assert tg >= at[("diagw", L - 1)]
            assert all(t < tg for t in prev)

    # ...and the point of the exercise: later levels' xfer-in/col-bcast
    # traffic actually rides rounds *before* the previous level's GEMM
    # has even fired (no level barrier left)
    overlapped = [
        L for L in range(1, nlev)
        if rounds_of.get(("xfer", L), []) and
        min(rounds_of[("xfer", L)]) < at[("gemm", L - 1)]]
    assert overlapped, "no cross-level interleaving happened"


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2)])
def test_overlapped_fewer_rounds_and_coalescing(lap_bs, pr, pc):
    """The overlapped+coalesced stream issues strictly fewer ppermute
    rounds than the level-serial path, some round carries a multi-block
    (src,dst) payload, and every round still satisfies the ppermute
    constraint (unique sources / destinations across pairs, lane count
    within the coalescing cap)."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(pr, pc), TreeKind.SHIFTED, nb=12)
    ex = compile_exec(plan)
    ov = schedule_overlapped(plan, coalesce_max=8)
    assert ppermute_round_count(ov) < ppermute_round_count(ex)
    assert any(r.width > 1 for r in ov.rounds)
    for rnd in ov.rounds:
        if not rnd.perm:        # local-copy-only rounds are legal
            assert rnd.width == 0 and not rnd.edges and rnd.lwidth
            continue
        srcs = [s for s, _ in rnd.perm]
        dsts = [d for _, d in rnd.perm]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        assert rnd.width <= 8
        lanes = {}
        for (s, d, _k, _lv, _nb) in rnd.edges:
            lanes[(s, d)] = lanes.get((s, d), 0) + 1
        assert lanes, rnd
        assert max(lanes.values()) == rnd.width


@pytest.mark.parametrize("window", [None, 1, 2])
def test_overlapped_u_stacks_complete_at_read_boundaries(window):
    """Replay only the comm rounds of the overlapped stream (numpy, host
    side) and check that at every GEMM *and* scomp boundary each
    participating device holds the exact Û(K,I) = L̂(I,K)ᵀ payload —
    scomp is a level's *last* Û reader, so holding there proves the
    recycled slots stay intact across the whole liveness window.

    Regression test for two dependence-keying hazards: (a) per-device
    slot keying — the per-column Û allocators share one address range,
    so equal slot numbers on different grid columns hold different
    blocks, and a slot-only key once wired a broadcast's root to the
    wrong xfer-in, shipping zeros; (b) generation keying — under slot
    recycling (window=1/2 here) the same (device, slot) hosts several
    levels' payloads, and a missing WAR anti-dependence would let a new
    generation's fill clobber a slot its previous tenant still reads.

    The arena holds no L̂ copy: xfer-in lanes read the resident input
    shard through the per-lane ``glh``/``lglh`` masks, so the replay
    keeps L̂ as a separate read-only buffer exactly like the executor."""
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(32, 8)), max_supernode=8)
    pr, pc = 4, 2
    plan = build_plan(bs, Grid2D(pr, pc), TreeKind.SHIFTED, nb=32)
    ov = schedule_overlapped(plan, window=window)
    P, nbr, nbc = pr * pc, ov.nbr, ov.nbc
    N = ov.n_ainv

    if window is not None:
        # recycling must actually alias slots across generations here
        owners = {}
        aliased = 0
        for L, lv in enumerate(ov.levels):
            for dev in range(P):
                for slot in lv.u_gather[dev]:
                    if slot == ov.trash:
                        continue
                    key = (dev, int(slot))
                    if key in owners and owners[key] != L:
                        aliased += 1
                    owners[key] = L
        assert aliased, "window set but no Û slot was ever recycled"

    # distinguishable payload per global block (I, K); L̂ is its own
    # buffer (the arena holds no copy of it)
    arena = np.zeros((P, ov.arena_blocks))
    lh = np.zeros((P, N))
    for K in range(bs.nsuper):
        for I in bs.struct[K]:
            I = int(I)
            dev = (I % pr) * pc + (K % pc)
            lh[dev, (I // pr) * nbc + K // pc] = 1000.0 * I + K

    read_at = {}
    for t, ops in enumerate(ov.compute_at):
        for op in ops:
            if op.kind in ("gemm", "scomp"):
                read_at.setdefault(t, []).append(op.level)

    def check_level(L):
        lv = ov.levels[L]
        for k, K in enumerate(lv.Ks):
            C = [int(x) for x in bs.struct[K]]
            for I in C:
                need = ({(J % pr) * pc + I % pc for J in C}
                        | {(K % pr) * pc + I % pc})
                for dev in need:
                    slot = lv.u_gather[dev, k * nbc + I // pc]
                    assert slot != ov.trash, (L, K, I, dev)
                    assert arena[dev, slot] == 1000.0 * I + K, \
                        (L, K, I, dev)

    def lane_src(snap, dev, slot, from_lh):
        return lh[dev, slot] if from_lh else snap[dev, slot]

    for t, rnd in enumerate(ov.rounds):
        for L in read_at.get(t, ()):
            check_level(L)
        if rnd.lwidth:
            snap = arena.copy()
            for dev in range(P):
                for j in range(rnd.lwidth):
                    arena[dev, rnd.lscatter[dev, j]] = lane_src(
                        snap, dev, rnd.lgather[dev, j], rnd.lglh[dev, j])
        if rnd.perm:
            snap = arena.copy()
            moved = np.zeros((P, rnd.width))
            for (s, d) in rnd.perm:
                moved[d] = [lane_src(snap, s, rnd.gather[s, j],
                                     rnd.glh[s, j])
                            for j in range(rnd.width)]
            for dev in range(P):
                for j in range(rnd.width):
                    arena[dev, rnd.scatter[dev, j]] = (
                        moved[dev, j]
                        + rnd.addm[dev, j] * snap[dev, rnd.scatter[dev, j]])
    for L in read_at.get(len(ov.rounds), ()):
        check_level(L)


def _u_write_lanes(ov):
    """Reconstruct every Û-writing lane of the compiled stream as
    (round, device, arena slot, level). Lane order inside
    ``GlobalRound.edges`` follows the (pair, lane) nesting of the
    scheduler, so the lane index recovers the scatter-table column."""
    out = []
    for t, rnd in enumerate(ov.rounds):
        lane_j = {}
        for (s, d, kind, lv, _nb) in rnd.edges:
            j = lane_j.get((s, d), 0)
            lane_j[(s, d)] = j + 1
            if kind in ("xfer", "col-bcast"):
                out.append((t, d, int(rnd.scatter[d, j]), lv))
        lane_j = {}
        for (dev, kind, lv) in rnd.lmoves:
            j = lane_j.get(dev, 0)
            lane_j[dev] = j + 1
            if kind == "xfer-local":
                out.append((t, dev, int(rnd.lscatter[dev, j]), lv))
    return out


@pytest.mark.parametrize("window", [None, 1, 2])
def test_no_live_generations_alias_a_slot(window):
    """The liveness-window property: whenever two generations (levels)
    alias the same (device, arena slot), the earlier tenant's *last
    read* precedes the later tenant's *first write*.

    Û slots: a generation is live from its first fill into the slot to
    its scomp boundary (boundary t computes before round t's comm, so
    ``scomp_boundary <= first_write_round`` is exact). Shared partial /
    S regions: generation L's occupancy [gemm(L), write(L)] /
    [scomp(L), diagw(L)] must end before generation L+1's begins —
    compute ops sharing a boundary execute in ``compute_at`` list order,
    so ties are legal only with the reader listed first."""
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(32, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=32)
    ov = schedule_overlapped(plan, window=window)
    at = {(op.kind, op.level): t for t, ops in enumerate(ov.compute_at)
          for op in ops}
    nlev = len(ov.levels)

    # ---- Û pool: per (device, slot), generations must not overlap ----
    writes = {}
    for (t, dev, slot, lv) in _u_write_lanes(ov):
        writes.setdefault((dev, slot), {}).setdefault(lv, []).append(t)
    aliased = 0
    for (dev, slot), gens in writes.items():
        order = sorted(gens)
        aliased += len(order) - 1
        for la, lb in zip(order, order[1:]):
            last_read = at[("scomp", la)]
            first_write = min(gens[lb])
            assert last_read <= first_write, \
                (dev, slot, la, lb, last_read, first_write)
    if window is not None:
        assert aliased, "window set but no Û slot hosted two generations"

    # ---- shared partial / S regions: generations ordered in time -----
    def _ordered(reader, writer, L):
        tr, tw = at[(reader, L)], at[(writer, L + 1)]
        assert tr <= tw, (reader, writer, L, tr, tw)
        if tr == tw:
            ops = ov.compute_at[tr]
            ir = ops.index(next(o for o in ops
                                if o.kind == reader and o.level == L))
            iw = ops.index(next(o for o in ops
                                if o.kind == writer and o.level == L + 1))
            assert ir < iw, (reader, writer, L)

    for L in range(nlev - 1):
        _ordered("write", "gemm", L)     # partial region: last read vs
        _ordered("diagw", "scomp", L)    # next write; same for S region


@pytest.mark.parametrize("nx,max_rounds", [(16, 28), (32, 35)])
def test_recycled_arena_peak_and_rounds(nx, max_rounds):
    """The acceptance envelope of the arena recycling + copy-free L̂
    gathers: at grid 4×2 the overlapped executor's peak footprint
    (arena + the resident input L̂ shard) lands strictly *below* the
    level-serial executor's transient peak (~0.9×; before the copy-free
    gathers it was ~1.2×, before slot recycling ~3× at nb=32) while the
    ppermute round counts hold the coalesced-overlap wins (28 @ nb=16,
    35 @ nb=32 — the shift-aware packer's offset grouping pays one
    round here at 4×2 and wins two back at 8×4, for a stream wire cut
    from ~36× to ~1.6× unrolled), and the schedule simulator carries
    the peak so the bench trajectory can regression-guard it."""
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(nx, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=nx)
    ex = compile_exec(plan)
    ov = schedule_overlapped(plan)
    assert ppermute_round_count(ov) <= max_rounds
    assert peak_arena_blocks(ov) < peak_arena_blocks(ex)
    sim = simulate_schedule(round_schedule_from_overlap(ov, plan))
    assert sim.peak_arena_blocks == peak_arena_blocks(ov)
    # a tighter window trades rounds for an even smaller arena but must
    # never lose correctness or the memory bound
    ov1 = schedule_overlapped(plan, window=1)
    assert peak_arena_blocks(ov1) <= peak_arena_blocks(ov)


def _dense_chain_bs(ns: int, w: int = 1) -> BlockStructure:
    """Dense lower-triangular block structure: struct(K) = {K+1..ns-1}
    (a path etree) — every participant count from ns down to 2 appears,
    which pins the HYBRID flat/shifted boundary exactly."""
    struct = [np.arange(K + 1, ns, dtype=np.int64) for K in range(ns)]
    return BlockStructure(
        offsets=np.arange(ns + 1, dtype=np.int64) * w,
        struct=struct, a_struct=struct,
        parent=np.array([K + 1 if K + 1 < ns else -1 for K in range(ns)],
                        dtype=np.int64))


@pytest.mark.parametrize("pr,pc", [(HYBRID_FLAT_MAX + 2, 1),
                                   (1, HYBRID_FLAT_MAX + 2)])
@pytest.mark.parametrize("kind", list(TreeKind))
def test_volumes_fast_bit_identical_at_hybrid_boundary(pr, pc, kind):
    """``volumes_fast`` must agree bit-for-bit with the slow tree-walking
    ``volumes`` for every TreeKind — in particular HYBRID straddling the
    flat→shifted threshold: the dense chain on a 26-rank axis issues
    collectives with 26, 25, 24, ... participants, so both sides of
    ``HYBRID_FLAT_MAX = 24`` (and the boundary counts 24/25 themselves)
    are exercised with the tag-derived shifted rotations."""
    bs = _dense_chain_bs(HYBRID_FLAT_MAX + 2)
    grid = Grid2D(pr, pc)
    out, _ = volumes(bs, grid, kind)
    fast = volumes_fast(bs, grid, kind)
    z = np.zeros(grid.size)
    np.testing.assert_array_equal(out.get("col-bcast", z),
                                  fast["col-bcast"])
    np.testing.assert_array_equal(out.get("row-reduce", z),
                                  fast["row-reduce"])


def test_tree_for_hybrid_participant_dispatch():
    """``tree_for`` is the per-collective HYBRID dispatch keyed on
    participant count (paper §4.2): at or below ``HYBRID_FLAT_MAX``
    participants the collective is the *memoized* flat tree — the very
    object the FLAT path returns, tag-independent — and one participant
    above the boundary it becomes the tag-seeded shifted-binary tree
    with logarithmic depth."""
    root = 5
    at_max = tuple(range(HYBRID_FLAT_MAX))            # 24 participants
    t_h = tree_for(TreeKind.HYBRID, root, at_max, tag=7)
    t_f = tree_for(TreeKind.FLAT, root, at_max, tag=3)
    assert t_h is t_f                      # same memoized flat object
    assert t_h == build_tree(TreeKind.FLAT, root,
                             [r for r in at_max if r != root])
    # flat: the root feeds every receiver directly (single fan-out)
    assert t_h.children == ((root, tuple(r for r in at_max
                                         if r != root)),)
    # different tags at/below the boundary: still the one flat tree
    assert tree_for(TreeKind.HYBRID, root, at_max, tag=11) is t_h

    over = tuple(range(HYBRID_FLAT_MAX + 1))          # 25 participants
    t_h25 = tree_for(TreeKind.HYBRID, root, over, tag=7)
    assert t_h25 == build_tree(TreeKind.HYBRID, root,
                               [r for r in over if r != root], tag=7)
    # shifted-binary: internal fan-out, logarithmic receive rounds —
    # strictly shallower than the flat tree's serial send chain
    assert len(t_h25.children) > 1
    assert 1 < t_h25.depth() < t_h.depth()
    # above the boundary the tag decorrelates concurrent collectives
    assert any(tree_for(TreeKind.HYBRID, root, over, tag=tg) != t_h25
               for tg in (8, 9, 10))


def test_hybrid_kind_bit_identical_below_boundary():
    """Numeric half of the boundary test: on an 8-device 4×2 grid every
    collective has ≤ 8 < ``HYBRID_FLAT_MAX`` participants, so a HYBRID
    plan must lower to the *same rounds* as a FLAT plan and both stream
    and overlapped executors must produce f64 bit-identical (drift 0.0)
    results across the kinds."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import sparse
        from repro.core.plan import PlanOptions
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import (analyze_structure,
                                             build_program,
                                             make_sweep_overlapped,
                                             make_sweep_stream,
                                             prepare_values)
        A = sparse.laplacian_2d(16, 8)
        b, pr, pc = 8, 4, 2
        bs, nb = analyze_structure(A, b, pr, pc)
        Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
        devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
        mesh = Mesh(devs, ("xy",))
        Lh = jnp.asarray(Lh_s, jnp.float64)
        Dinv = jnp.asarray(Dinv_s, jnp.float64)

        def run(prog, mk):
            fn = jax.jit(shard_map(mk(prog), mesh=mesh,
                                   in_specs=(P("xy"), P("xy")),
                                   out_specs=P("xy")))
            return np.asarray(fn(Lh, Dinv))

        outs = {}
        for kind in (TreeKind.HYBRID, TreeKind.FLAT):
            outs[kind, "st"] = run(
                build_program(bs, nb, b, pr, pc, kind,
                              options=PlanOptions(stream=True,
                                                  kind=kind)),
                make_sweep_stream)
            outs[kind, "ov"] = run(
                build_program(bs, nb, b, pr, pc, kind, overlap=True),
                make_sweep_overlapped)
        for ex in ("st", "ov"):
            d = abs(outs[TreeKind.HYBRID, ex]
                    - outs[TreeKind.FLAT, ex]).max()
            assert d == 0.0, (ex, d)
        print("OK")
    """, x64=True)


def test_levels_are_independent(lap_bs):
    """Same-level supernodes never appear in each other's struct — the
    condition that makes the level batching a legal reordering of the
    reverse-elimination sweep."""
    _, bs = lap_bs
    level = etree_levels(bs)
    for K in range(bs.nsuper):
        for I in bs.struct[K]:
            assert level[int(I)] < level[K]   # struct(K) ⊆ ancestors(K)


def test_plan_padding_supernodes(lap_bs):
    """Grid padding adds diag-only supernodes and no communication."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(3, 2), TreeKind.SHIFTED, nb=18)
    assert plan.nb == 18
    assert set(range(bs.nsuper, 18)) <= set(plan.diag_only)
    assert all(op.supernode < bs.nsuper for op in plan.ops)
    ex = compile_exec(plan)
    assert len(ex.diag_set_root) == len(plan.diag_only)


def test_packed_rounds_respect_ppermute_constraint(lap_bs):
    """Every compiled round has unique sources and destinations."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=12)
    ex = compile_exec(plan)
    nrounds = 0
    for lv in ex.levels:
        for rounds in (lv.xfer_in, lv.bcast, lv.reduce, lv.xfer_out,
                       lv.diag_reduce):
            for rnd in rounds:
                srcs = [s for s, _ in rnd.perm]
                dsts = [d for _, d in rnd.perm]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)
                nrounds += 1
    assert nrounds > 0


def test_merge_round_lists_collision_diagnostics():
    """Non-disjoint trees raise ValueError naming the colliding pairs."""
    t1 = build_tree(TreeKind.FLAT, 0, [1, 2])
    t2 = build_tree(TreeKind.FLAT, 0, [3])
    per_tree = [t1.bcast_rounds(), t2.bcast_rounds()]
    with pytest.raises(ValueError) as ei:
        merge_round_lists(per_tree, "bcast")
    msg = str(ei.value)
    assert "round 0" in msg and "(0, 1)" in msg and "(0, 3)" in msg


def test_batched_rounds_uses_shared_merge():
    """treecomm.batched_rounds delegates to the IR merge (disjoint trees
    merge; overlapping trees get the diagnostic ValueError)."""
    from repro.comm.treecomm import batched_rounds
    t1 = build_tree(TreeKind.BINARY, 0, [1, 2, 3])
    t2 = build_tree(TreeKind.BINARY, 0, [1, 2, 3])
    merged = batched_rounds([(t1, 0), (t2, 4)], "bcast")
    flat = [e for rnd in merged for e in rnd]
    assert len(flat) == 6 and max(max(s, d) for s, d in flat) == 7
    with pytest.raises(ValueError):
        batched_rounds([(t1, 0), (t2, 0)], "bcast")


def test_ir_sweep_matches_oracle_multi_grid():
    """The overlapped IR sweep (the default executor) reproduces the
    dense inverse on the selected pattern for several grid shapes / tree
    kinds, and agrees with both the level-serial IR executor and the
    legacy unrolled executor."""
    run_sub("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import run_distributed, gather_blocks
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(12, 8)
        ref = dense_selinv_oracle(A)
        for (pr, pc, kind) in ((2, 4, TreeKind.SHIFTED),
                               (2, 2, TreeKind.FLAT),
                               (4, 2, TreeKind.BINARY)):
            out, prog = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                        dtype=jnp.float64)   # overlapped
            out_s, _ = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                       dtype=jnp.float64, overlap=False)
            out_u, _ = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                       dtype=jnp.float64, pipelined=False)
            assert abs(out - out_s).max() < 1e-12, (pr, pc, kind)
            assert abs(out - out_u).max() < 1e-12, (pr, pc, kind)
            blocks = gather_blocks(out, prog)
            bs = prog.bs
            err = 0.0
            for K in range(bs.nsuper):
                err = max(err, abs(blocks[K, K]
                                   - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
                for I in bs.struct[K]:
                    I = int(I)
                    err = max(err, abs(blocks[I, K]
                                       - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
            assert err < 1e-9, (pr, pc, kind, err)
        print("OK")
    """, x64=True)


def test_overlapped_recycled_matches_serial_nb32():
    """End-to-end oracle under *forced* Û slot reuse: nb=32 on grid 4×2
    with window=1 (every level recycles the previous level's compact Û
    slots, plus the always-shared partial/S regions) must match the
    level-serial executor bit-tight (≤1e-12 in f64) and the dense
    inverse on the selected pattern — the executed proof that the
    generation anti-dependences make aliasing safe, not just the host
    replay."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import sparse
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import (build_program, make_sweep,
                                             make_sweep_overlapped,
                                             prepare_inputs, gather_blocks,
                                             run_distributed)
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(32, 8)
        b, pr, pc = 8, 4, 2
        bs, nb, Lh_s, Dinv_s = prepare_inputs(A, b, pr, pc)
        devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
        mesh = Mesh(devs, ("xy",))
        Lh = jnp.asarray(Lh_s, jnp.float64)
        Dinv = jnp.asarray(Dinv_s, jnp.float64)

        def run(prog, mk):
            fn = jax.jit(shard_map(mk(prog), mesh=mesh,
                                   in_specs=(P("xy"), P("xy")),
                                   out_specs=P("xy")))
            return np.asarray(fn(Lh, Dinv))

        prog_s = build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED)
        out_s = run(prog_s, make_sweep)
        prog_w = build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED,
                               overlap=True, window=1)
        assert prog_w.overlap_plan.arena_blocks < 400  # recycled arena
        out_w = run(prog_w, make_sweep_overlapped)
        assert abs(out_w - out_s).max() < 1e-12, abs(out_w - out_s).max()

        ref = dense_selinv_oracle(A)
        blocks = gather_blocks(out_w, prog_w)
        err = 0.0
        for K in range(bs.nsuper):
            err = max(err, abs(blocks[K, K]
                               - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
            for I in bs.struct[K]:
                I = int(I)
                err = max(err, abs(blocks[I, K]
                                   - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
        assert err < 1e-9, err
        print("OK")
    """, x64=True, timeout=600)
