"""CommPlan IR tests: the single-derivation guarantees.

(a) bytes equivalence — per-rank byte counts summed over the *compiled*
    executor rounds equal ``simulator.volumes`` on the same
    structure/grid/tree-kind (simulated bytes == executed bytes);
(b) oracle — the level-pipelined IR sweep matches the dense inverse on
    the selected pattern for several (pr, pc, TreeKind) combinations,
    and agrees with the legacy unrolled sweep;
plus structural invariants of the level batching and the merged-round
diagnostics.
"""
import numpy as np
import pytest
import scipy.sparse as sp

from conftest import run_sub

from repro.core import sparse
from repro.core.plan import (build_plan, compile_exec, etree_levels,
                             exec_byte_counts, merge_round_lists)
from repro.core.schedule import Grid2D
from repro.core.simulator import volumes
from repro.core.symbolic import symbolic_factorize
from repro.core.trees import TreeKind, build_tree

@pytest.fixture(scope="module")
def lap_bs():
    A = sparse.laplacian_2d(12, 8)
    return A, symbolic_factorize(sp.csr_matrix(A), max_supernode=8)


@pytest.mark.parametrize("pr,pc", [(4, 2), (2, 2), (2, 4)])
@pytest.mark.parametrize("kind",
                         [TreeKind.FLAT, TreeKind.BINARY, TreeKind.SHIFTED])
def test_exec_bytes_match_volumes(lap_bs, pr, pc, kind):
    """The bytes the compiled device program moves are the bytes the
    simulator accounts — same plan, independent accounting paths."""
    _, bs = lap_bs
    grid = Grid2D(pr, pc)
    plan = build_plan(bs, grid, kind, nb=12)
    out_e, inc_e = exec_byte_counts(compile_exec(plan))
    out_v, inc_v = volumes(bs, grid, kind)
    z = np.zeros(grid.size)
    for k in ("xfer", "col-bcast"):
        np.testing.assert_allclose(out_e.get(k, z), out_v.get(k, z))
        np.testing.assert_allclose(inc_e.get(k, z), inc_v.get(k, z))
    # volumes reports reductions in broadcast orientation (§4.1 counts
    # received volume at the combining node): mirror to wire direction
    np.testing.assert_allclose(out_e.get("row-reduce", z),
                               inc_v.get("row-reduce", z))
    np.testing.assert_allclose(inc_e.get("row-reduce", z),
                               out_v.get("row-reduce", z))


def test_levels_are_independent(lap_bs):
    """Same-level supernodes never appear in each other's struct — the
    condition that makes the level batching a legal reordering of the
    reverse-elimination sweep."""
    _, bs = lap_bs
    level = etree_levels(bs)
    for K in range(bs.nsuper):
        for I in bs.struct[K]:
            assert level[int(I)] < level[K]   # struct(K) ⊆ ancestors(K)


def test_plan_padding_supernodes(lap_bs):
    """Grid padding adds diag-only supernodes and no communication."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(3, 2), TreeKind.SHIFTED, nb=18)
    assert plan.nb == 18
    assert set(range(bs.nsuper, 18)) <= set(plan.diag_only)
    assert all(op.supernode < bs.nsuper for op in plan.ops)
    ex = compile_exec(plan)
    assert len(ex.diag_set_root) == len(plan.diag_only)


def test_packed_rounds_respect_ppermute_constraint(lap_bs):
    """Every compiled round has unique sources and destinations."""
    _, bs = lap_bs
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=12)
    ex = compile_exec(plan)
    nrounds = 0
    for lv in ex.levels:
        for rounds in (lv.xfer_in, lv.bcast, lv.reduce, lv.xfer_out,
                       lv.diag_reduce):
            for rnd in rounds:
                srcs = [s for s, _ in rnd.perm]
                dsts = [d for _, d in rnd.perm]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)
                nrounds += 1
    assert nrounds > 0


def test_merge_round_lists_collision_diagnostics():
    """Non-disjoint trees raise ValueError naming the colliding pairs."""
    t1 = build_tree(TreeKind.FLAT, 0, [1, 2])
    t2 = build_tree(TreeKind.FLAT, 0, [3])
    per_tree = [t1.bcast_rounds(), t2.bcast_rounds()]
    with pytest.raises(ValueError) as ei:
        merge_round_lists(per_tree, "bcast")
    msg = str(ei.value)
    assert "round 0" in msg and "(0, 1)" in msg and "(0, 3)" in msg


def test_batched_rounds_uses_shared_merge():
    """treecomm.batched_rounds delegates to the IR merge (disjoint trees
    merge; overlapping trees get the diagnostic ValueError)."""
    from repro.comm.treecomm import batched_rounds
    t1 = build_tree(TreeKind.BINARY, 0, [1, 2, 3])
    t2 = build_tree(TreeKind.BINARY, 0, [1, 2, 3])
    merged = batched_rounds([(t1, 0), (t2, 4)], "bcast")
    flat = [e for rnd in merged for e in rnd]
    assert len(flat) == 6 and max(max(s, d) for s, d in flat) == 7
    with pytest.raises(ValueError):
        batched_rounds([(t1, 0), (t2, 0)], "bcast")


def test_ir_sweep_matches_oracle_multi_grid():
    """The level-pipelined IR sweep reproduces the dense inverse on the
    selected pattern for two grid shapes / tree kinds, and agrees with
    the legacy unrolled executor."""
    run_sub("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import run_distributed, gather_blocks
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(12, 8)
        ref = dense_selinv_oracle(A)
        for (pr, pc, kind) in ((2, 4, TreeKind.SHIFTED),
                               (2, 2, TreeKind.FLAT),
                               (4, 2, TreeKind.BINARY)):
            out, prog = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                        dtype=jnp.float64)
            out_u, _ = run_distributed(A, b=8, pr=pr, pc=pc, kind=kind,
                                       dtype=jnp.float64, pipelined=False)
            assert abs(out - out_u).max() < 1e-12, (pr, pc, kind)
            blocks = gather_blocks(out, prog)
            bs = prog.bs
            err = 0.0
            for K in range(bs.nsuper):
                err = max(err, abs(blocks[K, K]
                                   - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
                for I in bs.struct[K]:
                    I = int(I)
                    err = max(err, abs(blocks[I, K]
                                       - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
            assert err < 1e-9, (pr, pc, kind, err)
        print("OK")
    """, x64=True)
