"""Substrate tests: optimizer, data pipeline, checkpointing, train loop
fault tolerance, serve engine, compression."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.comm.compression import dequantize_int8, ef_compress, quantize_int8
from repro.config import get_config, reduced_config
from repro.data.pipeline import SyntheticTokens
from repro.models import get_model
from repro.optim import adamw_init, adamw_update, cosine_warmup
from repro.runtime.train_loop import TrainLoopConfig, run_train_loop
from repro.runtime.serve_loop import Request, ServeEngine


def test_adamw_descends_quadratic():
    w = {"w": jnp.ones((8,)) * 5.0}
    st = adamw_init(w)
    for i in range(200):
        g = jax.tree_util.tree_map(lambda x: 2 * x, w)
        w, st, _ = adamw_update(w, g, st, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(w["w"]).max()) < 0.5


def test_schedule_warmup_and_decay():
    lr0 = cosine_warmup(jnp.asarray(0), 1e-3, 100, 1000)
    lrw = cosine_warmup(jnp.asarray(100), 1e-3, 100, 1000)
    lrend = cosine_warmup(jnp.asarray(1000), 1e-3, 100, 1000)
    assert float(lr0) == 0.0
    assert float(lrw) == pytest.approx(1e-3, rel=1e-3)
    assert float(lrend) < 2e-4


def test_data_pipeline_deterministic_and_regenerable():
    pipe = SyntheticTokens(vocab=100, seq_len=16, global_batch=4, seed=7)
    b5 = pipe.batch_at(5)
    b5b = pipe.batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b5["labels"][:, :-1], b5["tokens"][:, 1:])
    it = iter(pipe)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], pipe.batch_at(0)["tokens"])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 4))}}
    for step in (10, 20, 30):
        mgr.save(step, tree, blocking=True)
    assert mgr.list_steps() == [20, 30]
    out = mgr.restore(30, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_ignores_uncommitted(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"x": np.ones(3)}, blocking=True)
    # fake a torn checkpoint
    os.makedirs(tmp_path / "step_000000009")
    assert mgr.latest_step() == 5


def test_train_loop_resume_and_straggler_accounting(tmp_path):
    """Crash at step 7 -> loop restarts from checkpoint and completes."""
    r = reduced_config(get_config("granite-3-2b"))
    api = get_model(r)
    params = api.init(jax.random.key(0))
    opt = adamw_init(params)
    pipe = SyntheticTokens(vocab=r.vocab, seq_len=16, global_batch=2)

    crashed = {"done": False}

    @jax.jit
    def raw_step(params, opt_state, batch, step):
        loss, grads = jax.value_and_grad(
            lambda p: api.loss(p, batch))(params)
        params, opt_state, mx = adamw_update(params, grads, opt_state, 1e-3)
        return params, opt_state, loss, mx

    def step_fn(params, opt_state, batch, step):
        if step == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected device failure")
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        return raw_step(params, opt_state, b, jnp.asarray(step))

    cfg = TrainLoopConfig(total_steps=10, ckpt_every=5,
                          ckpt_dir=str(tmp_path), log_every=100,
                          resume=True)
    out = run_train_loop(step_fn, params, opt, pipe, cfg,
                         log=lambda *a: None)
    assert out["final_step"] == 10
    assert out["restarts"] == 1
    assert len(out["losses"]) >= 10
    assert np.isfinite(out["losses"][-1])


def test_serve_engine_continuous_batching():
    r = reduced_config(get_config("granite-3-2b"))
    api = get_model(r)
    params = api.init(jax.random.key(0))
    eng = ServeEngine(api, params, batch_slots=2, max_seq=32)
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=4)
            for i in range(5)]
    for q in reqs:
        eng.submit(q)
    eng.run(max_steps=200)
    for q in reqs:
        assert q.done and len(q.out) == 4
        assert all(0 <= t < r.vocab_padded for t in q.out)


def test_int8_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)), jnp.float32)
    q, s = quantize_int8(g)
    back = dequantize_int8(q, s, g.shape)
    assert float(jnp.max(jnp.abs(back - g))) < 0.05
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    target = jnp.zeros_like(g)
    for _ in range(50):
        q, s, err = ef_compress(g, err)
        acc = acc + dequantize_int8(q, s, g.shape)
        target = target + g
    # error feedback keeps the long-run average unbiased
    assert float(jnp.mean(jnp.abs(acc - target))) / 50 < 5e-3
