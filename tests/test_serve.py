"""Serving-layer tests: structure-keyed coalescing, batch windows,
failure isolation, timeouts, admission control, the program disk cache,
and the end-to-end mini-acceptance run.

Fast tests run at Grid(1, 1) in the main (single-device) pytest
process; the failure-isolation test runs on a real 2×1 mesh in a
subprocess (f64, so "neighbors solve bit-identically" is meaningful);
the full 4×2 traffic acceptance is ``slow``-marked (8 devices) and
covered nightly + by ``benchmarks/pselinv_bench.py``.
"""
import time

import numpy as np
import pytest
import scipy.sparse as sp

from conftest import run_sub

import jax.numpy as jnp

from repro.core import sparse
from repro.core.engine import (Grid, PlanOptions, PSelInvEngine,
                               bucket_size)
from repro.serve import (BatchWindow, ProgramDiskCache, RequestStatus,
                         SelInvServer, ServeConfig, ServeMetrics,
                         ServerOverloaded, SolveRequest,
                         StructureBatcher)


def _req(skey="s", submitted=None, deadline=None):
    r = SolveRequest(skey=skey, matrix=object(), deadline=deadline)
    if submitted is not None:
        r.submitted = submitted
    return r


# ---------------------------------------------------------------------
# units: bucket_size, metrics, batcher flush policy
# ---------------------------------------------------------------------

def test_bucket_size_pow2():
    assert [bucket_size(B) for B in (1, 2, 3, 4, 5, 8, 9, 13, 16, 17)] \
        == [1, 2, 4, 4, 8, 8, 16, 16, 16, 32]
    with pytest.raises(ValueError, match="batch size"):
        bucket_size(0)


def test_metrics_snapshot_shape():
    m = ServeMetrics()
    snap = m.snapshot()
    assert snap["submitted"] == snap["solved"] == 0
    assert snap["latency_p50_us"] is None
    assert snap["batch_occupancy_mean"] is None
    m.inc("submitted", 3)
    m.observe_latency(1e-3)
    m.observe_latency(3e-3)
    m.observe_batch(13, 16)
    m.set_queue_depth(7)
    m.set_queue_depth(2)
    snap = m.snapshot()
    assert snap["submitted"] == 3 and snap["batches"] == 1
    assert 1e3 <= snap["latency_p50_us"] <= 3e3
    assert snap["batch_occupancy_mean"] == pytest.approx(13 / 16)
    assert snap["batch_size_hist"] == {13: 1}
    assert snap["batch_bucket_hist"] == {16: 1}
    assert snap["queue_depth"] == 2 and snap["queue_depth_max"] == 7


def test_batcher_max_batch_flushes_immediately():
    b = StructureBatcher(BatchWindow(max_batch=4, max_wait_ms=1e6))
    now = time.monotonic()
    for _ in range(9):
        b.add(_req("s", submitted=now))
    batches, expired = b.pop_ready(now)
    # two full chunks flush now; the remainder waits out its window
    assert [len(x) for x in batches] == [4, 4] and not expired
    assert b.pending() == 1


def test_batcher_max_wait_flushes_partial():
    b = StructureBatcher(BatchWindow(max_batch=16, max_wait_ms=5.0))
    now = time.monotonic()
    b.add(_req("s", submitted=now))
    b.add(_req("t", submitted=now - 0.010))     # window already expired
    batches, _ = b.pop_ready(now)
    assert [len(x) for x in batches] == [1]
    assert batches[0][0].skey == "t"
    assert b.next_due(now) == pytest.approx(now + 0.005, abs=1e-6)
    batches, _ = b.pop_ready(now + 0.006)
    assert [len(x) for x in batches] == [1]
    assert b.pending() == 0


def test_batcher_pressure_flushes_fullest_queue():
    b = StructureBatcher(BatchWindow(max_batch=16, max_wait_ms=1e6,
                                     pressure=8))
    now = time.monotonic()
    for _ in range(7):
        b.add(_req("big", submitted=now))
    for _ in range(3):
        b.add(_req("small", submitted=now))
    batches, _ = b.pop_ready(now)
    # total backlog 10 > 8: the fullest queue flushes first, and that
    # alone brings the backlog under the bound
    assert [len(x) for x in batches] == [7]
    assert batches[0][0].skey == "big"
    assert b.pending() == 3


def test_batcher_expires_overdue_requests():
    b = StructureBatcher(BatchWindow(max_batch=4, max_wait_ms=1e6))
    now = time.monotonic()
    b.add(_req("s", submitted=now, deadline=now - 1.0))
    b.add(_req("s", submitted=now, deadline=now + 60.0))
    batches, expired = b.pop_ready(now, force=True)
    assert len(expired) == 1 and expired[0].deadline < now
    assert [len(x) for x in batches] == [1]


def test_request_future_semantics():
    r = _req()
    assert not r.done()
    with pytest.raises(TimeoutError, match="still queued"):
        r.result(timeout=0.01)
    r._finish(RequestStatus.SOLVED, result=42)
    assert r.done() and r.result() == 42
    r._finish(RequestStatus.FAILED, error=RuntimeError("late"))
    assert r.status is RequestStatus.SOLVED      # first completion wins


# ---------------------------------------------------------------------
# server end-to-end at Grid(1, 1), main process
# ---------------------------------------------------------------------

#: in-process tests run f32 (the main pytest process has no x64; the
#: f64 ≤1e-12 identity is asserted by the subprocess tests below and by
#: the bench harness) — batched-vs-unbatched f32 agreement bound
_F32_TOL = 1e-5


@pytest.fixture
def g11_server():
    PSelInvEngine.clear_cache()
    srv = SelInvServer(ServeConfig(
        b=8, grid=Grid(1, 1), dtype=jnp.float32,
        window=BatchWindow(max_batch=4, max_wait_ms=1.0)))
    yield srv
    srv.stop()


def test_server_coalesces_and_matches_unbatched(g11_server):
    """Same-structure requests coalesce into one batch whose per-request
    results match the engine's own unbatched solves (f64); a second
    structure lands in its own batch."""
    srv = g11_server
    A = sparse.laplacian_2d(12, 8)
    B = sparse.laplacian_2d(16, 8)
    I_A = sp.identity(A.shape[0])
    reqs = [srv.submit(A + c * I_A) for c in (0.0, 0.5, 1.0)]
    reqs.append(srv.submit(B))
    assert srv.pump(force=True) == 2             # one batch per structure
    eng = srv.engine_for(A)
    for c, r in zip((0.0, 0.5, 1.0), reqs[:3]):
        assert r.status is RequestStatus.SOLVED
        ref = np.asarray(eng.solve(A + c * I_A, dtype=jnp.float32))
        assert abs(np.asarray(r.result()) - ref).max() <= _F32_TOL
    assert reqs[3].status is RequestStatus.SOLVED
    st = srv.stats()
    assert st["solved"] == 4 and st["batches"] == 2
    assert len(st["structures"]) == 2
    assert st["batch_size_hist"] == {1: 1, 3: 1}


def test_server_bucket_padding_shares_programs(g11_server):
    """A batch of 3 rides the B=4 program: the engine traces once for
    the bucket, and a later exact-4 batch adds no trace."""
    srv = g11_server
    A = sparse.laplacian_2d(12, 8)
    I_A = sp.identity(A.shape[0])
    for c in (0.1, 0.2, 0.3):
        srv.submit(A + c * I_A)
    srv.pump(force=True)
    eng = srv.engine_for(A)
    assert eng.trace_count == 1
    st = srv.stats()
    skey = next(iter(st["structures"]))
    assert st["structures"][skey]["buckets_used"] == [4]
    assert st["batch_bucket_hist"] == {4: 1}
    for c in (0.4, 0.5, 0.6, 0.7):               # exact bucket, no pad
        srv.submit(A + c * I_A)
    srv.pump(force=True)
    assert eng.trace_count == 1                  # same compiled program


def test_server_admission_rejects_beyond_max_queue():
    PSelInvEngine.clear_cache()
    srv = SelInvServer(ServeConfig(
        b=8, grid=Grid(1, 1), max_queue=2,
        window=BatchWindow(max_batch=16, max_wait_ms=1e6)))
    A = sparse.laplacian_2d(12, 8)
    ok = [srv.submit(A) for _ in range(2)]
    rej = srv.submit(A)
    assert rej.status is RequestStatus.REJECTED
    with pytest.raises(ServerOverloaded, match="queue at capacity"):
        rej.result()
    assert srv.stats()["rejected"] == 1
    srv.pump(force=True)                         # admitted ones solve
    assert all(r.status is RequestStatus.SOLVED for r in ok)


def test_server_timeout_while_queued():
    PSelInvEngine.clear_cache()
    srv = SelInvServer(ServeConfig(
        b=8, grid=Grid(1, 1),
        window=BatchWindow(max_batch=16, max_wait_ms=1e6)))
    A = sparse.laplacian_2d(12, 8)
    r = srv.submit(A, timeout_ms=1.0)
    time.sleep(0.01)
    srv.pump()                   # no force: the deadline, not the
    assert r.status is RequestStatus.TIMED_OUT   # window, fired
    with pytest.raises(TimeoutError, match="missed its deadline"):
        r.result()
    assert srv.stats()["timed_out"] == 1


def test_server_background_worker_thread():
    """The background worker drives windows by itself: submits complete
    without any pump() from the caller."""
    PSelInvEngine.clear_cache()
    cfg = ServeConfig(b=8, grid=Grid(1, 1), dtype=jnp.float32,
                      window=BatchWindow(max_batch=4, max_wait_ms=1.0))
    A = sparse.laplacian_2d(12, 8)
    I_A = sp.identity(A.shape[0])
    with SelInvServer(cfg) as srv:
        reqs = [srv.submit(A + c * I_A) for c in (0.0, 0.5, 1.0, 2.0)]
        outs = [np.asarray(r.result(timeout=60)) for r in reqs]
        assert all(r.status is RequestStatus.SOLVED for r in reqs)
        eng = srv.engine_for(A)
        for c, o in zip((0.0, 0.5, 1.0, 2.0), outs):
            ref = np.asarray(eng.solve(A + c * I_A, dtype=jnp.float32))
            assert abs(o - ref).max() <= _F32_TOL
        assert srv.stats()["batches"] >= 1


def test_progcache_roundtrip(tmp_path):
    """The on-disk AOT cache: a miss compiles + persists, a fresh cache
    instance loads the serialized executable from disk, and both
    executables produce the engine's own batched result bit-for-bit —
    without touching trace_count."""
    PSelInvEngine.clear_cache()
    from repro.core.engine import stack_values
    A = sparse.laplacian_2d(12, 8)
    eng = PSelInvEngine.analyze(A, b=8, grid=Grid(1, 1),
                                options=PlanOptions())
    v = eng.prepare_values(A)
    vb = stack_values([v, v])
    ref = np.asarray(eng.solve(vb, dtype=jnp.float32))
    t0 = eng.trace_count

    cache = ProgramDiskCache(str(tmp_path))
    comp = cache.get(eng, 2, jnp.float32)
    out = np.asarray(comp(jnp.asarray(vb.Lh, jnp.float32),
                          jnp.asarray(vb.Dinv, jnp.float32)))
    assert abs(out - ref).max() == 0.0
    assert cache.stats() == {"hits": 0, "misses": 1, "stores": 1,
                             "load_errors": 0, "entries": 1}
    assert cache.get(eng, 2, jnp.float32) is comp     # memory hit

    cache2 = ProgramDiskCache(str(tmp_path))          # "restart"
    comp2 = cache2.get(eng, 2, jnp.float32)
    out2 = np.asarray(comp2(jnp.asarray(vb.Lh, jnp.float32),
                            jnp.asarray(vb.Dinv, jnp.float32)))
    assert abs(out2 - ref).max() == 0.0
    assert cache2.stats()["hits"] == 1                # disk hit
    assert cache2.stats()["misses"] == 0
    assert eng.trace_count == t0                      # AOT is uncounted
    # a different bucket/dtype is its own entry
    assert cache.cache_key(eng, 2, jnp.float32) != \
        cache.cache_key(eng, 4, jnp.float32)


def test_server_through_progcache(tmp_path):
    """A server configured with the program cache serves through the
    persisted AOT executables and still matches unbatched solves."""
    PSelInvEngine.clear_cache()
    srv = SelInvServer(ServeConfig(
        b=8, grid=Grid(1, 1), dtype=jnp.float32,
        window=BatchWindow(max_batch=4, max_wait_ms=1.0),
        prog_cache=ProgramDiskCache(str(tmp_path))))
    A = sparse.laplacian_2d(12, 8)
    I_A = sp.identity(A.shape[0])
    reqs = [srv.submit(A + c * I_A) for c in (0.0, 1.0, 2.0)]
    srv.pump(force=True)
    eng = srv.engine_for(A)
    for c, r in zip((0.0, 1.0, 2.0), reqs):
        assert r.status is RequestStatus.SOLVED
        ref = np.asarray(eng.solve(A + c * I_A, dtype=jnp.float32))
        assert abs(np.asarray(r.result()) - ref).max() <= _F32_TOL
    st = srv.stats()
    assert st["prog_cache"]["misses"] == 1
    assert st["prog_cache"]["stores"] == 1


# ---------------------------------------------------------------------
# failure isolation on a real 2x1 mesh (subprocess, f64)
# ---------------------------------------------------------------------

def test_failure_isolation_bad_request_fails_alone():
    """A request whose sparsity pattern escapes its claimed structure
    (submitted as pre-checked values would dodge admission — here it
    sneaks in by pattern-fingerprint collision simulation: same
    fingerprint path, corrupted matrix swapped onto the request) fails
    ALONE: its batch neighbors solve bit-identically to their unbatched
    solves and the server keeps serving the next window."""
    run_sub("""
        import numpy as np
        import scipy.sparse as sp
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.engine import Grid, PSelInvEngine
        from repro.serve import (BatchWindow, RequestStatus,
                                 SelInvServer, ServeConfig, ServeError)

        PSelInvEngine.clear_cache()
        srv = SelInvServer(ServeConfig(
            b=8, grid=Grid(2, 1), dtype=jnp.float64,
            window=BatchWindow(max_batch=4, max_wait_ms=1.0)))
        A = sparse.laplacian_2d(12, 8)
        I = sp.identity(A.shape[0])
        good = [srv.submit(A + c * I) for c in (0.5, 1.5)]
        bad = srv.submit(A + 1.0 * I)
        # corrupt the queued request's payload *after* admission: an
        # out-of-structure block the engine's tables cannot represent
        E = sp.lil_matrix(A)
        E[0, 95] = E[95, 0] = 1.0
        bad.matrix = sp.csr_matrix(E)

        srv.pump(force=True)
        assert bad.status is RequestStatus.FAILED, bad.status
        try:
            bad.result()
            raise AssertionError("bad request returned a result")
        except ServeError as e:
            assert "outside the analyzed block" in str(e), e
        # neighbors solved, bit-identical to their unbatched solves
        eng = srv.engine_for(A)
        for c, r in zip((0.5, 1.5), good):
            assert r.status is RequestStatus.SOLVED, r.status
            ref = np.asarray(eng.solve(A + c * I, dtype=jnp.float64))
            assert abs(np.asarray(r.result()) - ref).max() <= 1e-12
        # ...and the server survives for the next window
        nxt = srv.submit(A + 3.0 * I)
        srv.pump(force=True)
        assert nxt.status is RequestStatus.SOLVED, nxt.status
        st = srv.stats()
        assert st["failed"] == 1 and st["solved"] == 3, st
        print("OK")
    """, ndev=2, x64=True)


# ---------------------------------------------------------------------
# the full acceptance harness on the 4x2 mesh (slow, 8 devices)
# ---------------------------------------------------------------------

@pytest.mark.slow
def test_serve_traffic_acceptance_4x2():
    """The full harness on the 4×2 mesh with Poisson arrivals:
    compile conformance and ≤1e-12 identity are asserted strictly
    inside run_traffic; the throughput bar here is a sanity floor
    (coalescing must win) rather than the bench's ≥5× — with 8
    simulated devices plus Poisson sleeps sharing the host, the 4×2
    ratio swings run to run.  The asserted ≥5× lives in
    ``benchmarks/pselinv_bench.py --serve-bench`` on Grid(1, 1)."""
    out = run_sub("""
        import jax.numpy as jnp
        from repro.core.engine import Grid
        from repro.serve.batcher import BatchWindow
        from repro.serve.traffic import run_traffic

        res = run_traffic(n_requests=100, n_structures=2,
                          rate_hz=4000.0, seed=0, b=8, grid=Grid(4, 2),
                          window=BatchWindow(), dtype=jnp.float64,
                          check_identity=True, tol=1e-12, reps=3)
        assert res["speedup"] >= 1.5, res["speedup"]
        print(f"OK speedup={res['speedup']:.2f} "
              f"occ={res['serve_batch_occupancy']:.2f}")
    """, ndev=8, x64=True)
    assert "OK" in out
