"""PlanLint tests (``core/verify.py``): the verifier is itself verified.

(a) clean corpus — every shipped plan shape (nb=16/32, grids 4×2 and
    8×4, level-serial / overlapped / stream lowerings, both
    ``axis_factored`` settings, windowed and unwindowed Û pools) lints
    with **zero ERROR diagnostics**, entirely host-side;
(b) mutation self-test — each corruption class the checker pipeline
    exists for (stale generation, dropped anti-dependence, flipped slot
    gate, duplicate ppermute destination, byte-count drift) is injected
    into a deep-copied lowered artifact and must be caught with its
    distinct diagnostic code;
(c) wiring — ``PlanOptions(verify=...)`` validates its mode,
    ``build_program`` runs the pass at build time (default "error"),
    ``engine.analyze(..., verify=...)`` overrides per call, and
    ``enforce_verification`` maps modes to raise / warn / no-op;
(d) tooling — ``tools/plan_lint.py`` exits clean on the default corpus
    and ``tools/record_bench.py`` rejects malformed bench rows.
"""
import copy
import importlib.util
import os
import warnings

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import sparse
from repro.core import verify as V
from repro.core.plan import (PlanOptions, build_plan, compile_exec,
                             schedule_overlapped)
from repro.core.schedule import Grid2D
from repro.core.stream import lower_stream
from repro.core.symbolic import symbolic_factorize
from repro.core.trees import TreeKind

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _structure(nx):
    return symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(nx, 8)), max_supernode=8)


@pytest.fixture(scope="module")
def ov_plan():
    """The mutation target: nb=32 at 4×2 with window=1 — the tightest Û
    pool, so slot recycling (the race detector's whole subject matter)
    actually occurs."""
    plan = build_plan(_structure(32), Grid2D(4, 2), TreeKind.SHIFTED,
                      nb=32)
    ov = schedule_overlapped(plan, window=1)
    return plan, ov


@pytest.fixture(scope="module")
def stream_tables(ov_plan):
    _plan, ov = ov_plan
    return lower_stream(ov)


def _errors(diags):
    return [d for d in diags if d.severity == "error"]


def _codes(diags):
    return {d.code for d in _errors(diags)}


# ---------------------------------------------------------------------------
# (a) every shipped plan shape lints clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nx,nb,pr,pc", [
    (16, 16, 4, 2),
    (32, 32, 4, 2),
    (32, 32, 8, 4),
])
def test_shipped_plans_lint_clean(nx, nb, pr, pc):
    """The acceptance contract: nb=16/32 at grids 4×2 and 8×4, every
    lowering, both axis_factored settings, zero ERROR diagnostics."""
    plan = build_plan(_structure(nx), Grid2D(pr, pc), TreeKind.SHIFTED,
                      nb=nb)
    assert _errors(V.check_plan(plan)) == []
    assert _errors(V.check_exec(compile_exec(plan))) == []
    for window in (None, 1):
        ov = schedule_overlapped(plan, window=window)
        assert _errors(V.check_overlap(ov, plan)) == [], \
            f"overlap window={window}"
        for af in (True, False):
            st = lower_stream(ov, axis_factored=af)
            assert _errors(V.check_stream(st, plan)) == [], \
                f"stream window={window} axis_factored={af}"


# ---------------------------------------------------------------------------
# (b) mutation self-test: each corruption class fires its distinct code
# ---------------------------------------------------------------------------

def _u_writes(ov):
    """(device, slot) -> {generation: [fill rounds]} over the Û region,
    reconstructed exactly as the verifier sees it."""
    u_lo, base_p = ov.n_ainv, ov.levels[0].base_p
    writes = {}
    for t, rnd in enumerate(ov.rounds):
        lane_j = {}
        for (s, d, kind, lv, _nb) in rnd.edges:
            j = lane_j.get((s, d), 0)
            lane_j[(s, d)] = j + 1
            ds = int(rnd.scatter[d, j])
            if kind in ("xfer", "col-bcast") and u_lo <= ds < base_p:
                writes.setdefault((d, ds), {}).setdefault(lv, []).append(t)
    return writes


def test_mutation_stale_generation(ov_plan):
    """Retarget a col-bcast forward's gather lane at a Û slot whose
    latest visible write is a *different* generation — the exact stale
    tenant bug class of PRs 2/3 — and the race detector must name it."""
    plan, ov = ov_plan
    m = copy.deepcopy(ov)
    writes = _u_writes(m)
    mutated = False
    for t, rnd in enumerate(m.rounds):
        lane_j = {}
        for (s, d, kind, lv, _nb) in rnd.edges:
            j = lane_j.get((s, d), 0)
            lane_j[(s, d)] = j + 1
            if kind != "col-bcast" or bool(rnd.glh[s, j]):
                continue
            for (dev, slot), gens in writes.items():
                if dev != s:
                    continue
                prior = [(r, l) for l, rs in gens.items()
                         for r in rs if r < t]
                if not prior:
                    continue
                rmax = max(r for r, _l in prior)
                if lv not in {l for r, l in prior if r == rmax}:
                    rnd.gather[s, j] = slot
                    mutated = True
                    break
            if mutated:
                break
        if mutated:
            break
    assert mutated, "no retargetable col-bcast lane found"
    assert "race/stale-read" in _codes(V.check_overlap(m, plan))


def test_mutation_dropped_anti_dep(ov_plan):
    """Move a recycled slot's earlier tenant's last reader (its scomp
    boundary) past the later tenant's first fill — the WAR anti-dep the
    scheduler is obligated to enforce — and the race detector must flag
    the overlap."""
    plan, ov = ov_plan
    m = copy.deepcopy(ov)
    writes = _u_writes(m)
    recycled = sorted((k, v) for k, v in writes.items() if len(v) > 1)
    assert recycled, "window=1 plan must recycle Û slots"
    (_devslot, gens) = recycled[0]
    order = sorted(gens)
    la, lb = order[0], order[1]
    first_fill = min(gens[lb])
    moved = False
    for t, ops in enumerate(m.compute_at):
        hit = [op for op in ops if op.kind == "scomp" and op.level == la]
        if hit:
            m.compute_at[t] = [op for op in ops if op not in hit]
            dest = min(first_fill + 1, len(m.compute_at) - 1)
            m.compute_at[dest] = m.compute_at[dest] + hit
            moved = True
            break
    assert moved
    assert "race/war-overlap" in _codes(V.check_overlap(m, plan))


def test_mutation_flipped_gate_bit(stream_tables, ov_plan):
    """Flip one slot_active gate bit off: the receive table still routes
    a device onto the slot, so the gate/receive consistency check (the
    same one executed_wire_bytes prices through) must fire."""
    plan, _ov = ov_plan
    m = copy.deepcopy(stream_tables)
    idx = np.argwhere(m.slot_active)
    t, si = map(int, idx[len(idx) // 2])
    m.slot_active[t, si] = False
    assert "gate/active-mismatch" in _codes(V.check_stream(m, plan))
    assert "gate/active-mismatch" in _codes(V.check_stream_gates(m))


def test_mutation_duplicate_ppermute_dst(stream_tables, ov_plan):
    """Double-book one destination inside a comm slot's pair list — no
    longer a permutation, a payload would be dropped on device."""
    plan, _ov = ov_plan
    m = copy.deepcopy(stream_tables)
    si = max(range(m.nslots), key=lambda i: len(m.slot_perm[i]))
    perm = list(m.slot_perm[si])
    assert len(perm) >= 2
    (s0, d0), (s1, _d1) = perm[0], perm[1]
    perm[1] = (s1, d0)
    slot_perm = list(m.slot_perm)
    slot_perm[si] = tuple(perm)
    m.slot_perm = tuple(slot_perm)
    assert "perm/dup-endpoint" in _codes(V.check_stream(m, plan))


def test_mutation_byte_count_drift(ov_plan):
    """Inflate one edge's byte record: the executor tables no longer
    conserve the plan's tree volumes and the unified conservation pass
    must localize the drifting kind/rank."""
    plan, ov = ov_plan
    m = copy.deepcopy(ov)
    mutated = False
    for rnd in m.rounds:
        if rnd.edges:
            s, d, kind, lv, nb_ = rnd.edges[0]
            rnd.edges[0] = (s, d, kind, lv, nb_ * 2 + 64.0)
            mutated = True
            break
    assert mutated
    diags = V.check_overlap(m, plan)
    assert "conserve/bytes-drift" in _codes(diags)
    # and without the plan there is nothing to conserve against
    assert "conserve/bytes-drift" not in _codes(V.check_overlap(m, None))


def test_mutation_in_round_waw(ov_plan):
    """Point two lanes of one round at the same (device, slot): the
    one-writer-per-round invariant the scheduler enforces at build time
    must also be caught statically."""
    plan, ov = ov_plan
    m = copy.deepcopy(ov)
    mutated = False
    for rnd in m.rounds:
        for d in range(m.pr * m.pc):
            real = [j for j in range(rnd.width)
                    if int(rnd.scatter[d, j]) != m.trash]
            if len(real) >= 2:
                rnd.scatter[d, real[1]] = rnd.scatter[d, real[0]]
                mutated = True
                break
        if mutated:
            break
    assert mutated
    assert "race/waw-round" in _codes(V.check_overlap(m, plan))


def test_mutation_codes_are_distinct():
    """The acceptance criterion's five corruption classes map to five
    distinct diagnostic codes."""
    assert len({"race/stale-read", "race/war-overlap",
                "gate/active-mismatch", "perm/dup-endpoint",
                "conserve/bytes-drift"}) == 5


# ---------------------------------------------------------------------------
# (c) wiring: PlanOptions / build_program / engine / enforce
# ---------------------------------------------------------------------------

def test_plan_options_verify_validation():
    for mode in ("error", "warn", "off"):
        assert PlanOptions(verify=mode).verify == mode
    with pytest.raises(ValueError, match="verify"):
        PlanOptions(verify="loud")


def test_enforce_verification_modes():
    diag = V.PlanDiagnostic(code="race/stale-read", severity="error",
                            message="synthetic")
    with pytest.raises(V.PlanVerificationError) as ei:
        V.enforce_verification([diag], mode="error", where="test")
    assert ei.value.diagnostics == [diag]
    assert "race/stale-read" in str(ei.value)
    with pytest.warns(UserWarning, match="PlanLint"):
        V.enforce_verification([diag], mode="warn", where="test")
    assert V.enforce_verification([diag], mode="off") == [diag]
    with pytest.raises(ValueError, match="verify mode"):
        V.enforce_verification([diag], mode="loud")
    # warn-severity diagnostics never raise, even in error mode
    w = V.PlanDiagnostic(code="load/fanin", severity="warn", message="s")
    with pytest.warns(UserWarning):
        V.enforce_verification([w], mode="error", where="test")


def test_build_program_runs_planlint():
    """The tier-1 verify path: build_program lints the default nb=16
    plan at build time in every mode without complaint (the shipped
    plans are clean), and the verify knob round-trips PlanOptions."""
    from repro.core.pselinv_dist import build_program
    bs = _structure(16)
    for mode in ("error", "warn", "off"):
        prog = build_program(
            bs, 16, 8, 4, 2,
            options=PlanOptions(stream=True, verify=mode))
        assert prog.stream_tables is not None
    # and verify_program over the compiled program is clean end to end
    prog = build_program(bs, 16, 8, 4, 2,
                         options=PlanOptions(stream=True))
    assert _errors(V.verify_program(prog)) == []


def test_verify_artifact_dispatch(ov_plan, stream_tables):
    plan, ov = ov_plan
    assert _errors(V.verify_artifact(plan)) == []
    assert _errors(V.verify_artifact(ov, plan)) == []
    assert _errors(V.verify_artifact(stream_tables, plan)) == []
    assert _errors(V.verify_artifact(compile_exec(plan))) == []
    with pytest.raises(TypeError, match="verify_artifact"):
        V.verify_artifact(object())


def test_lint_report_format():
    diags = [V.PlanDiagnostic(code="load/fanin", severity="warn",
                              message="skew", device=3, round=7),
             V.PlanDiagnostic(code="race/stale-read", severity="error",
                              message="stale", slot=12, hint="rekey")]
    rep = V.lint_report(diags)
    assert rep.splitlines()[0] == "PlanLint: 1 error(s), 1 warning(s)"
    # errors sort first; locations and hints are embedded
    assert rep.splitlines()[1].startswith("  [ERROR] race/stale-read")
    assert "slot=12" in rep and "rekey" in rep
    assert "dev=3,round=7" in rep


def test_executed_wire_bytes_routes_through_gate_check(stream_tables):
    """The simulator's stream wire pricing now shares the PlanLint gate
    check: a drifted gate table still raises ValueError."""
    import types
    from repro.core.simulator import executed_wire_bytes
    from repro.core.stream import stream_wire_bytes
    prog = types.SimpleNamespace(b=8, stream_tables=stream_tables,
                                 overlap_plan=None)
    assert executed_wire_bytes(prog) == stream_wire_bytes(stream_tables, 8)
    m = copy.deepcopy(stream_tables)
    idx = np.argwhere(m.slot_active)
    t, si = map(int, idx[0])
    m.slot_active[t, si] = False
    bad = types.SimpleNamespace(b=8, stream_tables=m, overlap_plan=None)
    with pytest.raises(ValueError, match="gate"):
        executed_wire_bytes(bad)


# ---------------------------------------------------------------------------
# (d) tooling: the CLI linter and the bench recorder's schema check
# ---------------------------------------------------------------------------

def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(ROOT, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_plan_lint_cli_clean():
    tool = _load_tool("plan_lint")
    assert tool.main(["--grid", "4x2", "--nb", "16"]) == 0


def test_record_bench_row_schema():
    tool = _load_tool("record_bench")
    ok = [{"name": "selinv/x", "us_per_call": 1.0, "derived": {}}]
    tool.validate_rows(ok, where="test")          # clean rows pass
    with pytest.raises(SystemExit, match="name"):
        tool.validate_rows([{"us_per_call": 1.0}], where="test")
    with pytest.raises(SystemExit, match="us_per_call"):
        tool.validate_rows([{"name": "selinv/x", "us_per_call": "fast"}],
                           where="test")
    tool.validate_history([{"rev": "a", "benches": ok, "failed": []},
                           {"rev": "b", "benches": ok, "failed": []}])
    with pytest.raises(SystemExit, match="duplicate"):
        tool.validate_history([{"rev": "a", "benches": ok, "failed": []},
                               {"rev": "a", "benches": ok, "failed": []}])
