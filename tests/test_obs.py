"""SweepScope observability tier: span tracer semantics, the unified
metrics registry, the Chrome-trace exporter schema, serve-metrics
backward compatibility, engine stat gauges, and the profile_rounds
measured-timeline conformance contract (subprocess, 8 devices)."""
import json

import numpy as np
import pytest

from conftest import run_sub

from repro.obs.registry import REGISTRY, MetricsRegistry
from repro.obs.trace import Tracer


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_span_nesting_and_attr_roundtrip():
    t = Tracer(enabled=True)
    with t.span("outer", a=1) as sp:
        sp.set(b="two")
        with t.span("inner", c=3.0):
            pass
    spans = t.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    inner, outer = spans
    assert outer.parent_id is None
    assert inner.parent_id == outer.span_id
    assert outer.attrs == {"a": 1, "b": "two"}
    assert inner.attrs == {"c": 3.0}
    # timing sanity: inner nests inside outer on the same clock
    assert outer.t0_us <= inner.t0_us
    assert inner.t1_us <= outer.t1_us + 1.0
    assert outer.dur_us >= 0 and inner.dur_us >= 0


def test_span_records_exception_and_reraises():
    t = Tracer(enabled=True)
    with pytest.raises(ValueError):
        with t.span("boom"):
            raise ValueError("x")
    (s,) = t.spans()
    assert s.attrs["error"] == "ValueError"


def test_disabled_tracer_null_fast_path():
    t = Tracer(enabled=False)
    # the disabled path hands back one shared singleton — no per-call
    # allocation, nothing buffered, attrs silently dropped
    s1 = t.span("a", x=1)
    s2 = t.span("b")
    assert s1 is s2
    with s1 as sp:
        assert sp.set(y=2) is sp
    t.instant("marker")
    assert t.spans() == [] and len(t) == 0 and t.dropped == 0
    # flipping the switch restores real spans on the same tracer
    t.enable()
    with t.span("real"):
        pass
    assert [s.name for s in t.spans()] == ["real"]


def test_ring_buffer_bounded_with_drop_counter():
    t = Tracer(capacity=4, enabled=True)
    for i in range(6):
        with t.span(f"s{i}"):
            pass
    spans = t.spans()
    assert len(spans) == 4
    assert t.dropped == 2
    assert [s.name for s in spans] == ["s2", "s3", "s4", "s5"]  # oldest out
    t.clear()
    assert t.spans() == [] and t.dropped == 0


def test_tracer_thread_local_nesting():
    import threading
    t = Tracer(enabled=True)
    seen = {}

    def worker():
        with t.span("child-thread"):
            pass
        seen["done"] = True

    with t.span("main"):
        th = threading.Thread(target=worker)
        th.start()
        th.join()
    spans = {s.name: s for s in t.spans()}
    assert seen["done"]
    # the worker's span must NOT parent under main's open span
    assert spans["child-thread"].parent_id is None
    assert spans["child-thread"].tid != spans["main"].tid


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_basics():
    r = MetricsRegistry()
    c = r.counter("c_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = r.gauge("g")
    g.set(5)
    g.inc(2)
    g.max(3)          # below current → no-op
    assert g.value == 7.0
    g.max(11)
    assert g.value == 11.0
    # idempotent registration returns the same object...
    assert r.counter("c_total") is c
    # ...and a kind/label mismatch is an error, not a silent replace
    with pytest.raises(ValueError):
        r.gauge("c_total")
    with pytest.raises(ValueError):
        r.counter("c_total", labelnames=("x",))


def test_registry_labeled_children():
    r = MetricsRegistry()
    c = r.counter("events_total", labelnames=("name",))
    c.labels("solved").inc()
    c.labels("solved").inc()
    c.labels(name="failed").inc()
    assert {k: v.value for k, v in dict(c.children()).items()} == {
        ("solved",): 2.0, ("failed",): 1.0}
    with pytest.raises(ValueError):   # plain inc on a labeled metric
        c.inc()
    with pytest.raises(ValueError):   # wrong label arity
        c.labels("a", "b")


def test_histogram_percentiles_match_numpy():
    r = MetricsRegistry()
    h = r.histogram("lat_seconds")
    assert h.percentile(50) is None and h.mean is None
    rng = np.random.default_rng(0)
    xs = rng.lognormal(size=500)
    for x in xs:
        h.observe(x)
    assert h.count == 500
    assert h.sum == pytest.approx(xs.sum())
    assert h.mean == pytest.approx(xs.mean())
    assert float(h.percentile(95)) == pytest.approx(
        float(np.percentile(xs, 95)))
    p50, p99 = h.percentile((50, 99))
    assert float(p50) == pytest.approx(float(np.percentile(xs, 50)))
    s = h.summary()
    assert s["count"] == 500 and s["p99"] == pytest.approx(float(p99))


def test_histogram_reservoir_bounded_but_count_exact():
    r = MetricsRegistry()
    h = r.histogram("h", max_samples=10)
    for i in range(25):
        h.observe(float(i))
    assert h.count == 25
    assert h.sum == float(sum(range(25)))
    assert len(h.samples()) == 10        # keep-the-head policy


def test_registry_snapshot_and_prometheus_text():
    r = MetricsRegistry()
    r.counter("reqs_total", "requests", labelnames=("name",)) \
        .labels("ok").inc(3)
    r.gauge("depth", "queue depth").set(7)
    h = r.histogram("lat", "latency")
    h.observe(1.0)
    h.observe(3.0)
    snap = r.snapshot()
    assert snap["reqs_total"] == {"name=ok": 3.0}
    assert snap["depth"] == 7.0
    assert snap["lat"]["count"] == 2 and snap["lat"]["mean"] == 2.0
    json.dumps(snap)                      # JSON-able end to end
    text = r.prometheus_text()
    assert "# TYPE reqs_total counter" in text
    assert 'reqs_total{name="ok"} 3' in text
    assert "# TYPE lat summary" in text
    assert "lat_count 2" in text and "lat_sum 4" in text
    assert 'lat{quantile="0.5"} 2' in text
    assert "depth 7" in text


# ---------------------------------------------------------------------------
# serve metrics — thin wrappers over the registry, frozen snapshot shape
# ---------------------------------------------------------------------------

def test_serve_metrics_snapshot_backward_compatible():
    from repro.serve.metrics import COUNTERS, ServeMetrics
    m = ServeMetrics()
    snap0 = m.snapshot()
    for name in COUNTERS:
        assert snap0[name] == 0
    assert snap0["latency_p50_us"] is None
    assert snap0["latency_mean_us"] is None
    assert snap0["batch_occupancy_mean"] is None
    assert snap0["queue_depth"] == 0 and snap0["queue_depth_max"] == 0

    m.inc("submitted", 4)
    m.inc("solved", 3)
    m.inc("failed")
    for s in (1e-3, 2e-3, 3e-3, 10e-3):
        m.observe_latency(s)
    m.observe_batch(3, 4, cause="window")
    m.observe_batch(4, 4, cause="full")
    m.set_queue_depth(5)
    m.set_queue_depth(2)
    snap = m.snapshot()
    assert snap["submitted"] == 4 and snap["solved"] == 3
    assert snap["failed"] == 1 and snap["batches"] == 2
    lat = np.array([1e-3, 2e-3, 3e-3, 10e-3]) * 1e6
    assert snap["latency_p50_us"] == pytest.approx(
        float(np.percentile(lat, 50)))
    assert snap["latency_p95_us"] == pytest.approx(
        float(np.percentile(lat, 95)))
    assert snap["latency_mean_us"] == pytest.approx(float(lat.mean()))
    assert snap["batch_occupancy_mean"] == pytest.approx((0.75 + 1.0) / 2)
    assert snap["batch_size_hist"] == {3: 1, 4: 1}
    assert snap["batch_bucket_hist"] == {4: 2}
    assert snap["flush_causes"] == {"full": 1, "window": 1}
    assert snap["queue_depth"] == 2 and snap["queue_depth_max"] == 5
    # the serving tier is scrape-able through the registry surface
    text = m.registry.prometheus_text()
    assert 'selinv_serve_events_total{name="solved"} 3' in text
    assert "selinv_serve_latency_seconds_count 4" in text


def test_serve_metrics_registries_are_isolated():
    from repro.serve.metrics import ServeMetrics
    a, b = ServeMetrics(), ServeMetrics()
    a.inc("submitted")
    assert a.snapshot()["submitted"] == 1
    assert b.snapshot()["submitted"] == 0
    assert a.registry is not b.registry


# ---------------------------------------------------------------------------
# Chrome-trace exporter — golden schema
# ---------------------------------------------------------------------------

def _fake_profile():
    from repro.obs.rounds import RoundProfile, RoundSample
    samples = [
        RoundSample(index=0, rounds=(0,), wall_us=100.0, sim_us=10.0,
                    wire_bytes=512.0, lane_bytes=256.0, msgs=2,
                    compute_ops=0, pure_comm=True),
        RoundSample(index=1, rounds=(1, 2), wall_us=200.0, sim_us=30.0,
                    wire_bytes=1024.0, lane_bytes=768.0, msgs=3,
                    compute_ops=2, pure_comm=False),
    ]
    return RoundProfile(
        nrounds=3, nranks=2, b=8, chunk=2, samples=samples,
        init_us=50.0, final_us=25.0, final_sim_us=5.0,
        inbound_bytes=np.array([256.0, 768.0]),
        inbound_msgs=np.array([2, 3]),
        inbound_time_us=np.array([120.0, 180.0]),
        rank_bytes=np.array([[256.0, 0.0], [0.0, 768.0]]))


def test_chrome_trace_schema_golden():
    from repro.obs.export import chrome_trace
    from repro.serve.batcher import RequestStatus, SolveRequest
    t = Tracer(enabled=True)
    with t.span("engine.analyze", nb=4):
        with t.span("analyze.symbolic"):
            pass
    req = SolveRequest(skey="deadbeef" * 5)
    req.batched_at = req.submitted + 1e-3
    req.completed = req.submitted + 3e-3
    req.status = RequestStatus.SOLVED

    doc = chrome_trace(spans=t.spans(), profile=_fake_profile(),
                       requests=[req])
    doc = json.loads(json.dumps(doc, default=float))  # wire round-trip
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "empty trace"
    for ev in events:
        assert ev["ph"] in ("X", "M")
        assert isinstance(ev["name"], str)
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["args"], dict)
        if ev["ph"] == "X":                    # complete events
            assert ev["ts"] >= 0.0
            assert ev["dur"] >= 0.0
            assert ev["cat"] in ("span", "round", "request")
    # all three sources present, on distinct process lanes
    pids = {ev["pid"] for ev in events if ev["ph"] == "X"}
    assert pids == {1, 2, 3}
    names = {ev["name"] for ev in events}
    assert {"engine.analyze", "analyze.symbolic", "rounds 1-2",
            "queued", "batched"} <= names
    # nested span linkage survives export
    by_name = {ev["name"]: ev for ev in events if ev["ph"] == "X"}
    assert (by_name["analyze.symbolic"]["args"]["parent_id"]
            == by_name["engine.analyze"]["args"]["span_id"])
    # per-rank round lanes carry the inbound payload of that rank only
    rank_evs = [ev for ev in events
                if ev["ph"] == "X" and ev["pid"] == 2 and ev["tid"] > 0]
    assert {ev["args"]["inbound_bytes"] for ev in rank_evs} == {256.0, 768.0}


def test_write_trace_perfetto_loadable(tmp_path):
    from repro.obs.export import write_trace
    t = Tracer(enabled=True)
    with t.span("solo"):
        pass
    path = write_trace(str(tmp_path / "t.trace.json"), spans=t.spans())
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc["traceEvents"], list)
    assert any(ev["ph"] == "X" and ev["name"] == "solo"
               for ev in doc["traceEvents"])


# ---------------------------------------------------------------------------
# engine instrumentation (single device — Grid(1, 1))
# ---------------------------------------------------------------------------

def test_engine_stats_gauges_and_compile_guard():
    import scipy.sparse as sp

    import jax

    from repro.core import sparse
    from repro.core.engine import Grid, PlanOptions, PSelInvEngine

    A = sp.csr_matrix(sparse.laplacian_2d(4, 8))
    # distinctive coalesce_max: a fresh cache key, so the session is
    # guaranteed never-solved regardless of suite ordering
    eng = PSelInvEngine.analyze(A, b=8, grid=Grid(1, 1),
                                options=PlanOptions(coalesce_max=5))
    st = eng.stats()
    assert st["last_solve_us"] is None and st["prepare_us"] is None
    assert st["solve_calls"] == 0
    # stats(compile=True) on a never-compiled session must not blow up:
    # it device-checks then compiles the f32 single-matrix class
    st = eng.stats(compile=True)
    assert st["compile_ms"] > 0
    vals = eng.prepare_values(A)
    jax.block_until_ready(eng.solve(vals))
    st = eng.stats()
    assert st["solve_calls"] == 1
    assert st["last_solve_us"] > 0 and st["prepare_us"] > 0
    # every numeric stat is published to the global scrape surface
    g = REGISTRY.get("selinv_engine_last_solve_us")
    assert g is not None and g.value == pytest.approx(st["last_solve_us"])
    assert REGISTRY.get("selinv_engine_ppermute_rounds").value \
        == st["ppermute_rounds"]


def test_engine_spans_cover_analyze_and_solve():
    import scipy.sparse as sp

    import jax

    from repro.core import sparse
    from repro.core.engine import Grid, PlanOptions, PSelInvEngine
    from repro.obs.trace import TRACER

    A = sp.csr_matrix(sparse.laplacian_2d(4, 8))
    TRACER.clear()
    TRACER.enable()
    try:
        eng = PSelInvEngine.analyze(A, b=8, grid=Grid(1, 1),
                                    options=PlanOptions(coalesce_max=7))
        vals = eng.prepare_values(A)
        jax.block_until_ready(eng.solve(vals))
    finally:
        TRACER.disable()
    names = [s.name for s in TRACER.spans()]
    for expected in ("engine.analyze", "analyze.symbolic", "plan.build",
                     "plan.schedule", "plan.verify",
                     "engine.prepare_values", "engine.solve"):
        assert expected in names, (expected, names)
    spans = {s.name: s for s in TRACER.spans()}
    # the pipeline sub-spans parent under engine.analyze
    top = spans["engine.analyze"]
    assert spans["analyze.symbolic"].parent_id == top.span_id
    assert top.attrs["cache"] == "miss"
    assert top.attrs["nb"] == eng.nb


# ---------------------------------------------------------------------------
# profile_rounds conformance — 8 devices, subprocess
# ---------------------------------------------------------------------------

def test_profile_rounds_conformance_8dev():
    out = run_sub("""
        import numpy as np
        import scipy.sparse as sp
        import jax
        from repro.core import sparse
        from repro.core.engine import Grid, PSelInvEngine
        from repro.core.simulator import (executed_wire_bytes,
                                          simulate_schedule)
        from repro.core.schedule import BYTES_PER_ELT

        A = sp.csr_matrix(sparse.laplacian_2d(16, 8))
        eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2))
        vals = eng.prepare_values(A)
        ref = np.asarray(jax.block_until_ready(eng.solve(vals)))

        prof = eng.profile_rounds(vals, reps=1)
        ov = eng.program.overlap_plan

        # (1) the measured timeline covers the plan's rounds exactly
        assert prof.nrounds == len(ov.rounds), (prof.nrounds,
                                                len(ov.rounds))
        assert len(prof.samples) == len(ov.rounds)
        covered = [r for s in prof.samples for r in s.rounds]
        assert covered == list(range(len(ov.rounds)))

        # (2) per-round wire bytes re-derive the executed wire total
        per_round = [len(r.perm) * r.width * eng.b * eng.b
                     * BYTES_PER_ELT for r in ov.rounds]
        for s, w in zip(prof.samples, per_round):
            assert s.wire_bytes == w, (s.index, s.wire_bytes, w)
        assert prof.wire_bytes() == executed_wire_bytes(eng.program)

        # (3) the simulated join sums to the simulator's total
        sim = simulate_schedule(eng.program).total_time * 1e6
        assert abs(prof.sim_us - sim) / sim < 1e-9, (prof.sim_us, sim)

        # (4) the replay IS the sweep: bit-identical A^-1
        assert np.array_equal(np.asarray(prof.ainv), ref)

        # (5) measured walls are real (fenced, nonzero)
        assert all(s.wall_us > 0 for s in prof.samples)
        assert prof.init_us > 0 and prof.final_us > 0

        # (6) inbound joins match the plan's edge tables
        edges = [e for r in ov.rounds for e in r.edges]
        assert prof.inbound_bytes.sum() == sum(e[4] for e in edges)
        assert prof.inbound_msgs.sum() == len(edges)
        sk = prof.skew()
        assert sk["skew_ratio"] >= 1.0
        assert isinstance(sk["exceeds_static_warn"], bool)
        alpha, beta = prof.fit_alpha_beta()
        assert alpha >= 0 and beta >= 0

        # (7) chunked replay: same coverage, same wire accounting
        prof4 = eng.profile_rounds(vals, chunk=4, reps=1)
        covered4 = [r for s in prof4.samples for r in s.rounds]
        assert covered4 == list(range(len(ov.rounds)))
        assert prof4.wire_bytes() == executed_wire_bytes(eng.program)
        assert np.array_equal(np.asarray(prof4.ainv), ref)
        print("conformance ok:", prof.nrounds, "rounds,",
              int(prof.wire_bytes()), "wire bytes")
    """)
    assert "conformance ok: 28 rounds, 177152 wire bytes" in out
