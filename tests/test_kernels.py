"""Pallas kernels: shape/dtype sweeps, allclose vs the ref.py oracles."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.block_gemm import block_gemm_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.trsm import trsm_pallas

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 128),
                                   (200, 130, 70), (33, 17, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_gemm_sweep(m, k, n, dtype):
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    out = block_gemm_pallas(a, b, interpret=True)
    expect = ref.gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("alpha", [1.0, -1.0])
def test_block_gemm_alpha(alpha):
    a = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
    out = block_gemm_pallas(a, a, alpha=alpha, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               alpha * np.asarray(a) @ np.asarray(a),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,H,hd", [(1, 128, 2, 64), (2, 256, 4, 64),
                                      (1, 512, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, hd, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, H, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, H, hd)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, bq=128, bk=128,
                                 interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 3e-3,
                               atol=3e-2 if dtype == jnp.bfloat16 else 3e-3)


def test_flash_matches_model_attention_path():
    """The pure-jnp chunked attention in models/ and the Pallas kernel
    agree (same oracle)."""
    from repro.models.attention import _flash
    q = jnp.asarray(RNG.standard_normal((2, 256, 4, 64)), jnp.float32)
    a = _flash(q, q, q, 0, True, 64, 64)
    b = flash_attention_pallas(q * 64 ** -0.5 / (64 ** -0.5), q, q,
                               causal=True, interpret=True)
    # _flash applies the scale internally; pass the same inputs
    a2 = _flash(q, q, q, 0, True, 128, 128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(a2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@pytest.mark.parametrize("rows,d", [(64, 256), (100, 512), (7, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    x = jnp.asarray(RNG.standard_normal((rows, d)), dtype)
    s = jnp.asarray(RNG.standard_normal((d,)), dtype)
    out = rmsnorm_pallas(x, s, interpret=True)
    expect = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


@pytest.mark.parametrize("m,k", [(64, 32), (100, 64), (130, 48)])
def test_trsm_sweep(m, k):
    u = jnp.asarray(np.triu(RNG.standard_normal((k, k))) + 4 * np.eye(k),
                    jnp.float32)
    b = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    out = trsm_pallas(b, u, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.trsm_ref(b, u)),
                               rtol=1e-3, atol=1e-3)
    # residual check: X @ U == B
    np.testing.assert_allclose(np.asarray(out) @ np.asarray(u),
                               np.asarray(b), rtol=1e-4, atol=1e-4)
