"""Stream lowering tests: the uniform round-stream executor
(``core/stream.py`` + ``pselinv_dist.make_sweep_stream``).

(a) replay property — the round-indexed (R, P, W) tables reproduce the
    unrolled :class:`~.plan.GlobalRound` list round-for-round: same
    (src, dst, gather slot, scatter slot, add, transpose, L̂-gather)
    lanes, same owner-local moves, same compute boundaries, same
    byte-accounted edges — padded lanes all masked into the trash block;
(b) accounting — ``round_schedule_from_stream`` equals
    ``round_schedule_from_overlap`` event-for-event (simulated bytes
    still equal executed bytes) and ``round_schedule_of`` routes stream
    programs through it;
(c) execution — ``make_sweep_stream`` (one ``lax.fori_loop`` body) is
    f64 bit-identical to the unrolled overlapped executor and the
    level-serial oracle at nb=16 (tier-1) and nb=32 (``slow`` marker,
    excluded from tier-1 by default);
(d) wiring — ``PlanOptions(stream=True)`` flows through engine
    analyze/solve/stats (compile metrics included), and the deprecated
    ``run_distributed``/``prepare_inputs`` shims warn.
"""
import numpy as np
import pytest
import scipy.sparse as sp

from conftest import run_sub

from repro.core import sparse
from repro.core.plan import (PlanOptions, build_plan, schedule_overlapped,
                             schedule_stream)
from repro.core.schedule import Grid2D
from repro.core.simulator import (round_schedule_from_overlap,
                                  round_schedule_from_stream,
                                  round_schedule_of, simulate_schedule)
from repro.core.stream import (COMP_KIND_ID, decode_local_lanes,
                               decode_round_lanes, lower_stream)
from repro.core.symbolic import symbolic_factorize
from repro.core.trees import TreeKind


@pytest.fixture(scope="module", params=[None, 1])
def ov_st(request):
    """nb=32 plan on grid 4×2 → (plan, overlapped lowering, stream
    tables), with and without a Û liveness window (window=1 forces slot
    recycling through the stream tables too)."""
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(32, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=32)
    ov = schedule_overlapped(plan, window=request.param)
    return plan, ov, lower_stream(ov)


def _round_real_lanes(ov, rnd):
    """The overlapped round's real comm lanes in the decode tuple form
    (a real lane is one whose receiver scatter slot is not trash)."""
    out = set()
    for (s, d) in rnd.perm:
        for j in range(rnd.width):
            ds = int(rnd.scatter[d, j])
            if ds == ov.trash:
                continue
            out.add((s, d, int(rnd.gather[s, j]), ds,
                     float(rnd.addm[d, j]), bool(rnd.tmask[d, j]),
                     bool(rnd.glh[s, j])))
    return out


def test_stream_tables_replay_rounds(ov_st):
    """The replay property: every comm lane, owner-local move and
    compute boundary of the unrolled GlobalRound list is reproduced
    round-for-round by the uniform tables, and nothing else — the padded
    lanes all land in the trash block."""
    plan, ov, st = ov_st
    P = ov.pr * ov.pc
    assert st.nrounds == len(ov.rounds)
    assert st.steps == st.nrounds + 1
    assert st.arena_blocks == ov.arena_blocks and st.trash == ov.trash

    n_real = 0
    for t, rnd in enumerate(ov.rounds):
        decoded = set(decode_round_lanes(st, t))
        expect = _round_real_lanes(ov, rnd)
        assert decoded == expect, f"round {t} comm lanes drifted"
        n_real += len(expect)
        # byte-movement metadata is the round's, verbatim
        assert st.lane_edges[t] == rnd.edges
        # local moves: real lanes match, the LW padding is all-trash
        dec_loc = set(decode_local_lanes(st, t))
        exp_loc = set()
        for dev in range(P):
            for j in range(rnd.lwidth):
                ds = int(rnd.lscatter[dev, j])
                if ds == ov.trash:
                    continue
                exp_loc.add((dev, int(rnd.lgather[dev, j]), ds,
                             bool(rnd.ltmask[dev, j]),
                             bool(rnd.lglh[dev, j])))
        assert dec_loc == exp_loc, f"round {t} local lanes drifted"
    assert n_real == sum(len(r.edges) for r in ov.rounds)

    # the final fori_loop iteration is a comm no-op: all-trash tables
    assert not decode_round_lanes(st, st.nrounds)
    assert not decode_local_lanes(st, st.nrounds)

    # compute boundaries: same ops, same dependence order, same levels
    for t, ops in enumerate(ov.compute_at):
        got = [(int(k), int(l))
               for k, l in zip(st.comp_kind[t], st.comp_level[t]) if k]
        assert got == [(COMP_KIND_ID[op.kind], op.level) for op in ops]

    # level tables: the real prefix is the overlapped level's, the NK
    # padding is inert (trash Û lanes, zero masks, no-device diag root)
    for L, lv in enumerate(ov.levels):
        nk = len(lv.Ks)
        nbc = ov.nbc
        np.testing.assert_array_equal(st.u_gather[L, :, :nk * nbc],
                                      lv.u_gather)
        assert (st.u_gather[L, :, nk * nbc:] == st.trash).all()
        np.testing.assert_array_equal(st.cmask[L, :, :nk], lv.cmask)
        assert (st.cmask[L, :, nk:] == 0).all()
        assert (st.diag_root[L, nk:] == -1).all()
        assert (st.diag_slot[L, nk:] == st.trash).all()


def test_stream_round_schedule_matches_overlap(ov_st):
    """Simulated bytes equal executed bytes, stream edition: the
    timeline derived from the stream tables equals the overlapped
    executor's event-for-event, and the α-β simulator times both to the
    same total."""
    plan, ov, st = ov_st
    rs_o = round_schedule_from_overlap(ov, plan)
    rs_s = round_schedule_from_stream(st, plan)
    assert rs_s.nranks == rs_o.nranks
    assert rs_s.peak_arena_blocks == rs_o.peak_arena_blocks
    assert len(rs_s.events) == len(rs_o.events)
    for (wa, pa), (wb, pb) in zip(rs_o.events, rs_s.events):
        assert wa == wb
        if wa == "comp":
            np.testing.assert_array_equal(pa, pb)
        else:
            assert pa == pb
    sim_o = simulate_schedule(rs_o)
    sim_s = simulate_schedule(rs_s)
    assert sim_s.total_time == sim_o.total_time


def test_round_schedule_of_routes_stream_programs():
    """A stream-compiled program's executed timeline comes from its own
    tables (``round_schedule_from_stream``), not the overlapped object
    it was lowered from — and matches it."""
    from repro.core.pselinv_dist import build_program
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(12, 8)), max_supernode=8)
    prog = build_program(bs, 12, 8, 4, 2,
                         options=PlanOptions(stream=True))
    assert prog.stream_tables is not None
    rs = round_schedule_of(prog)
    rs_o = round_schedule_from_overlap(prog.overlap_plan, prog.plan)
    assert len(rs.events) == len(rs_o.events)
    assert simulate_schedule(rs).total_time == \
        simulate_schedule(rs_o).total_time


def test_stream_requires_overlap():
    """stream=True without the overlapped lowering is a contradiction —
    rejected at the options layer and at build_program."""
    from repro.core.pselinv_dist import build_program
    with pytest.raises(ValueError, match="overlap=True"):
        PlanOptions(stream=True, overlap=False)
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(4, 8)), max_supernode=8)
    with pytest.raises(ValueError, match="overlap=True"):
        build_program(bs, 4, 8, 1, 1, overlap=False, stream=True)


def test_schedule_stream_single_device():
    """Degenerate grid (1×1): no comm at all — the stream has an empty
    shift set and the tables still replay the (local + compute only)
    rounds."""
    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(8, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(1, 1), TreeKind.SHIFTED, nb=8)
    ov, st = schedule_stream(plan)
    assert st.shifts == () and st.nslots == 0 and st.W == 0
    assert st.nrounds == len(ov.rounds)
    assert (st.recv_slot == -1).all()
    assert st.slot_active.shape == (st.steps, 0)
    for t in range(st.steps):
        assert not decode_round_lanes(st, t)


def test_stream_shift_mask_replay(ov_st):
    """Gated-slot property test: the per-round shift-mask tables decode
    back to exactly the GlobalRound lane sets, round for round. Every
    slot perm is a single grid-offset bijection; a round's recv-slot
    assignments derive exactly the slots its gate row activates; the
    union of active slots covers exactly the round's permute pairs; and
    the executed-wire number from the gate table equals the simulator's
    independent recv-slot lens (simulated == executed, wire edition)."""
    import types

    from repro.core.simulator import executed_wire_bytes
    from repro.core.stream import stream_shifts_per_round, \
        stream_wire_blocks, stream_wire_bytes

    plan, ov, st = ov_st
    pr, pc = st.pr, st.pc
    assert st.axis_factored and st.nslots > 0

    for si, perm in enumerate(st.slot_perm):
        offs = {((d // pc - s // pc) % pr, (d % pc - s % pc) % pc)
                for (s, d) in perm}
        assert offs == {tuple(st.slot_shift[si])}, \
            f"slot {si} mixes grid offsets {offs}"
        assert len({s for s, _ in perm}) == len(perm)
        assert len({d for _, d in perm}) == len(perm)
        assert 1 <= st.slot_width[si] <= st.W

    for t, rnd in enumerate(ov.rounds):
        gated = {si for si in range(st.nslots) if st.slot_active[t, si]}
        derived = {int(si) for si in st.recv_slot[t] if si >= 0}
        assert gated == derived, f"round {t} gate/receive drift"
        # the active slots cover exactly this round's permute pairs
        pairs = {(s, d) for (s, d) in rnd.perm}
        for (s, d) in pairs:
            si = int(st.recv_slot[t, d])
            assert (s, d) in st.slot_perm[si]
        # decoded gated lanes == GlobalRound lanes (the replay property,
        # through the gate-checking decode path)
        assert set(decode_round_lanes(st, t)) == _round_real_lanes(ov,
                                                                   rnd)
    assert not st.slot_active[st.nrounds].any()

    # wire accounting: gate-table blocks == the manual per-round sum,
    # and the simulator's independent lens prices the same bytes
    manual = sum(len(st.slot_perm[si]) * st.slot_width[si]
                 for t in range(st.steps)
                 for si in range(st.nslots) if st.slot_active[t, si])
    assert stream_wire_blocks(st) == manual
    prog = types.SimpleNamespace(b=8, stream_tables=st,
                                 overlap_plan=ov)
    assert executed_wire_bytes(prog) == stream_wire_bytes(st, 8)
    # gating executes fewer permutes per round than the flat-ring
    # encoding's every-shift-every-round
    assert 0 < stream_shifts_per_round(st) < len(st.shifts)


def test_stream_flat_ring_mode():
    """``axis_factored=False`` recovers the PR-5 flat-ring encoding —
    one always-active full-ring slot per used shift — through the same
    slot machinery, and the gated grid-factored lowering of the same
    plan ships strictly (>2×) fewer wire blocks."""
    from repro.core.stream import stream_wire_blocks

    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(16, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=16)
    ov_f, st_f = schedule_stream(plan, axis_factored=False)
    assert not st_f.axis_factored
    P = 8
    assert st_f.nslots == len(st_f.shifts)
    for si, perm in enumerate(st_f.slot_perm):
        dlt = st_f.slot_shift[si]
        assert dlt == ((perm[0][1] - perm[0][0]) % P,)
        assert len(perm) == P and st_f.slot_width[si] == st_f.W
    assert st_f.slot_active.all()
    assert stream_wire_blocks(st_f) == \
        st_f.steps * st_f.nslots * P * st_f.W
    # flat mode still replays the identical lanes
    for t, rnd in enumerate(ov_f.rounds):
        assert set(decode_round_lanes(st_f, t)) == _round_real_lanes(
            ov_f, rnd)

    ov_g, st_g = schedule_stream(plan)
    assert 2 * stream_wire_blocks(st_g) < stream_wire_blocks(st_f)


def test_stream_shift_budget_coarsens():
    """``shift_budget`` trades wire for fewer gated permutes: the slot
    dictionary shrinks to the budget (or one slot per grid offset), the
    replay property still holds lane-for-lane, and the wire cost sits
    between the exact-width dictionary's and the flat ring's."""
    from repro.core.stream import stream_wire_blocks

    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(16, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(4, 2), TreeKind.SHIFTED, nb=16)
    ov, st = schedule_stream(plan)
    noffs = len({tuple(sh) for sh in st.slot_shift})
    ovb, stb = schedule_stream(plan, shift_budget=noffs)
    assert stb.nslots <= noffs < st.nslots
    for t, rnd in enumerate(ovb.rounds):
        assert set(decode_round_lanes(stb, t)) == _round_real_lanes(
            ovb, rnd)
    ov_f, st_f = schedule_stream(plan, axis_factored=False)
    assert stream_wire_blocks(st) <= stream_wire_blocks(stb) \
        < stream_wire_blocks(st_f)
    with pytest.raises(ValueError, match="one comm slot per grid "
                                         "offset"):
        schedule_stream(plan, shift_budget=1)
    with pytest.raises(ValueError, match="axis_factored=True"):
        PlanOptions(stream=True, axis_factored=False, shift_budget=4)


def test_stream_tables_grid8x4():
    """Tentpole validation at grid 8×4, where the flat ring pays ~200×
    unrolled wire: host-side lowering replays lane-for-lane, simulated
    wire equals executed wire from the gated tables, and the gated
    encoding lands within 4× of the unrolled executor's wire (the flat
    ring's every-shift-every-round is >25× here)."""
    import types

    from repro.core.simulator import executed_wire_bytes
    from repro.core.stream import overlap_wire_blocks, \
        stream_shifts_per_round, stream_wire_blocks, stream_wire_bytes

    bs = symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(32, 8)), max_supernode=8)
    plan = build_plan(bs, Grid2D(8, 4), TreeKind.SHIFTED, nb=32)
    ov, st = schedule_stream(plan)
    for t, rnd in enumerate(ov.rounds):
        assert set(decode_round_lanes(st, t)) == _round_real_lanes(ov,
                                                                   rnd)
    prog = types.SimpleNamespace(b=8, stream_tables=st, overlap_plan=ov)
    assert executed_wire_bytes(prog) == stream_wire_bytes(st, 8)

    wire_unrolled = overlap_wire_blocks(ov)
    wire_gated = stream_wire_blocks(st)
    _, st_f = schedule_stream(plan, axis_factored=False)
    wire_flat = stream_wire_blocks(st_f)
    assert wire_gated <= 4 * wire_unrolled, (wire_gated, wire_unrolled)
    assert wire_flat > 25 * wire_unrolled, (wire_flat, wire_unrolled)
    assert stream_shifts_per_round(st) < len(st.shifts) / 2


def test_stream_executor_bit_identical_nb16():
    """End-to-end f64: the fori_loop stream executor matches the
    unrolled overlapped executor and the level-serial executor exactly
    (≤1e-12 asserted, 0.0 observed) and the dense oracle on the selected
    pattern, at nb=16 on grid 4×2."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import sparse
        from repro.core.plan import PlanOptions
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import (analyze_structure,
                                             build_program, gather_blocks,
                                             make_sweep,
                                             make_sweep_overlapped,
                                             make_sweep_stream,
                                             prepare_values)
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(16, 8)
        b, pr, pc = 8, 4, 2
        bs, nb = analyze_structure(A, b, pr, pc)
        Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
        devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
        mesh = Mesh(devs, ("xy",))
        Lh = jnp.asarray(Lh_s, jnp.float64)
        Dinv = jnp.asarray(Dinv_s, jnp.float64)

        def run(prog, mk):
            fn = jax.jit(shard_map(mk(prog), mesh=mesh,
                                   in_specs=(P("xy"), P("xy")),
                                   out_specs=P("xy")))
            return np.asarray(fn(Lh, Dinv))

        prog_t = build_program(bs, nb, b, pr, pc,
                               options=PlanOptions(stream=True))
        out_t = run(prog_t, make_sweep_stream)
        prog_o = build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED,
                               overlap=True)
        out_o = run(prog_o, make_sweep_overlapped)
        prog_s = build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED)
        out_s = run(prog_s, make_sweep)
        assert abs(out_t - out_o).max() <= 1e-12, abs(out_t - out_o).max()
        assert abs(out_t - out_s).max() <= 1e-12, abs(out_t - out_s).max()

        ref = dense_selinv_oracle(A)
        blocks = gather_blocks(out_t, prog_t)
        err = 0.0
        for K in range(bs.nsuper):
            err = max(err, abs(blocks[K, K]
                               - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
            for I in bs.struct[K]:
                I = int(I)
                err = max(err, abs(blocks[I, K]
                                   - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
        assert err < 1e-9, err
        print("OK")
    """, x64=True)


@pytest.mark.slow
def test_stream_executor_bit_identical_nb32():
    """The nb=32 acceptance case (slow — excluded from tier-1 by the
    default ``-m "not slow"``; run with ``-m slow``): stream vs unrolled
    overlapped vs serial oracle, f64, including a recycled arena
    (window=1) stream."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import sparse
        from repro.core.plan import PlanOptions
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import (analyze_structure,
                                             build_program, gather_blocks,
                                             make_sweep,
                                             make_sweep_overlapped,
                                             make_sweep_stream,
                                             prepare_values)
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(32, 8)
        b, pr, pc = 8, 4, 2
        bs, nb = analyze_structure(A, b, pr, pc)
        Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
        devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
        mesh = Mesh(devs, ("xy",))
        Lh = jnp.asarray(Lh_s, jnp.float64)
        Dinv = jnp.asarray(Dinv_s, jnp.float64)

        def run(prog, mk):
            fn = jax.jit(shard_map(mk(prog), mesh=mesh,
                                   in_specs=(P("xy"), P("xy")),
                                   out_specs=P("xy")))
            return np.asarray(fn(Lh, Dinv))

        out_t = run(build_program(bs, nb, b, pr, pc,
                                  options=PlanOptions(stream=True)),
                    make_sweep_stream)
        out_w = run(build_program(bs, nb, b, pr, pc,
                                  options=PlanOptions(stream=True,
                                                      window=1)),
                    make_sweep_stream)
        out_o = run(build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED,
                                  overlap=True), make_sweep_overlapped)
        prog_s = build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED)
        out_s = run(prog_s, make_sweep)
        assert abs(out_t - out_o).max() <= 1e-12, abs(out_t - out_o).max()
        assert abs(out_t - out_s).max() <= 1e-12, abs(out_t - out_s).max()
        assert abs(out_w - out_s).max() <= 1e-12, abs(out_w - out_s).max()

        ref = dense_selinv_oracle(A)
        blocks = gather_blocks(out_t, prog_s)
        err = 0.0
        for K in range(bs.nsuper):
            err = max(err, abs(blocks[K, K]
                               - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
            for I in bs.struct[K]:
                I = int(I)
                err = max(err, abs(blocks[I, K]
                                   - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
        assert err < 1e-9, err
        print("OK")
    """, x64=True, timeout=600)


@pytest.mark.slow
@pytest.mark.bigmesh
def test_stream_executor_bit_identical_grid8x4():
    """The tentpole's target scale: a 32-host-device 8×4 grid
    (``bigmesh`` marker — run with ``-m bigmesh``), where the flat ring
    would execute 31 permutes every round. The gated stream executor is
    f64 bit-identical to the unrolled overlapped executor and the
    level-serial oracle, and its executed wire matches the simulator."""
    run_sub("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core import sparse
        from repro.core.plan import PlanOptions
        from repro.core.simulator import executed_wire_bytes
        from repro.core.stream import stream_wire_bytes
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import (analyze_structure,
                                             build_program, gather_blocks,
                                             make_sweep,
                                             make_sweep_overlapped,
                                             make_sweep_stream,
                                             prepare_values)
        A = sparse.laplacian_2d(32, 8)
        b, pr, pc = 8, 8, 4
        bs, nb = analyze_structure(A, b, pr, pc)
        Lh_s, Dinv_s = prepare_values(A, bs, nb, b, pr, pc)
        devs = np.array(jax.devices()[:pr * pc]).reshape(pr * pc)
        mesh = Mesh(devs, ("xy",))
        Lh = jnp.asarray(Lh_s, jnp.float64)
        Dinv = jnp.asarray(Dinv_s, jnp.float64)

        def run(prog, mk):
            fn = jax.jit(shard_map(mk(prog), mesh=mesh,
                                   in_specs=(P("xy"), P("xy")),
                                   out_specs=P("xy")))
            return np.asarray(fn(Lh, Dinv))

        prog_t = build_program(bs, nb, b, pr, pc,
                               options=PlanOptions(stream=True))
        assert executed_wire_bytes(prog_t) == \\
            stream_wire_bytes(prog_t.stream_tables, b)
        out_t = run(prog_t, make_sweep_stream)
        out_o = run(build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED,
                                  overlap=True), make_sweep_overlapped)
        out_s = run(build_program(bs, nb, b, pr, pc, TreeKind.SHIFTED),
                    make_sweep)
        assert abs(out_t - out_o).max() <= 1e-12, abs(out_t - out_o).max()
        assert abs(out_t - out_s).max() <= 1e-12, abs(out_t - out_s).max()
        print("OK")
    """, ndev=32, x64=True, timeout=600)


def test_stream_engine_session_end_to_end():
    """PlanOptions(stream=True) through the engine: cached analyze, a
    no-retrace solve hot path, batched solves bit-identical to the
    single path, and compile metrics off stats(compile=True) showing the
    stream program strictly smaller + faster-compiling than the unrolled
    overlapped program of the same structure."""
    run_sub("""
        import numpy as np
        import scipy.sparse as sp
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.engine import Grid, PlanOptions, PSelInvEngine

        A = sparse.laplacian_2d(16, 8)
        PSelInvEngine.clear_cache()
        opts = PlanOptions(stream=True)
        eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2), options=opts)
        assert eng.program.stream_tables is not None
        again = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                      options=PlanOptions(stream=True))
        assert again is eng            # options hash in the cache key
        base = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                     options=PlanOptions())
        assert base is not eng

        # stats: schedule keys shared with the unrolled session, plus
        # the stream session's executed-wire pair; compile metrics on
        # demand
        cache_keys = {"table_bytes", "cache_engines", "cache_hits",
                      "cache_misses", "cache_evictions"}
        gauge_keys = {"solve_calls", "last_solve_us", "prepare_us"}
        s = eng.stats()
        assert set(s) == {"ppermute_rounds", "peak_arena_blocks",
                          "stream_wire_bytes",
                          "stream_shifts_per_round"} \
            | cache_keys | gauge_keys
        sb = base.stats()
        assert set(sb) == {"ppermute_rounds",
                           "peak_arena_blocks"} | cache_keys | gauge_keys
        for k in ("ppermute_rounds", "peak_arena_blocks"):
            assert s[k] == sb[k]       # same schedule, same arena
        assert s["stream_wire_bytes"] > 0
        # gating beats the flat-ring encoding's every-shift-every-round
        nshifts = len(eng.program.stream_tables.shifts)
        assert 0 < s["stream_shifts_per_round"] < nshifts
        # simulated == executed wire: the simulator's independent lens
        # over the gated tables agrees with the table-derived number
        from repro.core.simulator import executed_wire_bytes
        assert executed_wire_bytes(eng) == s["stream_wire_bytes"]
        cs = eng.stats(compile=True)
        cu = base.stats(compile=True)
        for k in ("trace_lower_ms", "compile_ms", "jaxpr_lines",
                  "hlo_bytes"):
            assert cs[k] > 0 and cu[k] > 0
        assert cs["hlo_bytes"] <= 0.5 * cu["hlo_bytes"], (cs, cu)
        assert cs["jaxpr_lines"] < cu["jaxpr_lines"]
        assert eng.compile_stats() is eng.compile_stats()   # cached

        # solve: f64 bit-identical to the unrolled overlapped engine,
        # no retrace across repeated solves, batched == loop of singles
        out = np.asarray(eng.solve(A, dtype=jnp.float64))
        out_b = np.asarray(base.solve(A, dtype=jnp.float64))
        assert abs(out - out_b).max() <= 1e-12
        t0 = eng.trace_count
        eng.solve(A, dtype=jnp.float64)
        assert eng.trace_count == t0, "stream solve retraced"
        mats = [A + sp.identity(A.shape[0]) * c for c in (0.0, 0.5)]
        outs = np.asarray(eng.solve_many(mats, dtype=jnp.float64))
        for i, M in enumerate(mats):
            d = abs(outs[i]
                    - np.asarray(eng.solve(M, dtype=jnp.float64))).max()
            assert d <= 1e-12, (i, d)

        # the executed-timeline plumbing routes through the stream tables
        sim = eng.simulate()
        assert sim.total_time == base.simulate().total_time
        print("OK")
    """, x64=True, timeout=600)


def test_shims_emit_deprecation_warning():
    """The documented-deprecated ``run_distributed``/``prepare_inputs``
    shims actually warn, pointing at PSelInvEngine."""
    from repro.core.pselinv_dist import prepare_inputs, run_distributed
    A = sparse.laplacian_2d(4, 8)
    with pytest.warns(DeprecationWarning, match="PSelInvEngine"):
        prepare_inputs(A, b=8, pr=1, pc=1)
    with pytest.warns(DeprecationWarning, match="PSelInvEngine"):
        out, prog = run_distributed(A, b=8, pr=1, pc=1)
    assert np.isfinite(np.asarray(out)).all()
