"""Roofline methodology cross-validation: the analytic FLOPs model agrees
with XLA's cost_analysis on a config whose layer scan has trip count 1
(so XLA's count-body-once behaviour doesn't under-report), plus sanity
properties of param counting and the dry-run HLO collective parser."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.compat import cost_analysis_dict
from repro.config import ShapeConfig, get_config, reduced_config
from repro.launch.roofline import flops_model, model_flops, param_count
from repro.models import get_model


def test_param_count_matches_actual_tree():
    for arch in ("granite-3-2b", "dbrx-132b", "xlstm-125m"):
        r = reduced_config(get_config(arch))
        api = get_model(r)
        actual = sum(x.size for x in
                     jax.tree_util.tree_leaves(api.param_shapes()))
        total, active = param_count(r)
        assert total == pytest.approx(actual, rel=0.06), arch
        assert active <= total


def test_analytic_flops_vs_xla_cost_analysis():
    """Single-scan-trip config: XLA reports complete flops; the analytic
    model must land within 35% (it over-counts slightly: XLA fuses some
    elementwise work and counts dots only)."""
    base = reduced_config(get_config("granite-3-2b"))
    cfg = dataclasses.replace(base, n_layers=2, layer_group=2,
                              remat="none")
    shape = ShapeConfig("tiny", seq_len=64, global_batch=2, mode="prefill")
    api = get_model(cfg)

    def fwd(params, tokens):
        from repro.models.transformer import lm_forward
        logits, _ = lm_forward(params, cfg, tokens)
        return jnp.sum(logits.astype(jnp.float32))

    pshapes = api.param_shapes()
    toks = jax.ShapeDtypeStruct((2, 64), jnp.int32)
    cost = cost_analysis_dict(jax.jit(fwd).lower(pshapes, toks).compile())
    xla_flops = float(cost["flops"])
    anal = flops_model(cfg, shape)["flops"]
    assert anal == pytest.approx(xla_flops, rel=0.35), \
        (anal, xla_flops, anal / xla_flops)


def test_model_flops_anchors():
    """6·N·D for dense train; MoE active < total."""
    g = get_config("granite-3-2b")
    total, active = param_count(g)
    assert 2.0e9 < total < 3.5e9          # ~2.5B params
    grok = get_config("grok-1-314b")
    t2, a2 = param_count(grok)
    assert 2.7e11 < t2 < 3.6e11           # ~314B total
    assert a2 < 0.5 * t2                  # top-2 of 8 experts


def test_collective_parser_trip_counts():
    """The while-aware HLO parser multiplies scan-body collectives by the
    trip count (verified against a hand-built program)."""
    import os
    import subprocess
    import sys
    import textwrap
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.compat import shard_map
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.launch.dryrun import collective_bytes
        mesh = Mesh(np.array(jax.devices()[:4]), ("x",))

        def f(v):
            def body(c, _):
                return c + lax.psum(c, "x"), None
            out, _ = lax.scan(body, v, None, length=7)
            return out
        txt = jax.jit(shard_map(f, mesh=mesh, in_specs=P("x"),
                                    out_specs=P("x"))).lower(
            jnp.zeros((4, 128))).compile().as_text()
        cb = collective_bytes(txt)
        ar = cb.get("all-reduce", 0.0)
        # 7 iterations x 128 floats x 4B = 3584B (give fusion slack)
        assert 3 * 512 <= ar <= 10 * 512, cb
        print("OK", cb)
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
