"""Per-architecture smoke tests (reduced configs, CPU): one forward +
one train-grad step + a two-token decode; asserts shapes and finiteness.
Exercises every family code path (dense/moe/encdec/ssm/vlm/hybrid)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import SHAPES, get_config, list_configs, reduced_config
from repro.models import get_model
from repro.models import encdec as encdec_mod

ARCHS = list(list_configs())


def _batch(r, B=2, S=32):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if r.frontend == "vision":
        batch["frontend"] = jnp.ones((B, r.n_frontend_tokens, r.d_model),
                                     jnp.float32)
    elif r.enc_layers:
        batch["frontend"] = jnp.ones((B, S, r.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    r = reduced_config(get_config(arch))
    api = get_model(r)
    params = api.init(jax.random.key(0))
    batch = _batch(r)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss(p, batch))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    r = reduced_config(get_config(arch))
    api = get_model(r)
    params = api.init(jax.random.key(0))
    B, S = 2, 16
    if api.is_encdec:
        frames = jnp.ones((B, 8, r.d_model), jnp.float32)
        cache = encdec_mod.encdec_init_cache(params, r, frames, seq=S)
    else:
        cache = api.init_cache(B, S)
    tok = jnp.zeros((B,), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits, cache = api.decode_step(params, tok, pos, cache)
    logits2, _ = api.decode_step(params, tok + 1, pos + 1, cache)
    assert logits.shape == (B, r.vocab_padded)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_shape_support_rules(arch):
    cfg = get_config(arch)
    ok, why = cfg.supports_shape(SHAPES["long_500k"])
    if cfg.family in ("ssm", "hybrid"):
        assert ok
    else:
        assert not ok and "sub-quadratic" in why
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert cfg.supports_shape(SHAPES[s])[0]


def test_decode_matches_forward_dense():
    """Teacher-forced decode reproduces the forward logits (granite)."""
    r = reduced_config(get_config("granite-3-2b"))
    api = get_model(r)
    params = api.init(jax.random.key(1))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, r.vocab)
    from repro.models.transformer import lm_forward
    full_logits, _ = lm_forward(params, r, toks)
    cache = api.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = api.decode_step(
            params, toks[:, t], jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_mamba_chunk_invariance():
    from repro.models.mamba import _ssm_scan
    key = jax.random.key(0)
    B, S, di, ds = 2, 96, 8, 4
    u = jax.random.normal(key, (B, S, di))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (B, S, di)))
    A = jnp.log(jnp.arange(1., ds + 1.))[None, :].repeat(di, 0)
    Bc = jax.random.normal(jax.random.key(2), (B, S, ds))
    Cc = jax.random.normal(jax.random.key(3), (B, S, ds))
    y1 = _ssm_scan(u, dt, A, Bc, Cc, chunk=96)
    y2 = _ssm_scan(u, dt, A, Bc, Cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_moe_routes_and_balances():
    """MoE with forced-uniform router logits keeps all tokens (no drops)
    and aux loss ~ 1."""
    from repro.models import moe as moe_mod
    r = reduced_config(get_config("dbrx-132b"))
    p = moe_mod.init_moe(jax.random.key(0), r, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, r.d_model))
    y, aux = moe_mod.moe_ffn(p, r, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))


def test_encdec_decode_matches_forward():
    """Teacher-forced enc-dec decode reproduces the full-forward logits
    (cross-attention + self-attention cache paths)."""
    r = reduced_config(get_config("seamless-m4t-large-v2"))
    api = get_model(r)
    params = api.init(jax.random.key(3))
    B, S = 1, 8
    frames = jax.random.normal(jax.random.key(4), (B, 8, r.d_model))
    toks = jax.random.randint(jax.random.key(5), (B, S), 0, r.vocab)
    full = encdec_mod.encdec_forward(params, r, toks, frames)
    cache = encdec_mod.encdec_init_cache(params, r, frames, seq=S)
    outs = []
    for t in range(S):
        lg, cache = api.decode_step(
            params, toks[:, t], jnp.full((B,), t, jnp.int32), cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=6e-2, atol=6e-2)
