"""Distributed tests — run in subprocesses with their own XLA device
count (8 host devices), so the main pytest process stays single-device."""
import pytest

from conftest import run_sub


def test_run_distributed_validates_grid_and_inputs():
    """An oversized process grid raises a ValueError naming the requested
    grid vs the available devices (it used to die in a cryptic numpy
    reshape inside the device slicing), and ``prepare_inputs`` rejects a
    matrix whose size is not a multiple of the block size with a real
    ValueError (not an ``assert`` that vanishes under ``python -O``).
    The main pytest process is single-device, which is exactly the
    misconfiguration the grid check must catch."""
    from repro.core import sparse
    from repro.core.pselinv_dist import prepare_inputs, run_distributed

    A = sparse.laplacian_2d(12, 8)
    # a grid no host plausibly satisfies, so the check fires regardless
    # of how many devices this machine (or its XLA_FLAGS) exposes
    with pytest.raises(ValueError, match=r"grid 64x64 needs 4096 devices"):
        run_distributed(A, b=8, pr=64, pc=64)
    with pytest.raises(ValueError, match=r"not a multiple of the supernode"):
        prepare_inputs(A, b=7, pr=1, pc=1)


def test_tree_collectives_match_builtins():
    run_sub("""
        import jax, numpy as np
        from repro.compat import shard_map
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.trees import TreeKind, build_tree
        from repro.comm.treecomm import (tree_allreduce, subset_broadcast,
                                         subset_reduce)
        devs = jax.devices()
        mesh = Mesh(np.array(devs).reshape(8), ("x",))
        x = jnp.arange(8.0 * 4).reshape(8, 4)
        members = [1, 3, 4, 6]
        y = jax.jit(shard_map(
            lambda v: subset_broadcast(v, "x", 3, members,
                                       TreeKind.SHIFTED, tag=7),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        y = np.asarray(y)
        for r in range(8):
            exp = x[3] if r in members else x[r]
            assert np.allclose(y[r], exp)
        z = jax.jit(shard_map(
            lambda v: subset_reduce(v, "x", 4, members, TreeKind.BINARY),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        assert np.allclose(np.asarray(z)[4],
                           sum(np.asarray(x[m]) for m in members))
        tree = build_tree(TreeKind.SHIFTED, 2, [0,1,3,4,5,6,7], tag=13)
        w = jax.jit(shard_map(
            lambda v: tree_allreduce(v, "x", tree),
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
        assert np.allclose(np.asarray(w), np.asarray(x).sum(0))
        print("OK")
    """)


def test_hierarchical_allreduce_matches_psum():
    run_sub("""
        import jax, numpy as np
        from repro.compat import shard_map
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.comm.hierarchical import hierarchical_allreduce
        devs = jax.devices()
        mesh = Mesh(np.array(devs).reshape(2, 4), ("pod", "data"))
        xx = jnp.arange(8.0 * 8).reshape(2, 4, 8)
        def ha(xs):
            return hierarchical_allreduce(
                xs.reshape(8), "pod", "data", 2, 4, tag=3).reshape(1, 1, 8)
        out = jax.jit(shard_map(ha, mesh=mesh, in_specs=P("pod","data"),
                                    out_specs=P("pod","data")))(xx)
        assert np.allclose(np.asarray(out), np.asarray(xx).sum((0,1)))
        print("OK")
    """)


def test_distributed_pselinv_matches_oracle():
    run_sub("""
        import numpy as np
        import jax.numpy as jnp
        from repro.core import sparse
        from repro.core.trees import TreeKind
        from repro.core.pselinv_dist import run_distributed, gather_blocks
        from repro.core.selinv import dense_selinv_oracle
        A = sparse.laplacian_2d(12, 8)
        ref = dense_selinv_oracle(A)
        for kind in (TreeKind.FLAT, TreeKind.SHIFTED):
            out, prog = run_distributed(A, b=8, pr=4, pc=2, kind=kind,
                                        dtype=jnp.float64)
            blocks = gather_blocks(out, prog)
            bs = prog.bs
            err = 0.0
            for K in range(bs.nsuper):
                err = max(err, abs(blocks[K, K]
                                   - ref[K*8:(K+1)*8, K*8:(K+1)*8]).max())
                for I in bs.struct[K]:
                    I = int(I)
                    err = max(err, abs(blocks[I, K]
                                       - ref[I*8:(I+1)*8, K*8:(K+1)*8]).max())
            assert err < 1e-9, (kind, err)
        print("OK")
    """, x64=True)


def test_grad_sync_tree_equals_psum():
    """Manual-DP gradient sync with the paper's hierarchical tree equals
    plain psum (the LM-training integration of the technique)."""
    run_sub("""
        import jax, numpy as np
        from repro.compat import shard_map
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.comm.hierarchical import hierarchical_allreduce
        devs = jax.devices()
        mesh = Mesh(np.array(devs).reshape(2, 4), ("pod", "data"))
        w = jnp.ones((16,)) * 0.5
        x = jnp.arange(2.0 * 4 * 16).reshape(2, 4, 16)

        def loss(w, xb):
            return jnp.sum(jnp.tanh(xb @ w))

        def step_tree(w, xb):
            g = jax.grad(loss)(w, xb.reshape(1, 16))
            g = hierarchical_allreduce(g, "pod", "data", 2, 4, tag=0)
            return g.reshape(1, 1, 16)

        def step_psum(w, xb):
            g = jax.grad(loss)(w, xb.reshape(1, 16))
            return jax.lax.psum(g, ("pod", "data")).reshape(1, 1, 16)

        gt = jax.jit(shard_map(lambda xb: step_tree(w, xb), mesh=mesh,
                     in_specs=P("pod", "data"), out_specs=P("pod","data")))(x)
        gp = jax.jit(shard_map(lambda xb: step_psum(w, xb), mesh=mesh,
                     in_specs=P("pod", "data"), out_specs=P("pod","data")))(x)
        assert np.allclose(np.asarray(gt), np.asarray(gp), rtol=1e-6)
        print("OK")
    """)
