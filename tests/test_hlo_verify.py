"""HloLint tests (``core/hlo_verify.py`` + ``core/hlo_ir.py``): the
compiled-artifact verifier is itself verified.

(a) clean corpus — every shipped executor lowering (level-serial /
    overlapped / gated stream under both ``axis_factored`` settings,
    single and vmapped-batched) traces, lowers and lints with **zero
    ERROR diagnostics** at the jaxpr and StableHLO layers — on an
    abstract mesh, so the 8×4 bigmesh case runs without devices;
(b) mutation self-test — each corruption class the linter exists for
    (retargeted permute pair, dropped round/slot, stray all-gather,
    silent f64 → f32 convert, payload byte drift, loop-trip tampering)
    is injected into a copied compiled artifact and must be caught
    with its distinct diagnostic code;
(c) wire triangle — compiled blocks parsed back out of the StableHLO
    equal the plan-table yardstick and ``executed_wire_bytes`` for both
    the overlapped and stream lowerings;
(d) parser — the shared ``hlo_ir`` multiplier propagation
    (while-edges-only for the dryrun pricing, through-calls for the
    linter) on a synthetic HLO module, and the size-regression lint;
(e) wiring + tooling — ``PlanOptions(verify_compiled=...)`` validates
    its mode, ``build_program`` runs the pass at build time,
    ``engine.compile_stats``/``lint_compiled`` report and lint the
    optimized HLO on real devices, and ``tools/hlo_lint.py`` exits
    clean on the nb=16 corpus.
"""
import dataclasses
import importlib.util
import os
import re

import pytest
import scipy.sparse as sp

from conftest import run_sub
from repro.core import hlo_ir
from repro.core import hlo_verify as HV
from repro.core import sparse
from repro.core.plan import PlanOptions
from repro.core.pselinv_dist import build_program, pad_nb
from repro.core.schedule import BYTES_PER_ELT
from repro.core.symbolic import symbolic_factorize
from repro.core.verify import PlanVerificationError, enforce_verification

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _structure(nx):
    return symbolic_factorize(
        sp.csr_matrix(sparse.laplacian_2d(nx, 8)), max_supernode=8)


def _program(nx, pr, pc, **opts):
    bs = _structure(nx)
    return build_program(bs, pad_nb(bs.nsuper, pr, pc), 8, pr, pc,
                         options=PlanOptions(**opts))


@pytest.fixture(scope="module")
def stream_prog():
    """The mutation target: the nb=16 4×2 gated stream program."""
    return _program(16, 4, 2, stream=True)


@pytest.fixture(scope="module")
def stream_art(stream_prog):
    """(jaxpr, stablehlo_text) of the stream sweep, lowered once on an
    abstract mesh (no devices)."""
    return HV.abstract_lower(stream_prog)


@pytest.fixture(scope="module")
def ov_prog():
    return _program(16, 4, 2, overlap=True)


@pytest.fixture(scope="module")
def ov_art(ov_prog):
    return HV.abstract_lower(ov_prog)


def _errors(diags):
    return [d for d in diags if d.severity == "error"]


def _codes(diags):
    return {d.code for d in _errors(diags)}


# ---------------------------------------------------------------------------
# (a) every shipped executor lowering lints clean at the compiled layer
# ---------------------------------------------------------------------------

def test_stream_compiled_lints_clean(stream_prog, stream_art):
    jaxpr, sh = stream_art
    assert _errors(HV.lint_jaxpr(jaxpr, stream_prog)) == []
    assert _errors(HV.lint_text(sh, stream_prog)) == []


def test_overlap_compiled_lints_clean(ov_prog, ov_art):
    jaxpr, sh = ov_art
    assert _errors(HV.lint_jaxpr(jaxpr, ov_prog)) == []
    assert _errors(HV.lint_text(sh, ov_prog)) == []


def test_exec_compiled_lints_clean():
    assert _errors(HV.lint_program(_program(16, 4, 2))) == []


def test_stream_unfactored_compiled_lints_clean():
    prog = _program(16, 4, 2, stream=True, axis_factored=False)
    assert _errors(HV.lint_program(prog)) == []


def test_batched_compiled_lints_clean(stream_prog):
    """The vmapped batch axis divides out of the payload widths."""
    diags = HV.lint_program(stream_prog, batched=True, batch_size=4)
    assert _errors(diags) == []


def test_bigmesh_8x4_compiled_lints_without_devices():
    """The acceptance contract: the 8×4 (32-rank) programs lint at the
    compiled layer on this single-device host — AbstractMesh lowering
    needs no physical devices."""
    import jax
    assert jax.device_count() < 32
    for opts in (dict(overlap=True), dict(stream=True)):
        prog = _program(32, 8, 4, **opts)
        assert _errors(HV.lint_program(prog)) == [], f"opts={opts}"


def test_jaxpr_scan_carries_stream_trip(stream_prog, stream_art):
    """The fori_loop lowers to a jaxpr ``scan`` whose ``length`` is the
    stream's exact trip count — every ppermute inherits it."""
    jaxpr, _ = stream_art
    trips = {jc.trip for jc in hlo_ir.jaxpr_collectives(jaxpr)
             if jc.prim == "ppermute"}
    assert trips == {int(stream_prog.stream_tables.steps)}


# ---------------------------------------------------------------------------
# (b) mutation self-test: every corruption class fires its own code
# ---------------------------------------------------------------------------

def _cp_line_idx(sh):
    idxs = [i for i, ln in enumerate(sh.splitlines())
            if "stablehlo.collective_permute" in ln]
    assert idxs, "no collective_permute in the lowered text"
    return idxs


def test_mutation_retargeted_permute(stream_prog, stream_art):
    """Rewriting one permute's source_target_pairs to a pair set no
    comm slot owns is hlo/perm-unknown."""
    _, sh = stream_art
    lines = sh.splitlines()
    i = _cp_line_idx(sh)[0]
    mut = re.sub(r"source_target_pairs\s*=\s*dense<.*?>",
                 "source_target_pairs = dense<[[0, 0]]>", lines[i])
    assert mut != lines[i]
    lines[i] = mut
    codes = _codes(HV.lint_text("\n".join(lines), stream_prog))
    assert "hlo/perm-unknown" in codes


def test_mutation_dropped_slot(stream_prog, stream_art):
    """Deleting a compiled permute orphans its comm slot:
    hlo/perm-missing (and only that — the rest still match), and
    enforce_verification(mode="error") raises on it."""
    _, sh = stream_art
    lines = sh.splitlines()
    del lines[_cp_line_idx(sh)[0]]
    diags = HV.lint_text("\n".join(lines), stream_prog)
    codes = _codes(diags)
    assert "hlo/perm-missing" in codes
    assert "hlo/perm-unknown" not in codes
    with pytest.raises(PlanVerificationError):
        enforce_verification(diags, mode="error", where="mutated sweep")


def test_mutation_stray_collective(stream_prog, stream_art):
    _, sh = stream_art
    lines = sh.splitlines()
    lines.insert(_cp_line_idx(sh)[0],
                 '    %stray = "stablehlo.all_gather"(%arg0) : '
                 "(tensor<8x8xf32>) -> tensor<8x8xf32>")
    codes = _codes(HV.lint_text("\n".join(lines), stream_prog))
    assert "hlo/stray-collective" in codes


def test_mutation_precision_loss(stream_prog, stream_art):
    _, sh = stream_art
    lines = sh.splitlines()
    lines.insert(_cp_line_idx(sh)[0],
                 "    %narrowed = stablehlo.convert %arg0 : "
                 "(tensor<8x8xf64>) -> tensor<8x8xf32>")
    codes = _codes(HV.lint_text("\n".join(lines), stream_prog))
    assert "hlo/precision-loss" in codes


def test_mutation_byte_drift(stream_prog, stream_art):
    """Editing a permute's result payload to a width no slot packs is
    hlo/bytes-drift."""
    _, sh = stream_art
    lines = sh.splitlines()
    i = _cp_line_idx(sh)[0]
    head, tail = lines[i].rsplit("-> tensor<", 1)
    dims = tail.split("x")
    dims[0] = "999"
    lines[i] = head + "-> tensor<" + "x".join(dims)
    codes = _codes(HV.lint_text("\n".join(lines), stream_prog))
    assert "hlo/bytes-drift" in codes


def test_mutation_loop_trip(stream_prog, stream_art):
    """A permute whose loop-context execution count disagrees with the
    slot's trip count is hlo/loop-trip."""
    _, sh = stream_art
    ops = hlo_ir.parse_collectives(sh)
    cps = [op for op in ops if op.op == "collective-permute"]
    assert cps and all(
        op.multiplier == int(stream_prog.stream_tables.steps)
        for op in cps)
    mut = [dataclasses.replace(op, multiplier=1) if i == 0 else op
           for i, op in enumerate(ops)]
    codes = _codes(HV.check_collectives(mut, stream_prog,
                                        layer="stablehlo"))
    assert "hlo/loop-trip" in codes


# ---------------------------------------------------------------------------
# (c) the wire triangle: compiled == plan tables == executed
# ---------------------------------------------------------------------------

def test_wire_triangle_stream(stream_prog, stream_art):
    from repro.core.simulator import executed_wire_bytes
    from repro.core.stream import stream_wire_blocks
    _, sh = stream_art
    blocks = HV.compiled_wire_blocks(hlo_ir.parse_collectives(sh),
                                     stream_prog)
    assert blocks == HV.expected_wire_blocks(stream_prog)
    assert blocks == stream_wire_blocks(stream_prog.stream_tables)
    b = stream_prog.b
    assert blocks * b * b * BYTES_PER_ELT == \
        executed_wire_bytes(stream_prog)


def test_wire_triangle_overlap(ov_prog, ov_art):
    from repro.core.simulator import executed_wire_bytes
    from repro.core.stream import overlap_wire_blocks
    _, sh = ov_art
    blocks = HV.compiled_wire_blocks(hlo_ir.parse_collectives(sh),
                                     ov_prog)
    assert blocks == HV.expected_wire_blocks(ov_prog)
    assert blocks == overlap_wire_blocks(ov_prog.overlap_plan)
    b = ov_prog.b
    assert blocks * b * b * BYTES_PER_ELT == executed_wire_bytes(ov_prog)


# ---------------------------------------------------------------------------
# (d) the shared parser: multiplier propagation + size regression
# ---------------------------------------------------------------------------

_SYNTH_HLO = """\
HloModule synth

%inner (q: f32[2]) -> f32[2] {
  %q = f32[2] parameter(0)
  %cp2 = f32[2] collective-permute(%q), source_target_pairs={{0,1}}
  ROOT %r2 = f32[2] add(%q, %q)
}

%body (p: f32[2]) -> f32[2] {
  %p = f32[2] parameter(0)
  %cp = f32[2] collective-permute(%p), source_target_pairs={{0,1},{1,0}}
  %f = f32[2] fusion(%cp), kind=kLoop, calls=%inner
  ROOT %r = f32[2] add(%cp, %f)
}

%cond (s: f32[2]) -> pred[] {
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%c, %c), direction=LT
}

ENTRY %main (x: f32[2]) -> f32[2] {
  %x = f32[2] parameter(0)
  ROOT %w = f32[2] while(%x), condition=%cond, body=%body
}
"""


def test_hlo_multiplier_propagation():
    """while edges always propagate trip counts; fusion/call edges only
    under through_calls (what HloLint needs to see gated slots)."""
    m = hlo_ir.computation_multipliers(_SYNTH_HLO)
    assert m["body"] == 5 and m["inner"] == 1
    mc = hlo_ir.computation_multipliers(_SYNTH_HLO, through_calls=True)
    assert mc["body"] == 5 and mc["inner"] == 5
    ops = {op.computation: op
           for op in hlo_ir.parse_collectives(_SYNTH_HLO)}
    assert ops["body"].multiplier == 5
    assert ops["inner"].multiplier == 5
    assert ops["body"].pairs == ((0, 1), (1, 0))


def test_collective_bytes_keeps_dryrun_semantics():
    """The dryrun pricing stays while-edges-only: the fused permute
    counts once, the loop-body one trip-count times."""
    out = hlo_ir.collective_bytes(_SYNTH_HLO)
    assert out == {"collective-permute": 2 * 4 * 5 + 2 * 4}
    from repro.launch.dryrun import collective_bytes as dryrun_cb
    assert dryrun_cb is hlo_ir.collective_bytes


def test_size_baseline_and_regress(stream_art):
    baseline = HV.load_size_baseline(os.path.join(
        ROOT, "BENCH_pselinv.json"))
    assert baseline is not None and baseline["hlo_bytes"] > 0
    _, sh = stream_art
    ok = HV.check_size({"hlo_bytes": float(len(sh))}, baseline)
    assert [d for d in ok if d.code == "hlo/size-regress"] == []
    bloated = HV.check_size(
        {"hlo_bytes": 2.0 * baseline["hlo_bytes"]}, baseline)
    assert [d.code for d in bloated] == ["hlo/size-regress"]
    assert all(d.severity == "warn" for d in bloated)
    assert HV.check_size({"hlo_bytes": 1.0}, None) == []


# ---------------------------------------------------------------------------
# (e) wiring: options validation, build-time pass, engine reporting
# ---------------------------------------------------------------------------

def test_plan_options_verify_compiled_validates():
    for mode in ("error", "warn", "off"):
        assert PlanOptions(verify_compiled=mode).verify_compiled == mode
    with pytest.raises(ValueError, match="verify_compiled"):
        PlanOptions(verify_compiled="bogus")


def test_build_program_verify_compiled_clean():
    """verify_compiled="error" runs HloLint inside build_program and a
    clean program builds without raising."""
    bs = _structure(16)
    prog = build_program(bs, pad_nb(bs.nsuper, 4, 2), 8, 4, 2,
                         options=PlanOptions(stream=True,
                                             verify_compiled="error"))
    assert prog.stream_tables is not None


def test_engine_compile_stats_and_lint_compiled():
    """On 8 real devices: compile_stats (single and batched) reports
    the optimized-HLO ppermute census and collective bytes, and
    lint_compiled passes all three layers clean."""
    run_sub("""
        import jax
        import scipy.sparse as sp
        from repro.core import hlo_verify, sparse
        from repro.core.engine import Grid, PlanOptions, PSelInvEngine

        assert len(jax.devices()) == 8
        A = sparse.laplacian_2d(16, 8)
        eng = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                    options=PlanOptions(stream=True))
        n_exp = len(hlo_verify.expected_permutes(eng.program))
        cs = eng.compile_stats()
        assert cs["ppermute_count"] == n_exp, cs
        assert cs["collective_bytes"] > 0
        csb = eng.compile_stats(batched=True, batch_size=4)
        assert csb["ppermute_count"] == n_exp, csb
        assert csb["collective_bytes"] > cs["collective_bytes"]

        diags = eng.lint_compiled(verify_compiled="error")
        assert [d for d in diags if d.severity == "error"] == []
        assert eng.lint_compiled() is diags  # cached per shape class

        # the override is part of the session cache key
        eng2 = PSelInvEngine.analyze(A, b=8, grid=Grid(4, 2),
                                     options=PlanOptions(stream=True),
                                     verify_compiled="error")
        assert eng2 is not eng
        assert eng2.options.verify_compiled == "error"
        print("OK", n_exp)
    """)


# ---------------------------------------------------------------------------
# (f) tooling: the HloLint CLI exits clean
# ---------------------------------------------------------------------------

def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(ROOT, "tools", f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_hlo_lint_cli_clean():
    tool = _load_tool("hlo_lint")
    assert tool.main(["--grid", "4x2", "--nb", "16"]) == 0
